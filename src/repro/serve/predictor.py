"""Microbatching prediction service for the paper's Model contract.

The LM engine next door serves token streams; this module serves the
*classic* side of §III-C — any trained :class:`repro.core.interfaces.Model`
(logistic regression, k-means, ALS factors, …) — behind the same
queue-then-batch shape:

    submit (n_i, d) feature blocks  →  pack into fixed-size microbatches
    →  ONE compiled predict per microbatch  →  split outputs per request

Microbatches have a *static* row count (``max_batch``, short final batch
right-padded with zeros and sliced off), so the whole service runs on one
compiled program — the serving twin of the training side's static-shape
discipline.  With ``num_shards``/``mesh`` that program is a shard-aware
one-pass ``combine="concat"`` predict through ``DistributedRunner``
under the configured :class:`CollectiveSchedule` — the same plumbing as
:func:`repro.eval.metrics.predictions`, jitted once for the service's
lifetime — so rows never gather to one host.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Any, Callable, Deque, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collectives import CollectiveSchedule

__all__ = ["PredictRequest", "ModelPredictor"]


@dataclasses.dataclass
class PredictRequest:
    """One prediction request: a block of feature rows — or *raw* rows
    (a str / sequence of str) when the service carries a featurizer (a
    fitted pipeline's host-tier vocab lookup).

    ``result`` is filled by the service (shape ``(n,)`` or ``(n, …)``
    matching the model's per-row output); ``done`` flips on completion.
    """

    features: np.ndarray               # (n, d) — or (d,), treated as (1, d)
    result: Optional[np.ndarray] = None
    done: bool = False
    arrival: float = 0.0
    finished_at: Optional[float] = None
    #: True when ``features`` holds raw (string) rows awaiting host-tier
    #: featurization at flush time
    raw: bool = dataclasses.field(default=False, init=False)

    def __post_init__(self):
        if isinstance(self.features, str):
            self.features = np.asarray([self.features], object)
        else:
            self.features = np.asarray(self.features)
        if self.features.dtype.kind in "OUS":
            self.raw = True
            self.features = self.features.reshape(-1)
            return
        if self.features.ndim == 1:
            self.features = self.features[None, :]
        if self.features.ndim != 2:
            raise ValueError("features must be (n, d) rows")


class ModelPredictor:
    """Queue + microbatcher around ``model.predict``.

    Rows from queued requests are packed greedily into ``max_batch``-row
    microbatches — a request larger than one microbatch spans several, and
    one microbatch can serve many small requests (rows are independent
    under the Model contract).  Each microbatch is served by one compiled
    predict; the final short batch is zero-padded to the same shape and
    the pad rows sliced off before results are scattered back.

    Raw (string) rows pass a host-side **featurize memo** first — a
    bounded LRU keyed by row content, the classical-model twin of the
    serving stack's radix prefix KV cache: repeated raw-text rows skip
    re-featurization entirely (fitted featurizers replay frozen
    statistics, so a row's features are a pure function of its content).
    ``featurize_cache=0`` disables it.
    """

    def __init__(self, model: Any, *, max_batch: int = 256,
                 num_shards: int = 1, mesh=None,
                 schedule: Union[str, CollectiveSchedule]
                 = CollectiveSchedule.GATHER_BROADCAST,
                 predict_fn: Optional[Callable] = None,
                 featurize: Optional[Callable] = None,
                 featurize_cache: int = 512):
        if max_batch < 1:
            raise ValueError("max_batch must be positive")
        if num_shards > 1 and max_batch % num_shards:
            raise ValueError(f"max_batch {max_batch} must divide over "
                             f"{num_shards} shards")
        self.model = model
        self.max_batch = int(max_batch)
        self.num_shards = int(num_shards)
        self.mesh = mesh
        self.schedule = schedule
        self._predict = predict_fn if predict_fn is not None else model.predict
        # raw (string) requests run this host-tier map before packing —
        # defaults to the model's own featurizer (a FittedPipeline's vocab
        # lookup), so a raw-text request flows vocab lookup → device
        # feature chain → predict inside the same microbatching path
        self._featurize = (featurize if featurize is not None
                           else getattr(model, "featurize_rows", None))
        self._compiled = None
        self._queue: Deque[PredictRequest] = deque()
        # bounded LRU over featurized raw rows, keyed by row content
        self._feat_cap = int(featurize_cache)
        self._feat_memo: Optional[OrderedDict] = (
            OrderedDict() if self._feat_cap > 0 else None)
        # stats
        self.batches = 0
        self.rows_served = 0
        self.rows_padded = 0
        self.featurize_hits = 0
        self.featurize_misses = 0

    # ------------------------------------------------------------------ #
    # service surface
    # ------------------------------------------------------------------ #
    def submit(self, req: PredictRequest) -> PredictRequest:
        if req.raw and self._featurize is None:
            # fail fast, per request — a bad request must never poison the
            # queued valid ones at flush time
            raise ValueError(
                "raw (string) request but the service has no featurizer — "
                "serve a FittedPipeline or pass featurize=")
        self._queue.append(req)
        return req

    @property
    def queued(self) -> int:
        return len(self._queue)

    def flush(self, now: float = 0.0) -> List[PredictRequest]:
        """Serve everything queued; returns the completed requests.

        The queue is popped only AFTER every microbatch has succeeded: a
        predict/compile error (a bad ``predict_fn``, an incompatible
        feature width) must leave all queued requests intact for a retry
        — clearing up front silently dropped the whole queue with
        ``done=False`` and no way to resubmit (regression:
        ``tests/test_serve.py::test_flush_failure_keeps_queue``).  The
        per-microbatch stats roll back too, so a failed flush is
        invisible in ``report()``."""
        reqs = list(self._queue)
        if not reqs:
            return []
        # featurize raw requests in place first — a featurizer error also
        # leaves every queued request intact (featurization is idempotent
        # here: ``raw`` flips off per request as it succeeds)
        blocks = []
        for r in reqs:
            if r.raw:
                r.features = self._featurize_rows(list(r.features))
                r.raw = False               # (n, d): featurized once
            blocks.append(r.features)
        rows = np.concatenate(blocks, axis=0)
        outs: List[np.ndarray] = []
        batches0, padded0 = self.batches, self.rows_padded
        try:
            for start in range(0, rows.shape[0], self.max_batch):
                chunk = rows[start : start + self.max_batch]
                pad = self.max_batch - chunk.shape[0]
                if pad:
                    chunk = np.concatenate(
                        [chunk, np.zeros((pad,) + chunk.shape[1:], chunk.dtype)])
                    self.rows_padded += pad
                outs.append(np.asarray(self._predict_batch(chunk))[
                    : self.max_batch - pad])
                self.batches += 1
        except Exception:
            self.batches, self.rows_padded = batches0, padded0
            raise
        for _ in reqs:                      # all microbatches succeeded
            self._queue.popleft()
        flat = np.concatenate(outs, axis=0)
        self.rows_served += rows.shape[0]
        ofs = 0
        for r in reqs:
            n = r.features.shape[0]
            r.result = flat[ofs : ofs + n]
            r.done = True
            r.finished_at = now
            ofs += n
        return reqs

    @staticmethod
    def _row_key(row):
        """Content key for one raw row (str/bytes hash directly; anything
        array-like keys on dtype+shape+bytes)."""
        if isinstance(row, (str, bytes)):
            return row
        arr = np.asarray(row)
        if arr.dtype.kind in "OUS":
            return str(row)
        return (arr.dtype.str, arr.shape, arr.tobytes())

    def _featurize_rows(self, rows: List[Any]) -> np.ndarray:
        """Featurize ``rows`` through the LRU memo: only content-new rows
        reach the featurizer; repeats are served from the memo (valid
        because a fitted featurizer is a pure per-row function)."""
        if self._feat_memo is None:
            return np.asarray(self._featurize(rows), np.float32)
        memo = self._feat_memo
        keys = [self._row_key(r) for r in rows]
        local: dict = {}
        miss_keys: List[Any] = []
        miss_rows: List[Any] = []
        for k, r in zip(keys, rows):
            if k in local:
                continue
            if k in memo:
                memo.move_to_end(k)
                local[k] = memo[k]
            else:
                local[k] = None
                miss_keys.append(k)
                miss_rows.append(r)
        if miss_rows:
            feats = np.asarray(self._featurize(miss_rows), np.float32)
            for k, f in zip(miss_keys, feats):
                local[k] = f
                memo[k] = f
                if len(memo) > self._feat_cap:
                    memo.popitem(last=False)
        self.featurize_misses += len(miss_rows)
        self.featurize_hits += len(rows) - len(miss_rows)
        return np.stack([local[k] for k in keys])

    def predict_many(self, blocks: List[np.ndarray],
                     now: float = 0.0) -> List[np.ndarray]:
        """Convenience: submit + flush a list of feature blocks, returning
        results in submission order."""
        reqs = [self.submit(PredictRequest(features=b)) for b in blocks]
        self.flush(now)
        return [r.result for r in reqs]

    # ------------------------------------------------------------------ #
    # device path
    # ------------------------------------------------------------------ #
    def _predict_batch(self, chunk: np.ndarray) -> jnp.ndarray:
        """One microbatch through ONE compiled program (the zero-padding
        exists exactly so every batch shares it) — shard-aware when the
        service has shards/mesh, the plain predict otherwise."""
        return self._jitted()(jnp.asarray(chunk))

    def _jitted(self):
        if self._compiled is None:
            if self.mesh is not None or self.num_shards > 1:
                # one runner, one jit, built once: the same one-pass
                # combine="concat" plumbing as eval.metrics.predictions,
                # without rebuilding a table/runner per microbatch
                from repro.core.runner import DistributedRunner

                runner = DistributedRunner(mesh=self.mesh,
                                           num_shards=self.num_shards,
                                           schedule=self.schedule)
                self._compiled = jax.jit(lambda X: runner.partition_apply(
                    X, lambda b: jnp.asarray(self._predict(b)), (), "concat"))
            else:
                self._compiled = jax.jit(lambda X: self._predict(X))
        return self._compiled

    # ------------------------------------------------------------------ #
    # reporting
    # ------------------------------------------------------------------ #
    def report(self) -> dict:
        served = max(self.rows_served, 1)
        return {
            "batches": self.batches,
            "rows_served": self.rows_served,
            "rows_padded": self.rows_padded,
            "pad_fraction": self.rows_padded / (served + self.rows_padded),
            "max_batch": self.max_batch,
            "shards": self.num_shards if self.mesh is None else "mesh",
            "featurize_hits": self.featurize_hits,
            "featurize_misses": self.featurize_misses,
            "featurize_cache": self._feat_cap,
        }
