"""Continuous-batching decode engine: one shared KV cache, per-slot
positions, mid-decode backfill.

The serving-side analogue of the paper §III-C 'Model makes predictions'
contract, scaled from one ``predict`` call to a request stream.  Device
work is three jitted functions:

  * **ragged prefill** — newly admitted prompts of *mixed* lengths are
    right-padded and prefilled together (``TransformerLM.prefill_ragged``);
    pad columns never enter the shared cache, so each slot's cache is
    exactly what a lone batch-1 prefill would have written.  Architectures
    whose state a pad tail would corrupt (recurrent blocks, MoE capacity
    routing, encoder/vision frontends) prefill per-request into a batch-1
    cache that is scattered into the slot instead.
  * **fused decode** — ONE masked decode step advances every busy slot
    regardless of where each sits in its sequence: ``pos`` is a (B,)
    vector and the attention mask is per-slot (``models/layers/attention``).
  * **cache scatter** — drops a prefilled request into its slot of the
    shared cache.

Slot admission, retirement, and backfill are host-side and owned by
:class:`repro.serve.scheduler.SlotScheduler`; the engine is the device
half.  ``run_static`` keeps the pre-refactor behavior (equal-length
grouping, no backfill) as the reference baseline — greedy token streams
from both paths are identical per request (asserted in
``tests/test_serve_continuous.py``; measured in
``benchmarks/serving_throughput.py``).

The mesh/rules the engine runs under come from
:func:`repro.launch.mesh.serving_setup` (or its host-sized twin); passing
``mesh=`` shards the cache's slot axis over the mesh data axes via the
same logical-rule machinery as params (``serve/step.py`` +
``sharding/rules``).
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ATTENTION_KINDS, ArchConfig
from repro.models.transformer import TransformerLM
from repro.serve.scheduler import Request, SlotScheduler

__all__ = ["Request", "ServeEngine"]


def _now_zero() -> float:
    return 0.0


class ServeEngine:
    """Fixed-slot continuous-batching engine over a request stream.

    ``batch_size`` decode slots share one KV cache with a real batch
    dimension; requests are admitted into free slots (backfilled
    mid-decode as others retire) and every busy slot advances through one
    fused per-slot-position decode step per token.  Greedy outputs are
    identical to the slot-at-a-time path (tested).
    """

    def __init__(self, cfg: ArchConfig, params, batch_size: int, max_seq: int,
                 greedy: bool = True, mesh=None, rules=None, param_axes=None,
                 prefix_cache=None):
        self.cfg = cfg
        self.model = TransformerLM(cfg)
        self.batch = int(batch_size)
        self.max_seq = int(max_seq)
        self.greedy = greedy
        self.mesh = mesh
        if mesh is not None:
            from repro.sharding.rules import DEFAULT_RULES, shardings_for
            self.rules = rules if rules is not None else DEFAULT_RULES
            if param_axes is not None:
                params = jax.device_put(
                    params, shardings_for(param_axes, params, mesh, self.rules))
        if cfg.quantize != "none":
            if mesh is not None:
                raise ValueError(
                    "quantize= is a single-host serving knob: the mesh "
                    "param-sharding tables predate QTensor leaves")
            from repro.models.layers.quant import quantize_model_params
            params = quantize_model_params(params, cfg.quantize)
        self.params = params
        # ragged (batched mixed-length) prefill is exact only when no
        # cross-slot or sequential state exists; everything else prefills
        # per-request and scatters into its slot
        self.ragged_ok = (
            all(k in ATTENTION_KINDS for k in cfg.pattern)
            and not cfg.num_experts and not cfg.cross_attention
            and not cfg.vision_tokens)
        self._prefill = jax.jit(
            lambda p, t, c: self.model.prefill(p, t, c))
        self._prefill_ragged = jax.jit(
            lambda p, t, n, c: self.model.prefill_ragged(p, t, n, c))
        self._decode = jax.jit(
            lambda p, t, pos, c: self.model.decode_step(p, t, pos, c))
        self._scatter = jax.jit(self._scatter_impl)
        # radix prefix KV cache (serve/prefix_cache.py): admission becomes
        # match → restore cached blocks → prefill the uncached tail only
        self.prefix_cache = prefix_cache
        if prefix_cache is not None:
            if not self.ragged_ok:
                raise ValueError(
                    "prefix_cache requires the ragged-prefill path "
                    "(attention-only dense decoder)")
            if cfg.cache_dtype == "int8":
                raise ValueError(
                    "prefix_cache needs exact KV restore, but cache_dtype="
                    "'int8' stores quantized K/V while prefill attends raw "
                    "— greedy streams would not be bit-identical cache-on "
                    "vs off.  Use quantize= for int8 weights instead")
            prefix_cache.bind(self.model, self.max_seq)
            self._prefill_ragged_start = jax.jit(
                lambda p, t, n, s, c: self.model.prefill_ragged(
                    p, t, n, c, start_pos=s))

    # ------------------------------------------------------------------ #
    # shared-cache plumbing
    # ------------------------------------------------------------------ #
    def init_shared_cache(self):
        """The engine's one KV cache: batch dim = decode slots.  With a
        mesh, the slot axis is sharded over the mesh data axes ("slot
        sharding") through the same logical-rule table as params."""
        cache = self.model.init_cache(self.batch, self.max_seq)
        if self.mesh is not None:
            from repro.serve.step import cache_axes
            from repro.sharding.rules import shardings_for
            cache = jax.device_put(
                cache, shardings_for(cache_axes(self.cfg), cache, self.mesh,
                                     self.rules))
        return cache

    @staticmethod
    def _scatter_impl(cache, sub_cache, slots: jnp.ndarray):
        """Drop ``sub_cache`` (batch = len(slots)) into ``cache`` at slot
        indices ``slots`` along the batch axis (axis 1 — axis 0 is the
        stacked-periods axis).  Out-of-range slot indices are dropped: the
        ragged prefill pads its admission wave to a fixed batch with dummy
        rows routed to slot ``num_slots``."""
        return jax.tree.map(
            lambda full, sub: full.at[:, slots].set(sub, mode="drop"),
            cache, sub_cache)

    # ------------------------------------------------------------------ #
    # admission → prefill
    # ------------------------------------------------------------------ #
    def _prefill_into(self, cache, admits: List[Tuple[int, Request]],
                      pad_to: int = 8,
                      wave_pad: Optional[int] = None) -> Tuple[Any, np.ndarray]:
        """Prefill the admitted requests and scatter them into their slots.
        Returns (cache, first greedy token per admit).

        Mixed lengths go through ONE ragged right-padded prefill when the
        architecture allows it (``prefill_ragged``).  The admission wave is
        padded along *both* axes to keep compiled shapes stable across
        waves: sequence to a ``pad_to`` bucket, batch to a *wave bucket* —
        a single-request backfill (the dominant steady-state wave once
        slots start retiring one at a time) runs at batch 1, anything
        larger pads to the engine's slot count with dummy length-1 rows
        whose scatter destination is out-of-range (dropped).  Two compiled
        prefills per sequence bucket, whatever the wave size.
        ``wave_pad`` overrides the batch pad target (the router passes
        power-of-2 wave buckets so a fleet-sized cache never pays a
        full-fleet prefill for a two-request backfill).  Architectures the
        ragged path excludes prefill per-request (one compile per distinct
        prompt length) and scatter batch-1 caches.
        """
        slots = np.asarray([s for s, _ in admits], np.int32)
        reqs = [r for _, r in admits]
        lens = np.asarray([len(r.prompt) for r in reqs], np.int32)
        if np.any(lens + np.asarray([r.max_new_tokens for r in reqs]) >
                  self.max_seq):
            raise ValueError("prompt + max_new_tokens exceeds max_seq")
        if self.ragged_ok:
            n, B = len(reqs), self.batch
            if wave_pad is not None:
                wb = max(min(int(wave_pad), B), n)
            else:
                wb = 1 if n == 1 else B           # wave bucket (batch pad)
            if self.prefix_cache is not None:
                return self._prefill_into_cached(cache, reqs, slots, lens,
                                                 wb, pad_to)
            S = min(int(-(-int(lens.max()) // pad_to) * pad_to), self.max_seq)
            padded = np.zeros((wb, S), np.int32)
            full_lens = np.ones(wb, np.int32)     # dummy rows: 1 real token
            full_slots = np.full(wb, B, np.int32)  # dummy rows: OOB → dropped
            for i, r in enumerate(reqs):
                padded[i, : lens[i]] = r.prompt
                full_lens[i] = lens[i]
                full_slots[i] = slots[i]
            sub = self.model.init_cache(wb, self.max_seq)
            logits, sub = self._prefill_ragged(
                self.params, jnp.asarray(padded), jnp.asarray(full_lens), sub)
            cache = self._scatter(cache, sub, jnp.asarray(full_slots))
            first = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
            return cache, first[:n]
        first = np.zeros(len(reqs), np.int32)
        for i, r in enumerate(reqs):
            sub = self.model.init_cache(1, self.max_seq)
            logits, sub = self._prefill(
                self.params, jnp.asarray(r.prompt, jnp.int32)[None, :], sub)
            cache = self._scatter(cache, sub, jnp.asarray(slots[i : i + 1]))
            first[i] = int(jnp.argmax(logits[0, -1]))
        return cache, first

    def _prefill_into_cached(self, cache, reqs: List[Request],
                             slots: np.ndarray, lens: np.ndarray,
                             wb: int, pad_to: int):
        """Admission wave with the radix prefix cache: match each prompt's
        longest cached block-aligned prefix (pinned), then **split the
        wave** — miss rows (no cached prefix) run the plain ragged prefill
        (the exact cache-off compiled program, so their streams are
        trivially identical), hit rows get their matched blocks scattered
        into a sub-cache with one jitted restore and run ONE ragged
        **tail** prefill over the uncached suffixes
        (``prefill_ragged(start_pos=)``) whose sequence bucket is sized by
        the longest *tail* alone.  Without the split, a single miss in an
        80%-shared wave dragged every hit row's bucket back to full prompt
        width — through the wider prefix-attending program, i.e. slower
        than no cache at all.  Finally every full prompt block (fresh or
        ``valid_end``-improved) is gathered back into the pool with one
        jitted extract per sub-cache."""
        pc = self.prefix_cache
        n, B = len(reqs), self.batch
        matches = [pc.match(r.prompt) for r in reqs]
        starts = np.asarray([m.length for m in matches], np.int32)
        hit = np.nonzero(starts > 0)[0]
        miss = np.nonzero(starts == 0)[0]
        first = np.zeros(n, np.int32)
        groups = []                               # (sub_cache, req indices)

        def _bucket_batch(k: int) -> int:
            p = 1
            while p < k:
                p <<= 1
            return min(p, wb)                     # 1,2,4,… capped at wave pad

        if len(miss):
            idx = miss
            k, wbg = len(idx), _bucket_batch(len(miss))
            S = min(int(-(-int(lens[idx].max()) // pad_to) * pad_to),
                    self.max_seq)
            padded = np.zeros((wbg, S), np.int32)
            glens = np.ones(wbg, np.int32)        # dummy rows: 1 real token
            gslots = np.full(wbg, B, np.int32)    # dummy rows: OOB → dropped
            for j, i in enumerate(idx):
                padded[j, : lens[i]] = reqs[i].prompt
                glens[j] = lens[i]
                gslots[j] = slots[i]
            sub = self.model.init_cache(wbg, self.max_seq)
            logits, sub = self._prefill_ragged(
                self.params, jnp.asarray(padded), jnp.asarray(glens), sub)
            cache = self._scatter(cache, sub, jnp.asarray(gslots))
            first[idx] = np.asarray(jnp.argmax(logits[:, -1], axis=-1),
                                    np.int32)[:k]
            groups.append((sub, idx))
        if len(hit):
            idx = hit
            k, wbg = len(idx), _bucket_batch(len(hit))
            tails = lens[idx] - starts[idx]       # ≥ 1 (match leaves a tail)
            S = min(int(-(-int(tails.max()) // pad_to) * pad_to),
                    self.max_seq)
            padded = np.zeros((wbg, S), np.int32)
            glens = np.ones(wbg, np.int32)
            gstarts = np.zeros(wbg, np.int32)
            gslots = np.full(wbg, B, np.int32)
            for j, i in enumerate(idx):
                padded[j, : tails[j]] = reqs[i].prompt[starts[i]:]
                glens[j] = tails[j]
                gstarts[j] = starts[i]
                gslots[j] = slots[i]
            sub = self.model.init_cache(wbg, self.max_seq)
            restores = [(j, node.block_id, d * pc.block_size,
                         int(starts[i]))
                        for j, i in enumerate(idx)
                        for d, node in enumerate(matches[i].nodes)]
            sub = pc.restore_into(sub, restores)
            logits, sub = self._prefill_ragged_start(
                self.params, jnp.asarray(padded), jnp.asarray(glens),
                jnp.asarray(gstarts), sub)
            cache = self._scatter(cache, sub, jnp.asarray(gslots))
            first[idx] = np.asarray(jnp.argmax(logits[:, -1], axis=-1),
                                    np.int32)[:k]
            groups.append((sub, idx))
        # record this wave's full prompts: freshly computed blocks (and
        # blocks whose valid_end improves) flow back into the pool from
        # whichever sub-cache holds the row
        for sub, idx in groups:
            inserts = [(j, bid, st)
                       for j, i in enumerate(idx)
                       for bid, st in pc.plan_insert(reqs[i].prompt)]
            pc.extract_from(sub, inserts)
        for r, m in zip(reqs, matches):
            r.cached_prefill = int(m.length)
            pc.release(m)
        return cache, first

    # ------------------------------------------------------------------ #
    # continuous-batching loop
    # ------------------------------------------------------------------ #
    def run(self, requests: List[Request],
            scheduler: Optional[SlotScheduler] = None,
            now_fn=None) -> List[Request]:
        """Serve ``requests`` with continuous batching: admit into free
        slots, advance all busy slots through one fused decode step per
        token, retire on EOS/``max_new_tokens``, and backfill freed slots
        from the queue mid-decode.  ``now_fn`` supplies the clock for the
        scheduler's latency accounting (default: a frozen 0 clock, which
        keeps unit tests deterministic); requests whose ``arrival`` lies in
        the future are held back until the clock reaches them."""
        sched = scheduler or SlotScheduler(self.batch)
        now = now_fn or _now_zero
        if now is _now_zero and any(r.arrival > 0 for r in requests):
            raise ValueError("requests with a future arrival need an "
                             "advancing clock: pass now_fn="
                             "time.perf_counter (or rebase arrivals to 0)")
        for r in requests:
            sched.submit(r)

        B = self.batch
        cache = self.init_shared_cache()
        toks = np.zeros(B, np.int32)     # pending (unemitted) token per slot
        pos = np.zeros(B, np.int32)      # decode position per slot
        while sched.has_work():
            t = now()
            admits = sched.admit(t)
            if not admits and sched.busy == 0:
                # nothing running and nothing admissible yet: the next
                # arrival is in the future — let the clock catch up
                nxt = sched.next_arrival()
                if nxt is None:
                    break  # defensive; has_work() should have said no
                time.sleep(min(max(nxt - now(), 0.0), 0.001))
                continue
            if admits:
                cache, first = self._prefill_into(cache, admits)
                for (slot, req), tok in zip(admits, first):
                    toks[slot] = tok
                    pos[slot] = len(req.prompt)
            # emit one token per busy slot; retire EOS / exhausted slots
            t = now()
            for slot in range(B):
                req = sched.slots[slot]
                if req is None:
                    continue
                if req.max_new_tokens == 0:
                    sched.retire(slot, t)
                    continue
                req.out_tokens.append(int(toks[slot]))
                if len(req.out_tokens) >= req.max_new_tokens or (
                        req.eos_id is not None and toks[slot] == req.eos_id):
                    sched.retire(slot, t)
            if sched.busy == 0:
                continue  # all retired; backfill (or finish) next iteration
            # ONE fused masked step advances every slot, each at its own pos
            logits, cache = self._decode(
                self.params, jnp.asarray(toks[:, None], jnp.int32),
                jnp.asarray(pos, jnp.int32), cache)
            step_toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1),
                                   np.int32)
            for slot in range(B):
                if sched.slots[slot] is not None:
                    toks[slot] = step_toks[slot]
                    pos[slot] += 1
        return requests

    # ------------------------------------------------------------------ #
    # reference paths (parity + benchmark baseline)
    # ------------------------------------------------------------------ #
    def _run_one(self, req: Request) -> Request:
        """Slot-at-a-time reference: prefill one request, then greedy-decode
        token by token with a batch-1 cache.  The parity oracle for the
        continuous path."""
        S = len(req.prompt)
        cache = self.model.init_cache(1, self.max_seq)
        logits, cache = self._prefill(
            self.params, jnp.asarray(req.prompt, jnp.int32)[None, :], cache)
        pos = S
        tok = int(jnp.argmax(logits[0, -1]))
        for _ in range(req.max_new_tokens):
            req.out_tokens.append(tok)
            if req.eos_id is not None and tok == req.eos_id:
                break
            logits, cache = self._decode(self.params,
                                         jnp.asarray([[tok]], jnp.int32),
                                         jnp.asarray(pos, jnp.int32), cache)
            tok = int(jnp.argmax(logits[0, -1]))
            pos += 1
        req.done = True
        return req

    def run_static(self, requests: List[Request]) -> List[Request]:
        """The pre-refactor static engine, kept as the benchmark baseline:
        requests with equal prompt length group into one shared-position
        batch; every other request decodes slot-at-a-time.  No admission
        queue, no backfill — on a mixed-length workload this degenerates
        toward slot-at-a-time, which is exactly what
        ``benchmarks/serving_throughput.py`` measures against."""
        groups: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            groups.setdefault(len(r.prompt), []).append(i)
        for plen, idxs in groups.items():
            if len(idxs) == 1:
                self._run_one(requests[idxs[0]])
            else:
                self._run_group([requests[i] for i in idxs], plen)
        return requests

    def _run_group(self, reqs: List[Request], plen: int) -> None:
        """Batched decode for equal-length prompts: shared positions, one
        cache with a true batch dimension, per-slot retirement masks."""
        B = len(reqs)
        prompts = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        cache = self.model.init_cache(B, self.max_seq)
        logits, cache = self._prefill(self.params, prompts, cache)
        toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        pos = plen
        max_new = max(r.max_new_tokens for r in reqs)
        active = np.ones(B, bool)
        for step in range(max_new):
            for b, r in enumerate(reqs):
                if not active[b]:
                    continue
                r.out_tokens.append(int(toks[b]))
                if len(r.out_tokens) >= r.max_new_tokens or (
                        r.eos_id is not None and toks[b] == r.eos_id):
                    active[b] = False
                    r.done = True
            if not active.any() or step == max_new - 1:
                break
            logits, cache = self._decode(self.params,
                                         jnp.asarray(toks[:, None], jnp.int32),
                                         jnp.asarray(pos, jnp.int32), cache)
            toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
            pos += 1
        for r in reqs:
            r.done = True

    # ------------------------------------------------------------------ #
    # warmup (perf reporting excludes compile time)
    # ------------------------------------------------------------------ #
    def warmup(self, prompt_lens: Sequence[int] = (), pad_to: int = 8) -> None:
        """Compile the fused decode step, the cache scatter, and every
        prefill shape the given prompt lengths will hit, so serving (and
        the launcher's perf report) never pays compile time mid-stream.

        On the ragged path the compiled prefill shape depends only on the
        sequence *bucket* and the wave bucket (batch 1 for solo backfills,
        the slot count otherwise), so two warm prefills per distinct
        sequence bucket cover admission waves of any size; the per-request
        fallback path compiles one prefill per distinct prompt length
        instead.

        With a prefix cache the same waves run the match→restore→tail
        pipeline: the first wave per bucket misses (compiling the
        full-length tail prefill and the pool extract), the second wave
        hits the blocks the first just inserted (compiling the restore
        scatter and the short-tail bucket).  Tail buckets are
        traffic-dependent, so an unseen tail length can still cost one
        mid-stream compile of the (small) tail program; the warm-probe
        blocks are dropped from the trie afterwards (``reset``) so warmup
        never pollutes live hit-rate stats.
        """
        lens = sorted(set(int(n) for n in prompt_lens))
        cache = self.init_shared_cache()
        if lens and self.ragged_ok:
            buckets = sorted(set(
                min(-(-n // pad_to) * pad_to, self.max_seq) for n in lens))
            for b in buckets:
                req = Request(prompt=np.zeros(min(b, self.max_seq - 1),
                                              np.int32), max_new_tokens=1)
                cache, _ = self._prefill_into(cache, [(0, req)], pad_to=pad_to)
                if self.batch > 1:
                    wave = [(s, Request(prompt=np.zeros(
                        min(b, self.max_seq - 1), np.int32), max_new_tokens=1))
                        for s in range(min(2, self.batch))]
                    cache, _ = self._prefill_into(cache, wave, pad_to=pad_to)
        elif lens:
            for n in lens:
                req = Request(prompt=np.zeros(n, np.int32), max_new_tokens=1)
                cache, _ = self._prefill_into(cache, [(0, req)], pad_to=pad_to)
        _ = self._decode(self.params,
                         jnp.asarray(np.zeros((self.batch, 1), np.int32)),
                         jnp.asarray(np.zeros(self.batch, np.int32)), cache)
        jax.block_until_ready(_)
        if self.prefix_cache is not None:
            self.prefix_cache.reset()
