"""Batched serving engine: static-batch continuous decode over a request
queue (the serving-side analogue of the paper §III-C 'Model makes
predictions' contract, scaled from one ``predict`` call to a request
stream).

This engine is deliberately simple but real: it admits requests into fixed
batch slots, prefills per request, then steps all active slots together with
one fused decode step per token, retiring slots on EOS/max-tokens.  Slot
admission is host-side; all device work is two jitted functions.

See ``docs/architecture.md`` for where serving sits next to the training
stack and ``docs/benchmarks.md`` for the serving-mesh measurements; the
mesh/rules selection the engine runs under is
:func:`repro.launch.mesh.serving_setup`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.transformer import TransformerLM

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    """One generation request: a prompt plus decode limits.

    The streaming unit of the paper's Model contract (§III-C): where the
    paper's ``Model.predict`` maps one feature vector to one prediction,
    serving maps one ``Request`` to a token stream.  ``out_tokens`` is
    filled in place by the engine; ``done`` flips when the request retires
    (EOS or ``max_new_tokens``).
    """

    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-slot batched decode engine over a request list.

    Two jitted device functions (prefill, decode-step) plus host-side slot
    management.  Requests with equal prompt lengths are decoded together
    through one fused step per token; greedy outputs are identical to the
    slot-at-a-time path (asserted in ``tests/test_serve.py``).  See
    ``docs/architecture.md`` (serving section) for how this relates to the
    training-side DistributedRunner.
    """

    def __init__(self, cfg: ArchConfig, params, batch_size: int, max_seq: int,
                 greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.model = TransformerLM(cfg)
        self.batch = batch_size
        self.max_seq = max_seq
        # one cache per slot (batch=1) so per-request positions stay
        # independent; decode steps run vmapped over slots
        self._prefill = jax.jit(
            lambda p, t, c: self.model.prefill(p, t, c))
        self._decode = jax.jit(
            lambda p, t, pos, c: self.model.decode_step(p, t, pos, c))
        self.greedy = greedy

    def _run_one(self, req: Request) -> Request:
        """Slot-at-a-time fallback: prefill one request, then greedy-decode
        token by token with a batch-1 cache."""
        S = len(req.prompt)
        cache = self.model.init_cache(1, self.max_seq)
        logits, cache = self._prefill(self.params, jnp.asarray(req.prompt)[None, :], cache)
        pos = S
        tok = int(jnp.argmax(logits[0, -1]))
        for _ in range(req.max_new_tokens):
            req.out_tokens.append(tok)
            if req.eos_id is not None and tok == req.eos_id:
                break
            logits, cache = self._decode(self.params,
                                         jnp.asarray([[tok]], jnp.int32),
                                         jnp.asarray(pos, jnp.int32), cache)
            tok = int(jnp.argmax(logits[0, -1]))
            pos += 1
        req.done = True
        return req

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests: requests with equal prompt length are
        grouped and decoded TOGETHER through one fused decode step per token
        (batched continuous decode); odd lengths fall back to slot-at-a-time.
        Greedy outputs are identical either way (tested)."""
        groups: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            groups.setdefault(len(r.prompt), []).append(i)
        for plen, idxs in groups.items():
            if len(idxs) == 1:
                self._run_one(requests[idxs[0]])
            else:
                self._run_group([requests[i] for i in idxs], plen)
        return requests

    def _run_group(self, reqs: List[Request], plen: int) -> None:
        """Batched decode for equal-length prompts: shared positions, one
        cache with a true batch dimension, per-slot retirement masks."""
        B = len(reqs)
        prompts = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        cache = self.model.init_cache(B, self.max_seq)
        logits, cache = self._prefill(self.params, prompts, cache)
        toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)  # (B,)
        pos = plen
        max_new = max(r.max_new_tokens for r in reqs)
        active = np.ones(B, bool)
        for step in range(max_new):
            for b, r in enumerate(reqs):
                if not active[b]:
                    continue
                r.out_tokens.append(int(toks[b]))
                if len(r.out_tokens) >= r.max_new_tokens or (
                        r.eos_id is not None and toks[b] == r.eos_id):
                    active[b] = False
                    r.done = True
            if not active.any() or step == max_new - 1:
                break
            logits, cache = self._decode(self.params,
                                         jnp.asarray(toks[:, None], jnp.int32),
                                         jnp.asarray(pos, jnp.int32), cache)
            toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
            pos += 1
        for r in reqs:
            r.done = True
