"""Continuous-batching admission scheduler.

The host-side half of the serving stack (the device half is
``serve/engine.py``): a request queue plus a fixed table of decode
*slots*.  The engine asks the scheduler, between decode steps, which
requests to admit into free slots (**backfill** — a retirement mid-decode
frees a slot and the next queued request takes it without draining the
batch) and tells it when a slot retires.  The scheduler never touches
device state; it owns arrival release, admission order, and the
queue-depth / latency accounting the launcher reports.

Admission order is a :class:`FairQueue`: *priority classes* (lower number
= more urgent, strict between classes) and, within a class, weighted
fair queuing across *tenants* via stride scheduling — each tenant pays
``1/weight`` virtual time per admission, and the tenant with the least
virtual time goes next, so a tenant flooding the queue cannot starve the
others beyond its weight share.  With a single tenant and a single class
this degenerates *exactly* to the PR-4 ``(arrival, seq)`` FIFO (the
burst-release regression tests pin this).

Petuum (Xing et al., 2013) is the precedent this layer follows: a real
scheduler between the request stream and the device work is what turns a
fixed-batch decoder into a serving system.  See ``docs/architecture.md``
(serving section).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["Request", "FairQueue", "SlotScheduler", "tenant_report"]


@dataclasses.dataclass
class Request:
    """One generation request: a prompt plus decode limits.

    The streaming unit of the paper's Model contract (§III-C): where the
    paper's ``Model.predict`` maps one feature vector to one prediction,
    serving maps one ``Request`` to a token stream.  ``out_tokens`` is
    filled in place by the engine; ``done`` flips when the request retires
    (EOS or ``max_new_tokens``).  ``arrival`` is the request's release time
    on the launcher's clock (0 = available immediately); the ``*_at``
    fields are stamped by the scheduler for the latency report.
    """

    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    arrival: float = 0.0
    # multi-tenant serving (PR 8): who sent it, how urgent, and the
    # end-to-end deadline the router's admission control enforces
    tenant: str = "default"
    priority: int = 1                  # class; lower = more urgent (strict)
    slo_ms: Optional[float] = None     # arrival→finish deadline, milliseconds
    # router-stamped admission outcome
    rejected: bool = False             # refused at admission (SLO hopeless)
    degraded: bool = False             # admitted with max_new_tokens halved
    # scheduler-stamped accounting
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None
    # engine-stamped at admission: prompt tokens whose KV entries came out
    # of the radix prefix cache instead of prefill (serve/prefix_cache.py)
    cached_prefill: int = 0
    # monotone submission sequence stamped by SlotScheduler.submit — the
    # FIFO tiebreak for equal arrival times
    seq: int = -1


class FairQueue:
    """Priority classes over weighted per-tenant FIFOs (stride scheduling).

    ``push`` appends to the ``(priority, tenant)`` FIFO; ``pop`` serves the
    most urgent non-empty class and, within it, the tenant with the least
    *virtual time*, charging the winner ``1/weight``.  A tenant whose lane
    went idle re-enters at the class's minimum active virtual time (the
    standard stride re-entry rule), so idling never banks credit for a
    later burst.  Ties — including the everyone-at-zero start — break on
    the head request's ``(arrival, seq)``, which makes the single-tenant
    single-class case *identical* to a plain arrival-FIFO deque.
    """

    def __init__(self, weights: Optional[Dict[str, float]] = None):
        self._weights = dict(weights or {})
        # priority → tenant → FIFO of released requests
        self._classes: Dict[int, Dict[str, Deque[Request]]] = {}
        self._vt: Dict[Tuple[int, str], float] = {}
        self._len = 0

    def __len__(self) -> int:
        return self._len

    def __bool__(self) -> bool:
        return self._len > 0

    def __iter__(self):
        """Queued requests in an unspecified order (accounting only)."""
        for tenants in self._classes.values():
            for q in tenants.values():
                yield from q

    def push(self, req: Request) -> None:
        tenants = self._classes.setdefault(req.priority, {})
        q = tenants.setdefault(req.tenant, deque())
        if not q:  # lane was idle: re-enter at the class's active minimum
            active = [self._vt.get((req.priority, t), 0.0)
                      for t, d in tenants.items() if d]
            floor = min(active) if active else 0.0
            key = (req.priority, req.tenant)
            self._vt[key] = max(self._vt.get(key, 0.0), floor)
        q.append(req)
        self._len += 1

    def pop(self) -> Request:
        if not self._len:
            raise IndexError("pop from empty FairQueue")
        prio = min(p for p, ts in self._classes.items()
                   if any(ts.values()))
        tenants = self._classes[prio]
        best = min(
            (t for t, d in tenants.items() if d),
            key=lambda t: (self._vt.get((prio, t), 0.0),
                           tenants[t][0].arrival, tenants[t][0].seq))
        req = tenants[best].popleft()
        self._vt[(prio, best)] = (self._vt.get((prio, best), 0.0)
                                  + 1.0 / self._weights.get(best, 1.0))
        self._len -= 1
        return req


class SlotScheduler:
    """Fair queue + slot table with mid-decode backfill.

    Protocol (driven by the engine loop):

        sched.submit(req)                  # any time; respects req.arrival
        while sched.has_work():
            for slot, req in sched.admit(now):   # fills every free slot
                ... prefill req into slot ...
            ... one fused decode step ...
            sched.retire(slot, now)        # when a request finishes

    ``admit`` releases arrivals whose ``arrival <= now``, then fills free
    slots in fair-queue order (plain arrival-FIFO when every request shares
    one tenant and one priority class).  Admissions that land while other
    slots are mid-decode are counted as ``backfills`` — the statistic that
    distinguishes continuous batching from static batching (a static
    engine's count is always 0).  ``tenant_weights`` sets the per-tenant
    fair-queue weights (absent tenants weigh 1.0).
    """

    def __init__(self, num_slots: int,
                 tenant_weights: Optional[Dict[str, float]] = None):
        if num_slots < 1:
            raise ValueError("need at least one slot")
        self.num_slots = int(num_slots)
        self.slots: List[Optional[Request]] = [None] * self.num_slots
        self._pending: Deque[Request] = deque()   # not yet arrived
        self._queue = FairQueue(tenant_weights)   # arrived, awaiting a slot
        # accounting
        self.submitted = 0
        self.admitted = 0
        self.retired = 0
        self.backfills = 0
        # queue-depth running aggregates (one sample per admit call — i.e.
        # per decode step; a raw sample list would grow one entry per
        # generated token for the scheduler's lifetime)
        self._depth_max = 0
        self._depth_sum = 0
        self._depth_samples = 0
        self._finished: List[Request] = []

    # ------------------------------------------------------------------ #
    # queue side
    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        """Add one request; it becomes admissible once ``now >= arrival``.
        Admission is in arrival order; submission order breaks ties within
        equal arrival times."""
        req.seq = self.submitted
        self.submitted += 1
        self._pending.append(req)

    def release(self, now: float) -> None:
        """Move arrived requests from pending into the admission queue, in
        ``(arrival, seq)`` order.  Iterating pending in submission order
        would let a later-arriving request jump an earlier-arriving one
        released in the same call (burst traces submit out of arrival
        order); sorting restores arrival-FIFO, with the submit sequence as
        a stable tiebreak for equal arrivals."""
        still = deque()
        ready = []
        for r in self._pending:
            (ready if r.arrival <= now else still).append(r)
        ready.sort(key=lambda r: (r.arrival, r.seq))
        for r in ready:
            self._queue.push(r)
        self._pending = still

    def next_arrival(self) -> Optional[float]:
        """Earliest arrival time still pending (None when all released)."""
        return min((r.arrival for r in self._pending), default=None)

    # ------------------------------------------------------------------ #
    # slot side
    # ------------------------------------------------------------------ #
    @property
    def busy(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def queued(self) -> int:
        return len(self._queue) + len(self._pending)

    def queued_tokens(self) -> int:
        """Generation tokens still owed by queued + pending requests — the
        work-ahead measure the router's SLO admission predictor scales by
        (a queue of 1-token requests is not a queue of 512-token ones)."""
        return (sum(r.max_new_tokens for r in self._queue)
                + sum(r.max_new_tokens for r in self._pending))

    def has_work(self) -> bool:
        return bool(self._queue or self._pending or self.busy)

    def admit(self, now: float = 0.0) -> List[Tuple[int, Request]]:
        """Fill every free slot from the queue (fair-queue order); returns
        the (slot, request) pairs admitted this call and stamps their wait."""
        self.release(now)
        mid_decode = self.busy > 0
        admits: List[Tuple[int, Request]] = []
        for slot in range(self.num_slots):
            if self.slots[slot] is not None or not self._queue:
                continue
            req = self._queue.pop()
            req.admitted_at = now
            self.slots[slot] = req
            admits.append((slot, req))
            self.admitted += 1
            if mid_decode:
                self.backfills += 1
        depth = len(self._queue)
        self._depth_max = max(self._depth_max, depth)
        self._depth_sum += depth
        self._depth_samples += 1
        return admits

    def retire(self, slot: int, now: float = 0.0) -> Request:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is already free")
        req.done = True
        req.finished_at = now
        self.slots[slot] = None
        self.retired += 1
        self._finished.append(req)
        return req

    # ------------------------------------------------------------------ #
    # reporting
    # ------------------------------------------------------------------ #
    def report(self) -> dict:
        """Queue/latency summary for the launcher (all times on the clock
        the engine passed to ``admit``/``retire``).  ``finished`` is the
        sample count behind the percentiles — ``_pct`` maps an empty list
        to 0.0, so any latency bar MUST also require ``finished > 0`` (the
        nightly ``--check`` does) or an engine that served nothing passes
        with vacuously perfect latency."""
        waits = [r.admitted_at - r.arrival
                 for r in self._finished if r.admitted_at is not None]
        totals = [r.finished_at - r.arrival
                  for r in self._finished if r.finished_at is not None]
        return {
            "submitted": self.submitted,
            "admitted": self.admitted,
            "retired": self.retired,
            "finished": len(self._finished),
            "backfills": self.backfills,
            "queue_depth_max": self._depth_max,
            "queue_depth_mean": (self._depth_sum / self._depth_samples
                                 if self._depth_samples else 0.0),
            "wait_p50": _pct(waits, 50),
            "wait_p95": _pct(waits, 95),
            "wait_p99": _pct(waits, 99),
            "latency_p50": _pct(totals, 50),
            "latency_p95": _pct(totals, 95),
            "latency_p99": _pct(totals, 99),
            "tenants": tenant_report(self._finished),
        }


def tenant_report(requests: List[Request]) -> Dict[str, dict]:
    """Per-tenant outcome rollup over any request population (a scheduler's
    finished list, or the router's full stream including rejections).

    SLO attainment counts every request that *carries* an SLO — rejected
    ones count as misses, so shedding load can't inflate the metric.
    Requests without an SLO are excluded (attainment is 1.0 when no SLO
    was ever set)."""
    out: Dict[str, dict] = {}
    for r in requests:
        t = out.setdefault(r.tenant, {
            "finished": 0, "rejected": 0, "degraded": 0,
            "prefill_tokens": 0, "cached_prefill_tokens": 0,
            "slo_total": 0, "slo_attained": 0, "_lat": []})
        if r.rejected:
            t["rejected"] += 1
        elif r.done:
            t["finished"] += 1
            if r.degraded:
                t["degraded"] += 1
            # per-tenant prefix-cache accounting (0/0 → hit rate 0.0 when
            # no prefix cache is configured)
            t["prefill_tokens"] += len(r.prompt)
            t["cached_prefill_tokens"] += r.cached_prefill
            if r.finished_at is not None:
                t["_lat"].append(r.finished_at - r.arrival)
        if r.slo_ms is not None:
            t["slo_total"] += 1
            if (not r.rejected and r.done and r.finished_at is not None
                    and (r.finished_at - r.arrival) * 1e3 <= r.slo_ms):
                t["slo_attained"] += 1
    for t in out.values():
        lat = t.pop("_lat")
        t["latency_p50"] = _pct(lat, 50)
        t["latency_p99"] = _pct(lat, 99)
        t["slo_attainment"] = (t["slo_attained"] / t["slo_total"]
                               if t["slo_total"] else 1.0)
        t["prefix_hit_rate"] = (t["cached_prefill_tokens"]
                                / t["prefill_tokens"]
                                if t["prefill_tokens"] else 0.0)
    return out


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(xs, q)) if xs else 0.0
