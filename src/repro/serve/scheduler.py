"""Continuous-batching admission scheduler.

The host-side half of the serving stack (the device half is
``serve/engine.py``): a FIFO request queue plus a fixed table of decode
*slots*.  The engine asks the scheduler, between decode steps, which
requests to admit into free slots (**backfill** — a retirement mid-decode
frees a slot and the next queued request takes it without draining the
batch) and tells it when a slot retires.  The scheduler never touches
device state; it owns arrival release, FIFO order, and the queue-depth /
latency accounting the launcher reports.

Petuum (Xing et al., 2013) is the precedent this layer follows: a real
scheduler between the request stream and the device work is what turns a
fixed-batch decoder into a serving system.  See ``docs/architecture.md``
(serving section).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np

__all__ = ["Request", "SlotScheduler"]


@dataclasses.dataclass
class Request:
    """One generation request: a prompt plus decode limits.

    The streaming unit of the paper's Model contract (§III-C): where the
    paper's ``Model.predict`` maps one feature vector to one prediction,
    serving maps one ``Request`` to a token stream.  ``out_tokens`` is
    filled in place by the engine; ``done`` flips when the request retires
    (EOS or ``max_new_tokens``).  ``arrival`` is the request's release time
    on the launcher's clock (0 = available immediately); the ``*_at``
    fields are stamped by the scheduler for the latency report.
    """

    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    arrival: float = 0.0
    # scheduler-stamped accounting
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None
    # monotone submission sequence stamped by SlotScheduler.submit — the
    # FIFO tiebreak for equal arrival times
    seq: int = -1


class SlotScheduler:
    """FIFO queue + slot table with mid-decode backfill.

    Protocol (driven by the engine loop):

        sched.submit(req)                  # any time; respects req.arrival
        while sched.has_work():
            for slot, req in sched.admit(now):   # fills every free slot
                ... prefill req into slot ...
            ... one fused decode step ...
            sched.retire(slot, now)        # when a request finishes

    ``admit`` releases arrivals whose ``arrival <= now``, then fills free
    slots in FIFO order.  Admissions that land while other slots are
    mid-decode are counted as ``backfills`` — the statistic that
    distinguishes continuous batching from static batching (a static
    engine's count is always 0).
    """

    def __init__(self, num_slots: int):
        if num_slots < 1:
            raise ValueError("need at least one slot")
        self.num_slots = int(num_slots)
        self.slots: List[Optional[Request]] = [None] * self.num_slots
        self._pending: Deque[Request] = deque()   # not yet arrived
        self._queue: Deque[Request] = deque()     # arrived, awaiting a slot
        # accounting
        self.submitted = 0
        self.admitted = 0
        self.retired = 0
        self.backfills = 0
        # queue-depth running aggregates (one sample per admit call — i.e.
        # per decode step; a raw sample list would grow one entry per
        # generated token for the scheduler's lifetime)
        self._depth_max = 0
        self._depth_sum = 0
        self._depth_samples = 0
        self._finished: List[Request] = []

    # ------------------------------------------------------------------ #
    # queue side
    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        """Add one request; it becomes admissible once ``now >= arrival``.
        Admission is in arrival order; submission order breaks ties within
        equal arrival times."""
        req.seq = self.submitted
        self.submitted += 1
        self._pending.append(req)

    def release(self, now: float) -> None:
        """Move arrived requests from pending into the admission queue, in
        ``(arrival, seq)`` order.  Iterating pending in submission order
        would let a later-arriving request jump an earlier-arriving one
        released in the same call (burst traces submit out of arrival
        order); sorting restores arrival-FIFO, with the submit sequence as
        a stable tiebreak for equal arrivals."""
        still = deque()
        ready = []
        for r in self._pending:
            (ready if r.arrival <= now else still).append(r)
        ready.sort(key=lambda r: (r.arrival, r.seq))
        self._queue.extend(ready)
        self._pending = still

    def next_arrival(self) -> Optional[float]:
        """Earliest arrival time still pending (None when all released)."""
        return min((r.arrival for r in self._pending), default=None)

    # ------------------------------------------------------------------ #
    # slot side
    # ------------------------------------------------------------------ #
    @property
    def busy(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def queued(self) -> int:
        return len(self._queue) + len(self._pending)

    def has_work(self) -> bool:
        return bool(self._queue or self._pending or self.busy)

    def admit(self, now: float = 0.0) -> List[Tuple[int, Request]]:
        """Fill every free slot from the queue (FIFO); returns the
        (slot, request) pairs admitted this call and stamps their wait."""
        self.release(now)
        mid_decode = self.busy > 0
        admits: List[Tuple[int, Request]] = []
        for slot in range(self.num_slots):
            if self.slots[slot] is not None or not self._queue:
                continue
            req = self._queue.popleft()
            req.admitted_at = now
            self.slots[slot] = req
            admits.append((slot, req))
            self.admitted += 1
            if mid_decode:
                self.backfills += 1
        depth = len(self._queue)
        self._depth_max = max(self._depth_max, depth)
        self._depth_sum += depth
        self._depth_samples += 1
        return admits

    def retire(self, slot: int, now: float = 0.0) -> Request:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is already free")
        req.done = True
        req.finished_at = now
        self.slots[slot] = None
        self.retired += 1
        self._finished.append(req)
        return req

    # ------------------------------------------------------------------ #
    # reporting
    # ------------------------------------------------------------------ #
    def report(self) -> dict:
        """Queue/latency summary for the launcher (all times on the clock
        the engine passed to ``admit``/``retire``)."""
        waits = [r.admitted_at - r.arrival
                 for r in self._finished if r.admitted_at is not None]
        totals = [r.finished_at - r.arrival
                  for r in self._finished if r.finished_at is not None]
        return {
            "submitted": self.submitted,
            "admitted": self.admitted,
            "retired": self.retired,
            "backfills": self.backfills,
            "queue_depth_max": self._depth_max,
            "queue_depth_mean": (self._depth_sum / self._depth_samples
                                 if self._depth_samples else 0.0),
            "wait_p50": _pct(waits, 50),
            "wait_p95": _pct(waits, 95),
            "latency_p50": _pct(totals, 50),
            "latency_p95": _pct(totals, 95),
        }


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(xs, q)) if xs else 0.0
