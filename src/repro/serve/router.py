"""Replica router: a serving fleet on one shared cache.

``ReplicaRouter`` turns the single continuous-batching ``ServeEngine``
into N *replicas* with SLO-aware admission and queue-driven autoscale.
A replica is a **contiguous lane group** of ``slots_per_replica`` decode
slots on ONE fleet-sized KV cache (batch = ``max_replicas × slots_per_
replica``), the same stacking trick ``tune.AshaScheduler`` uses for trial
slots: the fleet advances with ONE fused decode step over the active lane
span, so per-step fixed costs (dispatch, host sync, kernel launch) are
paid once for the whole fleet instead of once per replica — that
amortization is the fleet's throughput win and it holds on any backend.
On a mesh the slot axis of the shared cache is exactly the axis
``serve/step.py`` shards, so lane groups map onto devices unchanged.

Per replica there is a full :class:`SlotScheduler` (fair queue + slot
table); the router in front owns three decisions:

  * **dispatch** — each arrival goes to the active replica with the least
    load (busy + queued), after admission control;
  * **admission** — when the request carries a deadline (``slo_ms`` or a
    per-priority-class default), predicted completion = per-generated-token
    EMA service time × (queued-ahead tokens / slots + the request's own
    ``max_new_tokens`` + a weighted tail-prefill length, shortened by the
    prefix cache's matched prefix when one is configured); a hopeless
    request is *rejected* (or *degraded*: ``max_new_tokens`` halved, then
    re-tested) rather than queued to miss.  Normalizing per token is what
    makes a 512-token request predict 256× longer than a 2-token one —
    the raw per-request EMA gave both the same prediction (regression:
    ``tests/test_serve_router.py``).  Until the EMA has warmed
    (3 completions) everything is admitted — the router never sheds load
    it knows nothing about;
  * **elasticity** — a :class:`QueueAutoscaler` maps demand to a target
    replica count each tick.  Scale-up activates the next lane group
    (compile-warm if ``warmup`` ran).  Scale-down *drains*: the highest
    active replica stops receiving dispatches and its lane group
    deactivates once its last slot retires, so the active span stays a
    contiguous prefix of the cache.

Decode on the active span only: the fused step slices the first
``active × slots_per_replica`` lanes out of the shared cache (batch is
axis 1 of every cache leaf — axis 0 is the stacked-periods axis), decodes
them, and writes the span back.  One compiled program per span size
actually visited.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.serve.autoscaler import QueueAutoscaler
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request, SlotScheduler, _pct, tenant_report

__all__ = ["ReplicaRouter", "PredictorFleet"]


def _now_zero() -> float:
    return 0.0


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


class ReplicaRouter:
    """N lane-group replicas behind admission control and autoscale."""

    def __init__(self, cfg: ArchConfig, params, *, slots_per_replica: int,
                 max_replicas: int, min_replicas: int = 1,
                 max_seq: int = 2048,
                 tenant_weights: Optional[Dict[str, float]] = None,
                 admission: str = "none",          # "none"|"reject"|"degrade"
                 class_slo_ms: Optional[Dict[int, float]] = None,
                 autoscaler: Optional[QueueAutoscaler] = None,
                 ema_beta: float = 0.8,
                 prefix_cache=None,
                 prefill_weight: float = 0.1):
        if admission not in ("none", "reject", "degrade"):
            raise ValueError(f"admission={admission!r}")
        if slots_per_replica < 1 or max_replicas < 1:
            raise ValueError("need >=1 slot per replica and >=1 replica")
        self.spr = int(slots_per_replica)
        self.max_replicas = int(max_replicas)
        self.min_replicas = max(1, min(int(min_replicas), self.max_replicas))
        # one engine ⇒ one cache ⇒ the prefix trie is shared fleet-wide
        # for free: a prefix any replica prefilled is a hit for all lanes
        self.engine = ServeEngine(cfg, params,
                                  batch_size=self.spr * self.max_replicas,
                                  max_seq=max_seq,
                                  prefix_cache=prefix_cache)
        self.scheds = [SlotScheduler(self.spr, tenant_weights)
                       for _ in range(self.max_replicas)]
        self.admission = admission
        self.class_slo_ms = dict(class_slo_ms or {})
        self.autoscaler = autoscaler
        # no autoscaler → fixed fleet at max
        self.active = self.max_replicas if autoscaler is None else self.min_replicas
        self.rejected: List[Request] = []
        # EMA of service seconds PER GENERATED TOKEN (a per-request EMA
        # made a 1-token and a 512-token request predict identically)
        self._ema_tok: Optional[float] = None
        self._ema_beta = float(ema_beta)
        self._completions = 0
        self._prefill_weight = float(prefill_weight)
        self._span_step = {}           # span → jitted slice-decode-writeback

    # ------------------------------------------------------------------ #
    # admission control
    # ------------------------------------------------------------------ #
    def _deadline_s(self, req: Request) -> Optional[float]:
        ms = req.slo_ms if req.slo_ms is not None else \
            self.class_slo_ms.get(req.priority)
        return None if ms is None else ms / 1e3

    def _request_tokens(self, req: Request,
                        max_new: Optional[int] = None) -> float:
        """Token-equivalents of serving ``req``: its generated tokens plus
        its tail-prefill length weighted down by ``prefill_weight``
        (prefill tokens are batched, decode tokens are steps).  With a
        prefix cache the tail shrinks by the currently matched prefix —
        saved prefill feeds straight into the admission prediction."""
        gen = req.max_new_tokens if max_new is None else max_new
        tail = len(req.prompt)
        pc = self.engine.prefix_cache
        if pc is not None:
            tail -= pc.peek(req.prompt)
        return gen + self._prefill_weight * tail

    def _predicted_completion(self, replica: int, req: Request,
                              max_new: Optional[int] = None
                              ) -> Optional[float]:
        """Seconds until ``req`` dispatched to ``replica`` now would
        finish: per-token EMA × (queued-ahead tokens / slots + the
        request's own token-equivalents).  None until the EMA has
        warmed."""
        if self._ema_tok is None or self._completions < 3:
            return None
        queued_tok = self.scheds[replica].queued_tokens()
        return self._ema_tok * (queued_tok / self.spr
                                + self._request_tokens(req, max_new))

    def _admit_or_shed(self, req: Request, replica: int, now: float) -> bool:
        """Returns True to dispatch ``req`` (possibly degraded)."""
        deadline = self._deadline_s(req)
        if self.admission == "none" or deadline is None:
            return True
        predicted = self._predicted_completion(replica, req)
        if predicted is None or predicted <= deadline:
            return True
        if self.admission == "degrade" and req.max_new_tokens > 1:
            # a shorter answer is a shorter service: retest at half length
            half = max(1, req.max_new_tokens // 2)
            scaled = self._predicted_completion(replica, req, max_new=half)
            if scaled is not None and scaled <= deadline:
                req.max_new_tokens = half
                req.degraded = True
                return True
        req.rejected = True
        req.finished_at = now
        self.rejected.append(req)
        return False

    def _dispatch(self, req: Request, now: float) -> None:
        replica = min(range(self.active),
                      key=lambda r: self.scheds[r].busy + self.scheds[r].queued())
        if self._admit_or_shed(req, replica, now):
            self.scheds[replica].submit(req)

    # ------------------------------------------------------------------ #
    # elasticity
    # ------------------------------------------------------------------ #
    def _autoscale(self, now: float) -> None:
        if self.autoscaler is None:
            return
        queued = sum(s.queued() for s in self.scheds[: self.active])
        busy = sum(s.busy for s in self.scheds[: self.active])
        target = self.autoscaler.tick(queued, busy, self.active, now)
        if target > self.active:
            self.active = target       # fresh lane groups join instantly
        elif target < self.active:
            # drain from the top: deactivate the highest lane group only
            # once it is idle, so the active span stays a contiguous prefix
            while (self.active > target
                   and not self.scheds[self.active - 1].has_work()):
                self.active -= 1

    def _wave_bucket(self, n: int) -> int:
        """Batch pad target for an n-request prefill wave: the next power
        of two, capped at the fleet width — a ladder small enough for
        ``warmup`` to precompile every shape the serving loop will ever
        request, tight enough that a 3-request backfill on a 256-lane
        fleet pays a 4-row prefill, not a 256-row one."""
        return min(_next_pow2(max(n, 1)), _next_pow2(self.engine.batch))

    # ------------------------------------------------------------------ #
    # fused span decode
    # ------------------------------------------------------------------ #
    def _step_for_span(self, span: int):
        fn = self._span_step.get(span)
        if fn is None:
            model = self.engine.model

            def step(params, toks, pos, cache):
                sub = jax.tree.map(lambda c: c[:, :span], cache)
                logits, sub = model.decode_step(params, toks, pos, sub)
                cache = jax.tree.map(
                    lambda full, s: full.at[:, :span].set(s), cache, sub)
                # greedy argmax inside the jit: one fused program per
                # step, and only span int32s cross back to the host
                return (jnp.argmax(logits[:, -1], axis=-1)
                        .astype(jnp.int32), cache)

            # donate the cache: without it every step materializes a fresh
            # full-fleet KV cache for the ``.at[:, :span].set`` writeback —
            # at 64+ lanes that copy dominates the decode itself
            fn = self._span_step[span] = jax.jit(step, donate_argnums=(3,))
        return fn

    # ------------------------------------------------------------------ #
    # serving loop
    # ------------------------------------------------------------------ #
    def run(self, requests: List[Request], now_fn=None) -> List[Request]:
        """Serve ``requests`` across the fleet.  Same contract as
        ``ServeEngine.run``: greedy decode, tokens appended in place,
        ``now_fn`` drives arrival release and latency stamps (default
        frozen 0 clock for deterministic tests)."""
        now = now_fn or _now_zero
        if now is _now_zero and any(r.arrival > 0 for r in requests):
            raise ValueError("requests with a future arrival need an "
                             "advancing clock: pass now_fn="
                             "time.perf_counter (or rebase arrivals to 0)")
        pending = sorted(requests, key=lambda r: (r.arrival, id(r)))
        pending.reverse()              # pop() from the arrival-ordered tail

        eng, spr = self.engine, self.spr
        B = eng.batch
        cache = eng.init_shared_cache()
        toks = np.zeros(B, np.int32)
        pos = np.zeros(B, np.int32)

        def has_work():
            return bool(pending) or any(s.has_work() for s in self.scheds)

        while has_work():
            t = now()
            # 1. release arrivals → admission → dispatch
            while pending and pending[-1].arrival <= t:
                self._dispatch(pending.pop(), t)
            # 2. autoscale on observed demand
            self._autoscale(t)
            # 3. per-replica slot admission, one fleet-wide prefill wave
            admits = []
            for r in range(self.active):
                for slot, req in self.scheds[r].admit(t):
                    admits.append((r * spr + slot, req))
            if admits:
                cache, first = eng._prefill_into(
                    cache, admits, wave_pad=self._wave_bucket(len(admits)))
                for (lane, req), tok in zip(admits, first):
                    toks[lane] = tok
                    pos[lane] = len(req.prompt)
            if not any(s.busy for s in self.scheds[: self.active]):
                if pending:
                    nxt = pending[-1].arrival
                    time.sleep(min(max(nxt - now(), 0.0), 0.001))
                continue
            # 4. emit pending tokens, retire finished slots
            t = now()
            for r in range(self.active):
                sched = self.scheds[r]
                for slot in range(spr):
                    req = sched.slots[slot]
                    if req is None:
                        continue
                    lane = r * spr + slot
                    if req.max_new_tokens == 0:
                        self._retire(sched, slot, t)
                        continue
                    req.out_tokens.append(int(toks[lane]))
                    if len(req.out_tokens) >= req.max_new_tokens or (
                            req.eos_id is not None
                            and toks[lane] == req.eos_id):
                        self._retire(sched, slot, t)
            busy = sum(s.busy for s in self.scheds[: self.active])
            if busy == 0:
                continue
            # 5. ONE fused decode step over the active lane span
            span = self.active * spr
            nxt, cache = self._step_for_span(span)(
                eng.params, jnp.asarray(toks[:span, None], jnp.int32),
                jnp.asarray(pos[:span], jnp.int32), cache)
            step_toks = np.asarray(nxt, np.int32)
            for r in range(self.active):
                sched = self.scheds[r]
                for slot in range(spr):
                    if sched.slots[slot] is not None:
                        lane = r * spr + slot
                        toks[lane] = step_toks[lane]
                        pos[lane] += 1
        return requests

    def _retire(self, sched: SlotScheduler, slot: int, t: float) -> None:
        req = sched.retire(slot, t)
        if req.admitted_at is not None and req.finished_at is not None:
            s = (req.finished_at - req.admitted_at) / max(
                1, len(req.out_tokens))
            self._ema_tok = s if self._ema_tok is None else (
                self._ema_beta * self._ema_tok + (1 - self._ema_beta) * s)
            self._completions += 1

    # ------------------------------------------------------------------ #
    # warmup & reporting
    # ------------------------------------------------------------------ #
    def warmup(self, prompt_lens: Sequence[int] = (), pad_to: int = 8,
               spans: Optional[Sequence[int]] = None) -> None:
        """Compile the prefill wave buckets and the span decode steps so a
        fixed fleet never compiles mid-stream.  ``spans`` defaults to the
        fixed-fleet span only; pass explicit replica counts (e.g.
        ``range(1, max_replicas + 1)``) when autoscaling."""
        self.engine.warmup(prompt_lens, pad_to=pad_to)
        lens = sorted(set(int(n) for n in prompt_lens))
        cache = self.engine.init_shared_cache()
        if lens and self.engine.ragged_ok:
            buckets = sorted(set(
                min(-(-n // pad_to) * pad_to, self.engine.max_seq)
                for n in lens))
            top = _next_pow2(self.engine.batch)
            waves = [w for w in
                     (1 << i for i in range(top.bit_length()))
                     if w <= top]
            for b in buckets:
                for w in waves:
                    admits = [(s, Request(
                        prompt=np.zeros(min(b, self.engine.max_seq - 1),
                                        np.int32), max_new_tokens=1))
                        for s in range(min(w, self.engine.batch))]
                    cache, _ = self.engine._prefill_into(
                        cache, admits, pad_to=pad_to, wave_pad=w)
        for n_active in (spans if spans is not None else [self.active]):
            span = int(n_active) * self.spr
            fn = self._step_for_span(span)
            # the step donates its cache argument — rebind so the next
            # span (or caller) never touches the consumed buffer
            nxt, cache = fn(self.engine.params,
                            jnp.zeros((span, 1), jnp.int32),
                            jnp.zeros(span, jnp.int32), cache)
            jax.block_until_ready(nxt)
        if self.engine.prefix_cache is not None:
            # drop the warm-probe blocks the wave loop above inserted
            self.engine.prefix_cache.reset()

    def report(self) -> dict:
        """Fleet rollup: per-replica scheduler reports, fleet-wide latency
        percentiles, per-tenant outcomes over the FULL stream (finished +
        rejected — rejections count against SLO attainment), and the
        autoscaler's event log."""
        finished = [r for s in self.scheds for r in s._finished]
        totals = [r.finished_at - r.arrival for r in finished
                  if r.finished_at is not None]
        return {
            "replicas": self.max_replicas,
            "active": self.active,
            "slots_per_replica": self.spr,
            "finished": len(finished),
            "rejected": len(self.rejected),
            "degraded": sum(1 for r in finished if r.degraded),
            "latency_p50": _pct(totals, 50),
            "latency_p95": _pct(totals, 95),
            "latency_p99": _pct(totals, 99),
            "backfills": sum(s.backfills for s in self.scheds),
            "ema_tok_s": self._ema_tok,
            "prefix_cache": (self.engine.prefix_cache.stats()
                             if self.engine.prefix_cache is not None
                             else None),
            "tenants": tenant_report(finished + self.rejected),
            "autoscaler_events": (list(self.autoscaler.events)
                                  if self.autoscaler else []),
            "per_replica": [s.report() for s in self.scheds],
        }


class PredictorFleet:
    """The classical-model twin: N ``ModelPredictor`` replicas behind
    least-loaded dispatch.  Each replica keeps its own microbatch queue;
    ``flush_all`` drains every replica and merges the stats."""

    def __init__(self, predictors: Sequence):
        if not predictors:
            raise ValueError("need at least one predictor")
        self.replicas = list(predictors)

    def submit(self, request) -> int:
        """Enqueue on the least-loaded replica; returns the replica index."""
        idx = min(range(len(self.replicas)),
                  key=lambda i: self.replicas[i].queued)
        self.replicas[idx].submit(request)
        return idx

    def flush_all(self, now: float = 0.0) -> list:
        done = []
        for p in self.replicas:
            done.extend(p.flush(now))
        return done

    @property
    def queued(self) -> int:
        return sum(p.queued for p in self.replicas)

    def report(self) -> dict:
        per = [p.report() for p in self.replicas]
        return {
            "replicas": len(per),
            "rows_served": sum(s.get("rows_served", 0) for s in per),
            "batches": sum(s.get("batches", 0) for s in per),
            "per_replica": per,
        }
