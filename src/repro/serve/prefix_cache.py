"""Fleet-wide radix prefix KV cache: reuse repeated prompt prefixes.

Production traffic re-prefills the same token prefixes (system prompts,
few-shot templates, multi-tenant boilerplate) thousands of times.  This
module turns that redundancy into skipped work — the Petuum principle of
exploiting repeated structure, applied to serving: the host keeps a
**token-block trie** over every prompt the fleet has prefilled, and the
device keeps a **block pool** holding the corresponding KV-cache entries.
At admission the engine asks for the longest cached block-aligned prefix,
copies its K/V blocks into the request's slot lane with ONE jitted
scatter, and runs prefill over the *uncached tail only*
(``TransformerLM.prefill_ragged(start_pos=)``).

Host half (pure Python, no device state — property-tested in
``tests/test_prefix_cache.py``):

  * fixed-size blocks (``block_size`` tokens) keyed by exact content, so
    the radix trie never needs mid-edge splits — a node IS a block;
  * refcounted nodes: ``match`` pins its chain until ``release`` so an
    eviction can never free a block mid-restore;
  * LRU eviction of **unreferenced leaves** only (an interior node is by
    construction older than its children and still reachable through
    them), bounded by ``capacity_blocks``;
  * hit/miss/evict statistics for the launcher's RESULT:: report.

Device half (bound to a model via :meth:`bind`):

  * the pool is one pytree shaped like the model's KV cache with
    ``(periods, capacity, block_size, …)`` leaves;
  * ``restore_into`` scatters any number of (lane, block) pairs into a
    wave's sub-cache in one jitted call; ``extract_from`` gathers freshly
    prefilled blocks back into the pool in one jitted call.  Both pad
    their block list to a power-of-two bucket so compiled shapes form a
    small ladder.

Ring-buffer correctness: sliding-window / chunked-attention layers keep
only ``window``/``attn_chunk`` cache slots, so a block extracted from a
prompt of length E holds garbage at positions < E - ring for those
layers.  Each node records ``valid_end`` (the E of the extract that wrote
it; re-extracts from shorter prompts shrink it — the prefix property
guarantees equal content where both are valid) and ``match`` truncates to
the longest prefix whose *needed* positions — the last ``ring`` of each
ring size — avoid every block's garbage region.  Global-attention layers
(ring = max_seq) never truncate.

Exactness: restored blocks are the bits a full prefill wrote (extracted
after that prefill, re-scattered verbatim), so greedy streams are
bit-identical cache-on vs cache-off — the invariant
``tests/test_serve_prefix.py`` pins across ragged, windowed, and
weight-quantized (int8/bf16) paths.  ``cache_dtype="int8"`` (quantized
KV *storage*) is the one exclusion: prefill attends raw K/V while the
cache stores quantized, so a restored prefix would be attended
dequantized — ``ServeEngine`` refuses the combination.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["RadixPrefixCache", "PrefixMatch"]


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


class _Node:
    """One cached block: a trie edge labelled by ``block_size`` tokens."""

    __slots__ = ("key", "parent", "children", "block_id", "start",
                 "valid_end", "refs", "last_used")

    def __init__(self, key: bytes, parent: Optional["_Node"], block_id: int,
                 start: int, valid_end: int):
        self.key = key
        self.parent = parent
        self.children: Dict[bytes, "_Node"] = {}
        self.block_id = block_id
        self.start = start              # absolute token offset of the block
        self.valid_end = valid_end      # prompt length at pool-write time
        self.refs = 0
        self.last_used = 0


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """A pinned match: ``length`` tokens over ``nodes`` (one per block).
    Hold it across the restore, then :meth:`RadixPrefixCache.release` it."""

    length: int
    nodes: Tuple[_Node, ...]

    @property
    def block_ids(self) -> Tuple[int, ...]:
        return tuple(n.block_id for n in self.nodes)


class RadixPrefixCache:
    """Token-block trie + device KV block pool (see module docstring).

    The host trie works standalone (``match``/``plan_insert``/``release``
    need no device state); :meth:`bind` attaches the pool and the jitted
    restore/extract for a concrete model.  One instance is shared by a
    whole :class:`~repro.serve.router.ReplicaRouter` fleet — its replicas
    are lane groups on one engine, so sharing is free.
    """

    def __init__(self, block_size: int = 16, capacity_blocks: int = 256,
                 ring_sizes: Sequence[int] = ()):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        if capacity_blocks < 1:
            raise ValueError("capacity_blocks must be >= 1")
        self.block_size = int(block_size)
        self.capacity = int(capacity_blocks)
        # ring_sizes is normally set by bind(); the ctor knob exists so the
        # host-only property tests can exercise ring-validity truncation
        self._ring_sizes: Tuple[int, ...] = tuple(sorted(set(ring_sizes)))
        self._root = _Node(b"", None, -1, -self.block_size, 0)
        self._registry: set = set()     # all live nodes (eviction scan)
        self._free: List[int] = list(range(self.capacity - 1, -1, -1))
        self._clock = 0
        # device half (None until bind)
        self._pool = None
        self._max_seq: Optional[int] = None
        self._restore_jit = None
        self._extract_jit = None
        self._reset_stats()

    def _reset_stats(self) -> None:
        self.requests = 0
        self.hits = 0
        self.misses = 0
        self.cached_tokens = 0          # prefill tokens served from the pool
        self.prompt_tokens = 0          # total prefill tokens requested
        self.evictions = 0
        self.inserted_blocks = 0

    # ------------------------------------------------------------------ #
    # host trie
    # ------------------------------------------------------------------ #
    @property
    def blocks(self) -> int:
        """Live blocks in the trie (≤ capacity — property-tested)."""
        return len(self._registry)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _keys(self, tokens: np.ndarray, nblocks: int) -> List[bytes]:
        bs = self.block_size
        return [tokens[d * bs:(d + 1) * bs].tobytes() for d in range(nblocks)]

    def _walk(self, tokens: np.ndarray, max_blocks: int) -> List[_Node]:
        chain: List[_Node] = []
        node = self._root
        for key in self._keys(tokens, max_blocks):
            child = node.children.get(key)
            if child is None:
                break
            chain.append(child)
            node = child
        return chain

    def _usable_blocks(self, chain: List[_Node], cap: int) -> int:
        """Longest usable prefix (in blocks): every ring size must find its
        needed positions — the last ``ring`` before the match end — outside
        each block's garbage region (positions < valid_end - ring)."""
        bs = self.block_size
        m = min(len(chain), cap)
        while m > 0:
            L = m * bs
            ok = True
            for ring in self._ring_sizes:
                lo = max(0, L - ring)           # needed: positions [lo, L)
                for d in range(lo // bs, m):
                    garbage_end = chain[d].valid_end - ring
                    if max(lo, chain[d].start) < min(L, garbage_end):
                        ok = False
                        break
                if not ok:
                    break
            if ok:
                return m
            m -= 1
        return 0

    def match(self, tokens: np.ndarray) -> PrefixMatch:
        """Longest usable cached block-aligned prefix of ``tokens``,
        **pinned** (refcounts incremented) until :meth:`release`.  Always
        leaves ≥ 1 uncached tail token so the tail prefill can produce the
        first greedy logits.  Updates hit/miss stats and LRU clocks."""
        toks = np.ascontiguousarray(tokens, np.int32)
        cap = max(0, (len(toks) - 1) // self.block_size)
        chain = self._walk(toks, cap)
        m = self._usable_blocks(chain, cap)
        chain = chain[:m]
        t = self._tick()
        for node in chain:
            node.refs += 1
            node.last_used = t
        length = m * self.block_size
        self.requests += 1
        if length > 0:
            self.hits += 1
        else:
            self.misses += 1
        self.cached_tokens += length
        self.prompt_tokens += len(toks)
        return PrefixMatch(length=length, nodes=tuple(chain))

    def release(self, match: PrefixMatch) -> None:
        """Unpin a match's chain (refcounts back down, never below 0)."""
        for node in match.nodes:
            if node.refs <= 0:
                raise RuntimeError("release without matching pin")
            node.refs -= 1

    def peek(self, tokens: np.ndarray) -> int:
        """Match length (tokens) WITHOUT pinning, stats, or LRU touches —
        the router's SLO predictor uses this to estimate tail-prefill
        length before dispatch."""
        toks = np.ascontiguousarray(tokens, np.int32)
        cap = max(0, (len(toks) - 1) // self.block_size)
        chain = self._walk(toks, cap)
        return self._usable_blocks(chain, cap) * self.block_size

    def plan_insert(self, tokens: np.ndarray) -> List[Tuple[int, int]]:
        """Record every full block of ``tokens`` in the trie; returns the
        ``(block_id, start)`` writes whose pool data the caller must fill
        (via :meth:`extract_from`) **before the next match** — new blocks,
        plus existing blocks whose ``valid_end`` shrinks (a shorter prompt
        strictly improves ring validity; content is equal where both are
        valid by the prefix property).  Allocation evicts LRU unreferenced
        leaves when full and stops planning when nothing is evictable."""
        toks = np.ascontiguousarray(tokens, np.int32)
        end = len(toks)
        bs = self.block_size
        writes: List[Tuple[int, int]] = []
        pinned: List[_Node] = []
        node = self._root
        t = self._tick()
        try:
            for d, key in enumerate(self._keys(toks, end // bs)):
                child = node.children.get(key)
                if child is None:
                    bid = self._alloc()
                    if bid is None:
                        break           # full of pinned/interior blocks
                    child = _Node(key, node, bid, d * bs, end)
                    node.children[key] = child
                    self._registry.add(child)
                    self.inserted_blocks += 1
                    writes.append((bid, d * bs))
                elif end < child.valid_end:
                    child.valid_end = end
                    writes.append((child.block_id, d * bs))
                child.last_used = t
                # pin the path so allocating block d+1 can never evict the
                # freshly inserted (still-leaf) block d
                child.refs += 1
                pinned.append(child)
                node = child
        finally:
            for n in pinned:
                n.refs -= 1
        return writes

    def _alloc(self) -> Optional[int]:
        if self._free:
            return self._free.pop()
        victims = [n for n in self._registry
                   if not n.children and n.refs == 0]
        if not victims:
            return None
        victim = min(victims, key=lambda n: n.last_used)
        del victim.parent.children[victim.key]
        self._registry.discard(victim)
        self.evictions += 1
        return victim.block_id

    def reset(self) -> None:
        """Drop every cached block and zero the stats; the device pool and
        its compiled restore/extract survive (warmup calls this so compile
        probes never pollute the live trie)."""
        self._root = _Node(b"", None, -1, -self.block_size, 0)
        self._registry.clear()
        self._free = list(range(self.capacity - 1, -1, -1))
        self._clock = 0
        self._reset_stats()

    def stats(self) -> dict:
        return {
            "requests": self.requests,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": (self.cached_tokens / self.prompt_tokens
                         if self.prompt_tokens else 0.0),
            "cached_tokens": self.cached_tokens,
            "prompt_tokens": self.prompt_tokens,
            "evictions": self.evictions,
            "inserted_blocks": self.inserted_blocks,
            "blocks": self.blocks,
            "capacity_blocks": self.capacity,
            "block_size": self.block_size,
        }

    # ------------------------------------------------------------------ #
    # device pool
    # ------------------------------------------------------------------ #
    def bind(self, model, max_seq: int) -> None:
        """Attach the block pool for ``model``'s cache layout.  Idempotent
        for a matching layout; a second engine with a different cache
        shape (other arch / max_seq) is refused — one pool, one layout."""
        import jax
        import jax.numpy as jnp

        template = model.init_cache(1, max_seq)
        shapes = tuple((leaf.shape[0], leaf.shape[2]) + tuple(leaf.shape[3:])
                       for leaf in jax.tree.leaves(template))
        if self._pool is not None:
            if shapes != self._bound_shapes or int(max_seq) != self._max_seq:
                raise ValueError(
                    "prefix cache already bound to a different cache layout "
                    "— one RadixPrefixCache serves one model/max_seq")
            return
        self._bound_shapes = shapes
        self._max_seq = int(max_seq)
        self._ring_sizes = tuple(sorted(
            {int(leaf.shape[2]) for leaf in jax.tree.leaves(template)}))
        cap, bs = self.capacity, self.block_size
        self._pool = jax.tree.map(
            lambda leaf: jnp.zeros((leaf.shape[0], cap, bs)
                                   + tuple(leaf.shape[3:]), leaf.dtype),
            template)
        self._restore_jit = jax.jit(self._restore_impl)
        self._extract_jit = jax.jit(self._extract_impl)

    @property
    def bound(self) -> bool:
        return self._pool is not None

    def _restore_impl(self, cache, pool, lanes, ids, starts, match_lens):
        import jax
        import jax.numpy as jnp

        bs = self.block_size
        pos = starts[:, None] + jnp.arange(bs, dtype=jnp.int32)[None, :]

        def leaf(c, p):
            ring = c.shape[2]
            vals = p[:, ids]                       # (periods, nb, bs, …)
            # a ring keeps only the last ``ring`` positions before the
            # match end; everything else (and padding, match_len=0) routes
            # out of bounds and is dropped
            keep = (pos < match_lens[:, None]) & (pos >= match_lens[:, None]
                                                  - ring)
            dest = jnp.where(keep, pos % ring, ring)
            return c.at[:, lanes[:, None], dest].set(vals, mode="drop")

        return jax.tree.map(leaf, cache, pool)

    def _extract_impl(self, cache, pool, lanes, ids, starts):
        import jax
        import jax.numpy as jnp

        bs = self.block_size
        pos = starts[:, None] + jnp.arange(bs, dtype=jnp.int32)[None, :]

        def leaf(c, p):
            ring = c.shape[2]
            vals = c[:, lanes[:, None], pos % ring]  # (periods, nb, bs, …)
            # padding carries id == capacity → dropped
            return p.at[:, ids].set(vals, mode="drop")

        return jax.tree.map(leaf, cache, pool)

    def _pad(self, entries: List[Tuple[int, ...]], pad_id: int):
        import jax.numpy as jnp

        nb = _next_pow2(len(entries))
        cols = [np.zeros(nb, np.int32) for _ in range(4)]
        cols[1][:] = pad_id
        for i, e in enumerate(entries):
            for c, v in zip(cols, e):
                c[i] = v
        return [jnp.asarray(c) for c in cols]

    def restore_into(self, cache, entries: List[Tuple[int, int, int, int]]):
        """Scatter cached blocks into a wave sub-cache in ONE jitted call.
        ``entries``: (lane, block_id, start, match_len) per block — the
        match_len of the owning request bounds each ring's keep window.
        Returns the updated cache (input is not donated)."""
        if not entries:
            return cache
        if self._pool is None:
            raise RuntimeError("restore_into before bind()")
        lanes, ids, starts, lens = self._pad(
            [(e[0], e[1], e[2], e[3]) for e in entries], pad_id=0)
        # padded rows carry match_len 0 → every position OOB-dropped
        return self._restore_jit(cache, self._pool, lanes, ids, starts, lens)

    def extract_from(self, cache, entries: List[Tuple[int, int, int]]) -> None:
        """Gather freshly prefilled blocks out of a wave sub-cache into the
        pool in ONE jitted call.  ``entries``: (lane, block_id, start)."""
        if not entries:
            return
        if self._pool is None:
            raise RuntimeError("extract_from before bind()")
        lanes, ids, starts, _ = self._pad(
            [(e[0], e[1], e[2], 0) for e in entries], pad_id=self.capacity)
        self._pool = self._extract_jit(cache, self._pool, lanes, ids, starts)
