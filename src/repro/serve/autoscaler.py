"""Queue-depth-driven replica autoscaling.

The policy half of fleet elasticity (the mechanism — activating and
draining lane groups on the shared cache — lives in
:class:`repro.serve.router.ReplicaRouter`).  The shape of the policy
follows ``tune.AshaScheduler``'s slot backfilling: capacity chases demand
*eagerly upward* (a queue that outruns the active slots gets every replica
it needs in one tick, exactly like ASHA backfilling freed trial slots
from the promotion queue), but *reluctantly downward* — scale-down
requires ``hysteresis`` consecutive low-demand ticks, because dropping a
replica costs a drain and a likely re-spin when the next burst lands.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

__all__ = ["QueueAutoscaler"]


@dataclasses.dataclass
class QueueAutoscaler:
    """Maps observed demand to a target replica count.

    ``tick(queued, busy, active, now)`` returns the new target in
    ``[min_replicas, max_replicas]``:

      * **up** (immediate): while ``queued`` exceeds ``up_threshold`` ×
        the free slot capacity of the target fleet, add replicas — a
        single deep-queue tick can spin the whole fleet.
      * **down** (hysteresis): when total demand (busy + queued) fits in
        ``down_threshold`` × the capacity of one-fewer replicas for
        ``hysteresis`` consecutive ticks, drop one replica and restart
        the count.  Any non-low tick resets the streak.
    """

    slots_per_replica: int
    min_replicas: int = 1
    max_replicas: int = 4
    up_threshold: float = 1.0      # queued > thr × free capacity → grow
    down_threshold: float = 0.5    # demand ≤ thr × shrunk capacity → streak
    hysteresis: int = 3            # consecutive low ticks before shrinking
    events: List[Tuple[float, str, int]] = dataclasses.field(
        default_factory=list)      # (now, "up"|"down", new_target)
    _low_streak: int = 0

    def __post_init__(self):
        if not (1 <= self.min_replicas <= self.max_replicas):
            raise ValueError(
                f"need 1 <= min {self.min_replicas} <= max {self.max_replicas}")
        if self.slots_per_replica < 1:
            raise ValueError("slots_per_replica must be >= 1")
        if self.hysteresis < 1:
            raise ValueError("hysteresis must be >= 1")

    def tick(self, queued: int, busy: int, active: int, now: float = 0.0) -> int:
        target = max(self.min_replicas, min(active, self.max_replicas))
        spr = self.slots_per_replica

        grew = False
        while (target < self.max_replicas
               and queued > self.up_threshold * max(target * spr - busy, 0)):
            target += 1
            grew = True
        if grew:
            self._low_streak = 0
            self.events.append((now, "up", target))
            return target

        demand = busy + queued
        if (target > self.min_replicas
                and demand <= self.down_threshold * (target - 1) * spr):
            self._low_streak += 1
            if self._low_streak >= self.hysteresis:
                target -= 1
                self._low_streak = 0
                self.events.append((now, "down", target))
        else:
            self._low_streak = 0
        return target
