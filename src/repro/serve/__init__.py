from repro.serve.step import make_prefill_step, make_decode_step, cache_axes
from repro.serve.engine import ServeEngine

__all__ = ["make_prefill_step", "make_decode_step", "cache_axes", "ServeEngine"]
