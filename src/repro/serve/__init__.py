from repro.serve.step import make_prefill_step, make_decode_step, cache_axes
from repro.serve.scheduler import Request, SlotScheduler
from repro.serve.engine import ServeEngine
from repro.serve.predictor import ModelPredictor, PredictRequest

__all__ = ["make_prefill_step", "make_decode_step", "cache_axes",
           "Request", "SlotScheduler", "ServeEngine",
           "ModelPredictor", "PredictRequest"]
