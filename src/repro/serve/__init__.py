from repro.serve.step import make_prefill_step, make_decode_step, cache_axes
from repro.serve.scheduler import (Request, FairQueue, SlotScheduler,
                                   tenant_report)
from repro.serve.prefix_cache import RadixPrefixCache, PrefixMatch
from repro.serve.engine import ServeEngine
from repro.serve.predictor import ModelPredictor, PredictRequest
from repro.serve.autoscaler import QueueAutoscaler
from repro.serve.router import ReplicaRouter, PredictorFleet

__all__ = ["make_prefill_step", "make_decode_step", "cache_axes",
           "Request", "FairQueue", "SlotScheduler", "tenant_report",
           "RadixPrefixCache", "PrefixMatch",
           "ServeEngine", "ModelPredictor", "PredictRequest",
           "QueueAutoscaler", "ReplicaRouter", "PredictorFleet"]
