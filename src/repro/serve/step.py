"""Serving steps: prefill and single-token decode with sharded KV caches.

Cache sharding (via the same logical-rule machinery as params):
  * batched decode  — cache batch dim on ("pod","data"), heads on "model"
    when divisible.
  * long-context batch-1 decode — batch mapping drops (1 % devices), freeing
    the "data" axis for the *sequence* dim of the cache: context-parallel
    decode.  XLA partitions the softmax reduction over the sharded key axis
    (the flash-decode pattern, expressed declaratively).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ATTENTION_KINDS, ArchConfig, BlockKind
from repro.models.transformer import TransformerLM
from repro.sharding.rules import DEFAULT_RULES, ShardingRules, logical_to_spec

__all__ = ["cache_axes", "make_prefill_step", "make_decode_step"]

_ATTN_KV_AXES = ("batch", "kv_seq", "kv_heads", "head")
_ATTN_SCALE_AXES = ("batch", "kv_seq", "kv_heads")


def _block_cache_axes(cfg: ArchConfig, kind: BlockKind, int8: bool) -> Dict[str, Any]:
    c: Dict[str, Any] = {}
    if kind in ATTENTION_KINDS:
        attn = {"k": _ATTN_KV_AXES, "v": _ATTN_KV_AXES}
        if int8:
            attn["k_scale"] = _ATTN_SCALE_AXES
            attn["v_scale"] = _ATTN_SCALE_AXES
        c["attn"] = attn
        if cfg.cross_attention:
            c["cross"] = {"k": ("batch", None, "kv_heads", "head"),
                          "v": ("batch", None, "kv_heads", "head")}
    elif kind == BlockKind.RGLRU:
        c["rglru"] = {"h": ("batch", "rnn"), "conv": ("batch", None, "rnn")}
    elif kind == BlockKind.SSD:
        c["ssd"] = {"h": ("batch", "ssd_heads", None, None),
                    "conv": ("batch", None, "rnn")}
    return c


def cache_axes(cfg: ArchConfig) -> Dict[str, Any]:
    """Logical-axis tree matching TransformerLM.init_cache structure, with
    the leading stacked-periods axis."""
    int8 = cfg.cache_dtype == "int8"
    per = {f"b{i}": _block_cache_axes(cfg, kind, int8)
           for i, kind in enumerate(cfg.pattern)}

    def prepend(ax):
        return (None,) + tuple(ax)

    return jax.tree.map(prepend, per,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))


def make_prefill_step(cfg: ArchConfig) -> Callable:
    model = TransformerLM(cfg)

    def prefill(params, tokens, cache, vision_embeds=None, encoder_frames=None):
        return model.prefill(params, tokens, cache,
                             vision_embeds=vision_embeds,
                             encoder_frames=encoder_frames)

    return prefill


def make_decode_step(cfg: ArchConfig) -> Callable:
    model = TransformerLM(cfg)

    def decode(params, token, pos, cache):
        return model.decode_step(params, token, pos, cache)

    return decode
