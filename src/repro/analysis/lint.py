"""Repo-invariant AST lint: `python -m repro.analysis lint src/`.

Five rules over plain Python source (no imports executed):

* ``traced-leak`` — ``float()`` / ``bool()`` / ``.item()`` /
  ``np.asarray()`` / ``jax.device_get()`` on values inside a traced
  region (a function that is jitted, shard_mapped, vmapped, or passed to
  ``lax.scan``/``fori_loop``/``while_loop``/``cond``): each forces a
  concrete value out of the tracer — either a TracerConversionError at
  runtime or, worse, a silent device→host sync per step.
* ``wallclock-in-trace`` — ``time.time()`` / ``perf_counter()`` /
  ``datetime.now()`` / ``np.random.*`` / ``random.*`` inside a traced
  region: the value is baked in at trace time, so the code reads like it
  samples per step but doesn't (and defeats determinism contracts).
* ``donated-reuse`` — a variable passed at a ``donate_argnums`` position
  of a locally-jitted function and *read again* afterwards without
  rebinding: the buffer may already be aliased/invalidated.
* ``non-atomic-write`` — inside store directories (``checkpoint/``,
  ``core/exchange.py``): ``open(path, "w"/"wb"/"a")``, ``np.save``,
  ``np.savez``, ``json.dump`` targeting anything that is not a temp
  file.  Durable state must go tmp → fsync → ``os.replace`` or a
  concurrent reader sees a torn file — the race class PR 7 patched
  reactively in ``read_at_most``.
* ``jit-in-loop`` — ``jax.jit(...)`` constructed inside a ``for``/
  ``while`` body: a fresh jit wrapper has a fresh cache, so the loop
  recompiles every iteration.

Allowlist: append ``# lint: allow[rule-id] <one-line justification>`` on
the flagged line (or the line above) to suppress a finding.  The
justification is mandatory by convention and reviewed like code.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .finding import Finding

__all__ = ["lint_paths", "lint_file", "lint_source", "RULES"]

RULES = (
    "traced-leak",
    "wallclock-in-trace",
    "donated-reuse",
    "non-atomic-write",
    "jit-in-loop",
)

# Files whose writes must be atomic (tmp -> fsync -> os.replace).  Matched
# as substrings of the normalized relative path.
STORE_PATH_MARKERS = ("checkpoint/", "core/exchange.py")

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\[([a-z0-9\-,\s]+)\]")

# Entry points that trace the callable handed to them.
_TRACING_CALLEES = {
    "jit", "shard_map", "vmap", "pmap", "scan", "fori_loop",
    "while_loop", "cond", "switch", "checkpoint", "remat", "grad",
    "value_and_grad", "custom_vjp", "custom_jvp", "make_jaxpr",
}

_TRACED_LEAK_CALLS = {"float", "bool"}  # int() is legit on static shapes
_TRACED_LEAK_ATTRS = {"item", "tolist", "block_until_ready"}
_TRACED_LEAK_QUALIFIED = {
    ("np", "asarray"), ("numpy", "asarray"),
    ("np", "array"), ("numpy", "array"),
    ("jax", "device_get"),
}

_WALLCLOCK_QUALIFIED = {
    ("time", "time"), ("time", "monotonic"), ("time", "perf_counter"),
    ("time", "process_time"), ("time", "time_ns"),
    ("datetime", "now"), ("datetime", "utcnow"),
}
_WALLCLOCK_MODULES = {"random"}          # random.random(), random.randint…
_WALLCLOCK_NP_RANDOM = True              # np.random.* inside trace


@dataclasses.dataclass
class _Ctx:
    path: str            # display path (as passed by the caller)
    tree: ast.AST
    lines: Sequence[str]
    allows: Dict[int, Set[str]]
    findings: List[Finding]

    def flag(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        allowed = self.allows.get(line, set()) | self.allows.get(line - 1, set())
        if rule in allowed or "*" in allowed:
            return
        self.findings.append(Finding(rule, f"{self.path}:{line}", message))


def _parse_allows(lines: Sequence[str]) -> Dict[int, Set[str]]:
    allows: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = _ALLOW_RE.search(line)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            allows[i] = rules
    return allows


# ---------------------------------------------------------------------------
# traced-region discovery
# ---------------------------------------------------------------------------

def _callee_name(func: ast.AST) -> Optional[str]:
    """Terminal name of a call target: jax.jit -> 'jit', jit -> 'jit'."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _is_tracing_call(call: ast.Call) -> bool:
    name = _callee_name(call.func)
    if name in _TRACING_CALLEES:
        return True
    # functools.partial(jax.jit, ...) used as a decorator factory
    if name == "partial" and call.args:
        inner = _callee_name(call.args[0])
        return inner in _TRACING_CALLEES
    return False


def _decorated_traced(node: ast.AST) -> bool:
    for dec in getattr(node, "decorator_list", []):
        if isinstance(dec, ast.Call) and _is_tracing_call(dec):
            return True
        if _callee_name(dec) in _TRACING_CALLEES:
            return True
    return False


class _TracedRegions(ast.NodeVisitor):
    """Collect (start, end) line spans of functions that jax traces.

    A function is traced if it is decorated with a tracing transform, or
    appears (by name or inline) as an argument to one.  Nested defs
    inherit the region (the tracer doesn't stop at an inner ``def``).
    """

    def __init__(self) -> None:
        self.spans: List[Tuple[int, int]] = []
        self._fn_defs: Dict[str, ast.AST] = {}
        self._traced_names: Set[str] = set()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._fn_defs[node.name] = node
        if _decorated_traced(node):
            self._add(node)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_Call(self, node: ast.Call) -> None:
        if _is_tracing_call(node):
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, (ast.Lambda, ast.Call)):
                    if isinstance(arg, ast.Lambda):
                        self._add(arg)
                elif isinstance(arg, ast.Name):
                    self._traced_names.add(arg.id)
        self.generic_visit(node)

    def _add(self, node: ast.AST) -> None:
        start = getattr(node, "lineno", None)
        end = getattr(node, "end_lineno", None)
        if start is not None and end is not None:
            self.spans.append((start, end))

    def finish(self) -> List[Tuple[int, int]]:
        for name in self._traced_names:
            node = self._fn_defs.get(name)
            if node is not None:
                self._add(node)
        return sorted(set(self.spans))


def _in_spans(line: int, spans: Sequence[Tuple[int, int]]) -> bool:
    return any(start <= line <= end for start, end in spans)


# ---------------------------------------------------------------------------
# rule: traced-leak + wallclock-in-trace (walk calls inside traced spans)
# ---------------------------------------------------------------------------

def _qualified(func: ast.AST) -> Optional[Tuple[str, str]]:
    """('np', 'asarray') for np.asarray; ('datetime','now') for datetime.datetime.now."""
    if isinstance(func, ast.Attribute):
        base = func.value
        if isinstance(base, ast.Name):
            return (base.id, func.attr)
        if isinstance(base, ast.Attribute):
            return (base.attr, func.attr)
    return None


def _check_traced_calls(ctx: _Ctx, spans: Sequence[Tuple[int, int]]) -> None:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        line = getattr(node, "lineno", 0)
        if not _in_spans(line, spans):
            continue

        # --- traced-leak ---------------------------------------------------
        if isinstance(node.func, ast.Name) and node.func.id in _TRACED_LEAK_CALLS:
            if node.args and not isinstance(node.args[0], ast.Constant):
                ctx.flag(
                    "traced-leak", node,
                    f"'{node.func.id}()' on a value inside a traced region "
                    f"forces concretization (host sync or TracerError).")
        qual = _qualified(node.func)
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in _TRACED_LEAK_ATTRS):
            ctx.flag(
                "traced-leak", node,
                f"'.{node.func.attr}()' inside a traced region pulls the "
                f"value to host.")
        elif qual in _TRACED_LEAK_QUALIFIED:
            ctx.flag(
                "traced-leak", node,
                f"'{qual[0]}.{qual[1]}()' inside a traced region is a "
                f"device->host transfer per step.")

        # --- wallclock-in-trace -------------------------------------------
        if qual in _WALLCLOCK_QUALIFIED:
            ctx.flag(
                "wallclock-in-trace", node,
                f"'{qual[0]}.{qual[1]}()' inside a traced region is frozen "
                f"at trace time — it will not advance per step.")
        elif (qual and qual[0] in _WALLCLOCK_MODULES
              and isinstance(node.func, ast.Attribute)
              and isinstance(node.func.value, ast.Name)):
            # bare `random.x()` only: jax.random.x / np.random.x reach here
            # with qual ("random", x) too but are not the stdlib module
            ctx.flag(
                "wallclock-in-trace", node,
                f"'{qual[0]}.{qual[1]}()' (host RNG) inside a traced region "
                f"is sampled once at trace time; use jax.random with a "
                f"threaded key.")
        elif _WALLCLOCK_NP_RANDOM and isinstance(node.func, ast.Attribute):
            base = node.func.value
            if (isinstance(base, ast.Attribute) and base.attr == "random"
                    and isinstance(base.value, ast.Name)
                    and base.value.id in ("np", "numpy")):
                ctx.flag(
                    "wallclock-in-trace", node,
                    f"'np.random.{node.func.attr}()' inside a traced region "
                    f"is sampled once at trace time; use jax.random.")


# ---------------------------------------------------------------------------
# rule: donated-reuse
# ---------------------------------------------------------------------------

def _donated_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """If `call` is jax.jit(..., donate_argnums=...), return the positions."""
    if _callee_name(call.func) != "jit":
        return None
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            value = kw.value
            if isinstance(value, ast.Constant) and isinstance(value.value, int):
                return (value.value,)
            if isinstance(value, (ast.Tuple, ast.List)):
                out = []
                for elt in value.elts:
                    if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                        out.append(elt.value)
                return tuple(out)
    return None


class _DonatedReuse(ast.NodeVisitor):
    """Within each function body, track names jitted with donate_argnums,
    calls through them, and loads of donated arguments after the call."""

    def __init__(self, ctx: _Ctx) -> None:
        self.ctx = ctx

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._scan_scope(node)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_Module(self, node: ast.Module) -> None:
        self._scan_scope(node)
        self.generic_visit(node)

    def _scan_scope(self, scope: ast.AST) -> None:
        donating: Dict[str, Tuple[int, ...]] = {}
        # calls: (call line, donated arg name, rebound names at that stmt)
        events: List[Tuple[int, str]] = []
        rebinds: Dict[str, List[int]] = {}

        body = getattr(scope, "body", [])
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Assign):
                    value = node.value
                    if isinstance(value, ast.Call):
                        pos = _donated_positions(value)
                        if pos is not None:
                            for tgt in node.targets:
                                if isinstance(tgt, ast.Name):
                                    donating[tgt.id] = pos
                    for tgt in node.targets:
                        for name_node in ast.walk(tgt):
                            if isinstance(name_node, ast.Name):
                                rebinds.setdefault(name_node.id, []).append(
                                    node.lineno)
                if isinstance(node, ast.Call):
                    fn_name = (node.func.id
                               if isinstance(node.func, ast.Name) else None)
                    if fn_name in donating:
                        for pos in donating[fn_name]:
                            if pos < len(node.args):
                                arg = node.args[pos]
                                if isinstance(arg, ast.Name):
                                    events.append((node.lineno, arg.id))

        if not events:
            return
        # any Load of a donated name strictly after the donating call,
        # with no rebind in between, is a reuse
        loads: Dict[str, List[Tuple[int, ast.Name]]] = {}
        for node in ast.walk(scope):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                loads.setdefault(node.id, []).append((node.lineno, node))
        for call_line, name in events:
            for load_line, load_node in loads.get(name, []):
                if load_line <= call_line:
                    continue
                rebound = any(call_line <= r <= load_line
                              for r in rebinds.get(name, []))
                if not rebound:
                    self.ctx.flag(
                        "donated-reuse", load_node,
                        f"'{name}' was passed at a donate_argnums position "
                        f"on line {call_line} and read again here: the "
                        f"buffer may already be invalidated.")
                    break  # one finding per (call, name) pair


# ---------------------------------------------------------------------------
# rule: non-atomic-write
# ---------------------------------------------------------------------------

def _expr_text(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:
        return ""


def _is_tmpish(node: ast.AST, tmp_names: Set[str]) -> bool:
    """Heuristic: the write target is a temp path (later os.replace'd)."""
    if isinstance(node, ast.Name) and node.id in tmp_names:
        return True
    text = _expr_text(node).lower()
    return "tmp" in text or "temp" in text


def _write_mode(call: ast.Call) -> Optional[str]:
    """Mode string of an open() call if it writes, else None."""
    mode_node: Optional[ast.AST] = None
    if len(call.args) >= 2:
        mode_node = call.args[1]
    for kw in call.keywords:
        if kw.arg == "mode":
            mode_node = kw.value
    if mode_node is None:
        return None
    if isinstance(mode_node, ast.Constant) and isinstance(mode_node.value, str):
        mode = mode_node.value
        if any(ch in mode for ch in "wax+"):
            return mode
    return None


def _check_atomic_writes(ctx: _Ctx) -> None:
    # names bound by `with open(tmpish, ...) as f:` are themselves tmp-ish
    tmp_names: Set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                call = item.context_expr
                if (isinstance(call, ast.Call)
                        and _callee_name(call.func) == "open"
                        and call.args and _is_tmpish(call.args[0], set())
                        and isinstance(item.optional_vars, ast.Name)):
                    tmp_names.add(item.optional_vars.id)
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            call = node.value
            if (_callee_name(call.func) == "open" and call.args
                    and _is_tmpish(call.args[0], set())):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        tmp_names.add(tgt.id)
        # names assigned from tempfile APIs are tmp-ish
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            qual = _qualified(node.value.func)
            if qual and qual[0] == "tempfile":
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        tmp_names.add(tgt.id)

    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _callee_name(node.func)
        qual = _qualified(node.func)

        if name == "open" and node.args:
            mode = _write_mode(node)
            if mode and not _is_tmpish(node.args[0], tmp_names):
                ctx.flag(
                    "non-atomic-write", node,
                    f"open(..., {mode!r}) writes a durable path in place; "
                    f"write to a '<path>.<pid>.tmp', fsync, then os.replace "
                    f"so readers never see a torn file.")
        elif qual in (("np", "save"), ("numpy", "save"),
                      ("np", "savez"), ("numpy", "savez"),
                      ("np", "savez_compressed"), ("numpy", "savez_compressed")):
            if node.args and not _is_tmpish(node.args[0], tmp_names):
                ctx.flag(
                    "non-atomic-write", node,
                    f"{qual[0]}.{qual[1]} targets a durable path directly; "
                    f"route through an atomic tmp->fsync->os.replace writer.")
        elif qual and qual[1] == "dump" and qual[0] in ("json", "pickle"):
            if len(node.args) >= 2 and not _is_tmpish(node.args[1], tmp_names):
                ctx.flag(
                    "non-atomic-write", node,
                    f"{qual[0]}.dump into a non-temp handle; route through "
                    f"an atomic tmp->fsync->os.replace writer.")


# ---------------------------------------------------------------------------
# rule: jit-in-loop
# ---------------------------------------------------------------------------

class _JitInLoop(ast.NodeVisitor):
    def __init__(self, ctx: _Ctx) -> None:
        self.ctx = ctx
        self._loop_depth = 0

    def _loop(self, node: ast.AST) -> None:
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_For = _loop
    visit_While = _loop
    visit_AsyncFor = _loop

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # a def inside a loop body resets loop context: the jit inside it
        # is constructed at call time, not per loop iteration here
        saved, self._loop_depth = self._loop_depth, 0
        self.generic_visit(node)
        self._loop_depth = saved

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]
    visit_Lambda = visit_FunctionDef            # type: ignore[assignment]

    def visit_Call(self, node: ast.Call) -> None:
        if self._loop_depth > 0 and _callee_name(node.func) == "jit":
            self.ctx.flag(
                "jit-in-loop", node,
                "jax.jit(...) constructed inside a loop body gets a fresh "
                "compile cache each iteration — hoist it (or cache by key).")
        self.generic_visit(node)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _is_store_path(path: str) -> bool:
    normalized = path.replace(os.sep, "/")
    return any(marker in normalized for marker in STORE_PATH_MARKERS)


def lint_source(source: str, path: str = "<string>", *,
                store_rules: Optional[bool] = None) -> List[Finding]:
    """Lint one source text.  ``store_rules`` forces/suppresses the
    atomic-write rule; by default it applies iff ``path`` is a store path."""
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [Finding("syntax-error", f"{path}:{exc.lineno or 0}",
                        f"cannot parse: {exc.msg}")]
    lines = source.splitlines()
    ctx = _Ctx(path=path, tree=tree, lines=lines,
               allows=_parse_allows(lines), findings=[])

    finder = _TracedRegions()
    finder.visit(tree)
    spans = finder.finish()

    _check_traced_calls(ctx, spans)
    _DonatedReuse(ctx).visit(tree)
    _JitInLoop(ctx).visit(tree)
    if store_rules if store_rules is not None else _is_store_path(path):
        _check_atomic_writes(ctx)

    return ctx.findings


def lint_file(path: str, *, store_rules: Optional[bool] = None) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        source = f.read()
    return lint_source(source, path, store_rules=store_rules)


def lint_paths(paths: Iterable[str]) -> List[Finding]:
    """Lint every ``.py`` file under the given files/directories."""
    findings: List[Finding] = []
    for root in paths:
        if os.path.isfile(root):
            findings.extend(lint_file(root))
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fname in sorted(filenames):
                if fname.endswith(".py"):
                    findings.extend(lint_file(os.path.join(dirpath, fname)))
    return findings
