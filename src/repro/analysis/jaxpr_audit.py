"""Jaxpr auditor: walk a closed jaxpr and flag distributed-hot-path hazards.

Four rules, each a named invariant the repo's performance claims rest on:

* ``host-callback`` — callback/transfer primitives (``pure_callback``,
  ``io_callback``, ``debug_callback``, ``device_to_host``…) inside a hot
  path force a device→host sync every step: the hidden-straggler class.
* ``f64-promotion`` — a float64 intermediate in a path we compile for
  f32/bf16 doubles bandwidth and silently disables fast matmul paths.
* ``non-donated-carry`` — a jit we *declared* as donating (epoch/step
  carries) whose large operands are all un-donated doubles peak memory.
* ``collective-axis`` — a collective whose axis name is not in the
  declared mesh-axis set for that path: the op would resolve against
  the wrong (or no) mesh and desync the `CollectiveSchedule` contract.

The walker descends into every sub-jaxpr (pjit, scan, while, cond,
shard_map, custom_* …) so nothing hides behind a nested jit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, FrozenSet, Iterator, List, Optional, Tuple

import numpy as np

from .finding import Finding

__all__ = [
    "AuditSpec",
    "audit_jaxpr",
    "iter_eqns",
    "collective_axis_names",
]

# Primitives that imply a host round-trip or callback. ``name`` match is
# deliberate — primitives are registered by name and stable across the
# jax versions we support.
CALLBACK_PRIMITIVES = frozenset({
    "pure_callback",
    "io_callback",
    "debug_callback",
    "callback",
    "outside_call",        # legacy host_callback
    "host_local_array_to_global_array",
    "device_put",          # explicit placement inside a traced body
    "infeed",
    "outfeed",
})

# Collective primitives that carry a mesh-axis name. NB ``reduce_sum`` /
# ``reduce_max`` etc. are *positional* reductions (their ``axes`` param is
# array dims, not mesh axes) and are deliberately absent.
COLLECTIVE_PRIMITIVES = frozenset({
    "psum",
    "pmin",
    "pmax",
    "pmean",
    "ppermute",
    "pbroadcast",
    "all_gather",
    "all_to_all",
    "psum_scatter",
    "reduce_scatter",
    "axis_index",
})


@dataclasses.dataclass(frozen=True)
class AuditSpec:
    """Expected properties of one hot path's jaxpr.

    Attributes:
      declared_axes: mesh axis names collectives may legally use.  An
        empty set means "this path must use no collectives at all";
        ``None`` disables the collective-axis rule.
      allow_f64: permit float64 intermediates (e.g. a solver path that
        genuinely needs them).
      allow_callbacks: number of callback primitives tolerated (a path
        with a deliberate debug tap can declare it).
      expect_donation: names of inner pjit eqns (``jax.jit``'d function
        names) that must donate at least one large operand.
      large_bytes: threshold above which an operand counts as "large"
        for the donation rule.
    """

    declared_axes: Optional[FrozenSet[str]] = frozenset()
    allow_f64: bool = False
    allow_callbacks: int = 0
    expect_donation: Tuple[str, ...] = ()
    large_bytes: int = 1 << 14


def iter_eqns(jaxpr: Any) -> Iterator[Any]:
    """Yield every eqn in ``jaxpr`` and, recursively, all sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def _sub_jaxprs(params: dict) -> Iterator[Any]:
    """Find nested jaxprs inside an eqn's params (pjit/scan/cond/...)."""
    for value in params.values():
        for sub in _as_jaxprs(value):
            yield sub


def _as_jaxprs(value: Any) -> Iterator[Any]:
    # ClosedJaxpr has .jaxpr; raw Jaxpr has .eqns. Branch params (cond)
    # are tuples of ClosedJaxprs.
    if hasattr(value, "jaxpr"):
        yield value.jaxpr
    elif hasattr(value, "eqns"):
        yield value
    elif isinstance(value, (tuple, list)):
        for item in value:
            yield from _as_jaxprs(item)


def collective_axis_names(eqn: Any) -> Tuple[str, ...]:
    """Extract mesh-axis names used by a collective eqn."""
    names: List[str] = []
    for key in ("axis_name", "axes"):
        value = eqn.params.get(key)
        if value is None:
            continue
        if isinstance(value, str):
            names.append(value)
        elif isinstance(value, (tuple, list)):
            names.extend(v for v in value if isinstance(v, str))
    return tuple(names)


def _aval_bytes(aval: Any) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    try:
        return int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
    except (TypeError, ValueError):  # abstract / polymorphic dims
        return 0


def _is_f64(aval: Any) -> bool:
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and np.dtype(dtype) == np.float64


def audit_jaxpr(closed: Any, spec: AuditSpec, *, where: str) -> List[Finding]:
    """Audit one closed jaxpr against ``spec``; return findings."""
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    findings: List[Finding] = []

    callbacks: List[str] = []
    f64_hits: List[str] = []
    donating_seen: dict = {name: None for name in spec.expect_donation}

    for eqn in iter_eqns(jaxpr):
        prim = eqn.primitive.name

        if prim in CALLBACK_PRIMITIVES:
            # a device_put with no target devices is the tracer staging a
            # host constant (e.g. jnp.asarray of a numpy array) — aliasing,
            # not a transfer; one WITH a target sharding is real placement
            # leaked into the traced body, which we do flag
            devices = eqn.params.get("devices") if prim == "device_put" else None
            if not (prim == "device_put"
                    and devices is not None
                    and all(d is None for d in devices)):
                callbacks.append(prim)

        if not spec.allow_f64:
            for var in eqn.outvars:
                if _is_f64(getattr(var, "aval", None)):
                    f64_hits.append(f"{prim} -> {var.aval.str_short()}")
                    break

        if spec.declared_axes is not None and prim in COLLECTIVE_PRIMITIVES:
            for axis in collective_axis_names(eqn):
                if axis not in spec.declared_axes:
                    declared = sorted(spec.declared_axes) or ["<none>"]
                    findings.append(Finding(
                        "collective-axis", where,
                        f"collective '{prim}' uses axis {axis!r} but this "
                        f"path declares axes {declared}: the op would bind "
                        f"to an undeclared mesh axis."))

        if prim == "pjit" and eqn.params.get("name") in donating_seen:
            donating_seen[eqn.params["name"]] = eqn

    if len(callbacks) > spec.allow_callbacks:
        findings.append(Finding(
            "host-callback", where,
            f"{len(callbacks)} host callback/transfer primitive(s) "
            f"({', '.join(sorted(set(callbacks)))}) in a hot path "
            f"(allowed {spec.allow_callbacks}): each one forces a "
            f"device->host sync per step."))

    if f64_hits:
        findings.append(Finding(
            "f64-promotion", where,
            f"float64 intermediate(s) in an f32/bf16 path, e.g. "
            f"{f64_hits[0]}: doubles bandwidth and disables fast matmul."))

    for name, eqn in donating_seen.items():
        if eqn is None:
            findings.append(Finding(
                "non-donated-carry", where,
                f"expected a donating jit named {name!r} but no such pjit "
                f"eqn exists in this jaxpr."))
            continue
        donated = eqn.params.get("donated_invars", ())
        large = [v for v in eqn.invars
                 if _aval_bytes(getattr(v, "aval", None)) >= spec.large_bytes]
        if large and not any(donated):
            sizes = ", ".join(v.aval.str_short() for v in large[:3])
            findings.append(Finding(
                "non-donated-carry", where,
                f"jit {name!r} carries large operand(s) [{sizes}] with no "
                f"donated buffers: peak memory doubles on every step."))

    return findings
