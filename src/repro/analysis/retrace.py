"""Retrace sentinel: turn "a warmed hot loop never recompiles" into an assert.

jax emits monitoring events for every backend compilation
(``/jax/core/compile/backend_compile_duration`` fires exactly once per
XLA compile; a cache hit emits nothing).  This module installs a pair of
process-wide listeners — once, lazily — and exposes:

* :func:`watch_compiles` — context manager yielding a :class:`CompileWatch`
  whose ``.compiles`` counts backend compiles inside the block.
* :func:`assert_no_retrace` — context manager that raises
  :class:`RetraceError` if more than ``allow`` compiles happen inside the
  block.  This is the asserted form of the PR-3 "one compiled epoch
  serves the whole search" and PR-8 "a fixed fleet never compiles
  mid-stream" claims.

Listeners are never unregistered (jax's unregister API is private and
fragile); instead a stack of active watches receives each event.  The
listeners themselves are free when no watch is active, so importing this
module costs nothing on the hot path.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator, List

__all__ = [
    "CompileWatch",
    "RetraceError",
    "assert_no_retrace",
    "watch_compiles",
]

# One event per XLA backend compile; cache hits emit no event at all.
# (A single user-visible trace may legitimately produce several of these
# — e.g. ``jnp.ones`` compiles its own fill program — which is exactly
# what we want to count: *any* compile inside a warmed loop is a miss.)
_BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_lock = threading.Lock()
_watches: List["CompileWatch"] = []
_installed = False


class RetraceError(AssertionError):
    """A region that promised zero recompiles compiled anyway."""


class CompileWatch:
    """Counts backend compiles observed while the watch is active."""

    def __init__(self) -> None:
        self.compiles = 0

    def _record(self) -> None:
        self.compiles += 1


def _on_event_duration(event: str, duration: float, **kwargs) -> None:
    if event != _BACKEND_COMPILE_EVENT:
        return
    with _lock:
        active = list(_watches)
    for watch in active:
        watch._record()


def _install_listener() -> None:
    global _installed
    with _lock:
        if _installed:
            return
        import jax.monitoring

        jax.monitoring.register_event_duration_secs_listener(_on_event_duration)
        _installed = True


@contextlib.contextmanager
def watch_compiles() -> Iterator[CompileWatch]:
    """Count jax backend compiles that happen inside the ``with`` block."""
    _install_listener()
    watch = CompileWatch()
    with _lock:
        _watches.append(watch)
    try:
        yield watch
    finally:
        with _lock:
            _watches.remove(watch)


@contextlib.contextmanager
def assert_no_retrace(what: str = "", *, allow: int = 0) -> Iterator[CompileWatch]:
    """Fail with :class:`RetraceError` if the block compiles anything.

    Args:
      what: label for the guarded region, used in the error message.
      allow: number of compiles to tolerate (default 0 — fully warmed).

    Usage::

        router.warmup(prompt_lens=[16, 64])
        with assert_no_retrace("fleet serve after warmup"):
            router.run(requests)
    """
    with watch_compiles() as inner:
        yield inner
    count = inner.compiles
    if count > allow:
        label = f" in {what!r}" if what else ""
        raise RetraceError(
            f"expected at most {allow} jax compile(s){label}, observed "
            f"{count}: a warmed hot loop retraced.  Look for shape drift, "
            f"weak-type promotion, non-hashable static args, or a jit "
            f"constructed inside the loop."
        )
