"""Static analysis & sanitizers for the distributed hot paths (ShardLint).

Three tiers, one currency (:class:`~repro.analysis.finding.Finding`):

1. **Jaxpr auditor** (:mod:`repro.analysis.jaxpr_audit` +
   :mod:`repro.analysis.manifest`) — walks the closed jaxprs of every
   registered hot path and flags host callbacks/transfers, f64
   promotions, non-donated large carries, and collectives on undeclared
   mesh axes.  ``python -m repro.analysis audit --check``.
2. **Retrace sentinel** (:mod:`repro.analysis.retrace`) — runtime
   compile-event instrumentation; ``assert_no_retrace()`` turns the
   "a warmed loop never recompiles" claims into asserted contracts.
3. **AST lint** (:mod:`repro.analysis.lint`) — repo-invariant rules over
   the source tree (traced-value leaks, wallclock/RNG in traced code,
   donated-buffer reuse, non-atomic store writes, jit-in-loop).
   ``python -m repro.analysis lint src/``.
"""
from .finding import Finding, format_findings
from .jaxpr_audit import AuditSpec, audit_jaxpr, iter_eqns
from .lint import RULES, lint_file, lint_paths, lint_source
from .manifest import (AuditTarget, HotPath, audit_hot_path, hot_paths,
                       register, run_audit)
from .retrace import (CompileWatch, RetraceError, assert_no_retrace,
                      watch_compiles)

__all__ = [
    "AuditSpec", "AuditTarget", "CompileWatch", "Finding", "HotPath",
    "RULES", "RetraceError", "assert_no_retrace", "audit_hot_path",
    "audit_jaxpr", "format_findings", "hot_paths", "iter_eqns",
    "lint_file", "lint_paths", "lint_source", "register", "run_audit",
    "watch_compiles",
]
