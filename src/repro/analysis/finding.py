"""The one currency every analysis tier trades in: a ``Finding``.

A finding is a *located, rule-attributed* claim that an invariant is
violated — the jaxpr auditor, the AST linter, and the retrace sentinel
all emit the same shape so the CLI, CI leg, and tests can treat them
uniformly.  Rules are short kebab-case ids (``"host-callback"``,
``"traced-leak"``); ``where`` is either a ``path:line`` source location
(lint) or a ``hotpath:<name>`` manifest location (audit).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List

__all__ = ["Finding", "format_findings"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one location."""

    rule: str       # kebab-case rule id, stable across releases
    where: str      # "src/repro/foo.py:42" or "hotpath:serve.fused_decode"
    message: str    # human-readable: what tripped and why it matters

    def __str__(self) -> str:
        return f"{self.where}: [{self.rule}] {self.message}"


def format_findings(findings: Iterable[Finding]) -> str:
    """Render findings one per line, grouped by rule, stable order."""
    items: List[Finding] = sorted(findings,
                                  key=lambda f: (f.rule, f.where, f.message))
    return "\n".join(str(f) for f in items)
