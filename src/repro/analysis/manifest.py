"""Hot-path manifest: the registry `python -m repro.analysis audit` runs.

Every entry names one distributed hot path, a zero-arg closure that
traces the *actual* function object the runtime executes (same caches,
same donation flags — not a reconstruction), and the
:class:`~repro.analysis.jaxpr_audit.AuditSpec` it must satisfy.  Tracing
via ``jax.make_jaxpr`` never executes the path, so the audit is cheap,
deterministic, and safe on a CPU CI box.

To register a new hot path::

    @register("subsystem.name", "one-line description")
    def _build():
        fn, args = ...build the jitted callable and example args...
        return AuditTarget(trace=lambda: fn(*args),
                           spec=AuditSpec(expect_donation=("step",)))

Entries that need a real multi-device mesh set ``requires_devices``;
the CLI skips them (with a note) when the process has fewer devices and
``--require-mesh`` turns that skip into a failure (the nightly 8-device
leg runs with it).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from .finding import Finding
from .jaxpr_audit import AuditSpec, audit_jaxpr

__all__ = [
    "AuditTarget", "HotPath", "register", "hot_paths", "audit_hot_path",
    "run_audit",
]


@dataclasses.dataclass(frozen=True)
class AuditTarget:
    """What one hot path hands the auditor: a zero-arg trace closure
    (``jax.make_jaxpr(trace)()`` must succeed) plus its expectations."""

    trace: Callable[[], Any]
    spec: AuditSpec


@dataclasses.dataclass(frozen=True)
class HotPath:
    name: str
    description: str
    build: Callable[[], AuditTarget]
    requires_devices: int = 1


_REGISTRY: Dict[str, HotPath] = {}


def register(name: str, description: str, *, requires_devices: int = 1):
    """Decorator: register a zero-arg builder as a named hot path."""
    def wrap(build: Callable[[], AuditTarget]) -> Callable[[], AuditTarget]:
        if name in _REGISTRY:
            raise ValueError(f"hot path {name!r} registered twice")
        _REGISTRY[name] = HotPath(name=name, description=description,
                                  build=build,
                                  requires_devices=requires_devices)
        return build
    return wrap


def hot_paths() -> List[HotPath]:
    return [_REGISTRY[name] for name in sorted(_REGISTRY)]


def audit_hot_path(hp: HotPath) -> List[Finding]:
    import jax

    target = hp.build()
    closed = jax.make_jaxpr(target.trace)()
    return audit_jaxpr(closed, target.spec, where=f"hotpath:{hp.name}")


def run_audit(names: Optional[List[str]] = None, *,
              require_mesh: bool = False
              ) -> Tuple[List[Finding], List[str], List[str]]:
    """Audit the registered hot paths.

    Returns ``(findings, audited_names, skipped_names)``.  Paths whose
    ``requires_devices`` exceeds the process device count are skipped
    unless ``require_mesh`` (then a finding is emitted instead).
    """
    import jax

    device_count = len(jax.devices())
    selected = hot_paths()
    if names:
        unknown = sorted(set(names) - set(hp.name for hp in selected))
        if unknown:
            raise KeyError(f"unknown hot path(s): {unknown}")
        selected = [hp for hp in selected if hp.name in set(names)]

    findings: List[Finding] = []
    audited: List[str] = []
    skipped: List[str] = []
    for hp in selected:
        if hp.requires_devices > device_count:
            if require_mesh:
                findings.append(Finding(
                    "audit-skip", f"hotpath:{hp.name}",
                    f"needs {hp.requires_devices} devices, have "
                    f"{device_count} (--require-mesh)"))
            else:
                skipped.append(hp.name)
            continue
        findings.extend(audit_hot_path(hp))
        audited.append(hp.name)
    return findings, audited, skipped


# ---------------------------------------------------------------------------
# shared fixtures (memoized: the audit traces several paths per process)
# ---------------------------------------------------------------------------

_SMOKE: Dict[str, Any] = {}


def _smoke_lm():
    """One tiny transformer + engine reused by every serve entry."""
    if "engine" not in _SMOKE:
        import jax
        from repro.configs import get_smoke
        from repro.models.transformer import init_model
        from repro.serve.engine import ServeEngine

        cfg = get_smoke("qwen2-1.5b")
        params, _ = init_model(jax.random.PRNGKey(0), cfg)
        _SMOKE["cfg"] = cfg
        _SMOKE["params"] = params
        _SMOKE["engine"] = ServeEngine(cfg, params, batch_size=4, max_seq=64)
    return _SMOKE["cfg"], _SMOKE["params"], _SMOKE["engine"]


class _Table:
    """Minimal stand-in for MLNumericTable: the runner only reads .data."""

    def __init__(self, data: Any) -> None:
        self.data = data


def _sgd_step(block, w, r):
    import jax.numpy as jnp

    del r
    resid = block @ w
    return w - 0.01 * (block.T @ resid) / jnp.float32(block.shape[0])


def _mesh_runner(schedule: str):
    import jax
    from repro.core.compat import make_mesh
    from repro.core.runner import DistributedRunner

    mesh = make_mesh((len(jax.devices()),), ("data",))
    return DistributedRunner(mesh=mesh, schedule=schedule, donate=True)


# ---------------------------------------------------------------------------
# runner hot paths (emulated partitions: shape contract is mesh-independent)
# ---------------------------------------------------------------------------

@register("runner.resident_rounds",
          "run_rounds: jitted scan over rounds, donated state carry")
def _build_resident_rounds() -> AuditTarget:
    import jax.numpy as jnp
    from repro.core.runner import DistributedRunner

    runner = DistributedRunner(num_shards=4, donate=True)
    data = jnp.ones((64, 16), jnp.float32)
    w0 = jnp.ones((16, 32), jnp.float32)          # 2 KiB carry
    return AuditTarget(
        trace=lambda: runner.run_rounds(_Table(data), w0, _sgd_step, 3),
        spec=AuditSpec(expect_donation=("run",), large_bytes=1 << 10))


@register("runner.streaming_epoch",
          "run_epochs body: one jitted epoch (scan over window chunks), "
          "donated state carry")
def _build_streaming_epoch() -> AuditTarget:
    import jax.numpy as jnp
    from repro.core.runner import DistributedRunner

    runner = DistributedRunner(num_shards=4, donate=True)
    epoch = runner.epoch_fn(_sgd_step, chunks_per_epoch=2)
    window = jnp.ones((64, 16), jnp.float32)
    state = jnp.ones((16, 32), jnp.float32)
    rounds = jnp.arange(2, dtype=jnp.int32)
    return AuditTarget(
        trace=lambda: epoch(state, window, rounds),
        spec=AuditSpec(expect_donation=("epoch",), large_bytes=1 << 10))


@register("runner.stacked_epoch",
          "run_stacked_epochs body: K vmapped trials through one jitted "
          "epoch, traced hyper scalars, donated stacked carry")
def _build_stacked_epoch() -> AuditTarget:
    import jax.numpy as jnp
    from repro.core.optimizer import sgd_trial_round
    from repro.core.runner import DistributedRunner

    runner = DistributedRunner(num_shards=4, donate=True)
    k, d = 4, 16
    step = sgd_trial_round(_grad_row, local_batch_size=16)
    stacked_step, stacked_upd = runner._stacked_fns(step, None)
    epoch = runner.epoch_fn(stacked_step, stacked_upd, chunks_per_epoch=1)
    carry = {
        "trial": jnp.ones((k, d), jnp.float32),
        "hyper": {"lr": jnp.full((k,), 0.05, jnp.float32),
                  "decay": jnp.ones((k,), jnp.float32),
                  "l1": jnp.zeros((k,), jnp.float32)},
        "active": jnp.ones((k,), bool),
        "offset": jnp.zeros((k,), jnp.int32),
    }
    window = jnp.ones((64, d), jnp.float32)
    rounds = jnp.arange(1, dtype=jnp.int32)
    return AuditTarget(
        trace=lambda: epoch(carry, window, rounds),
        spec=AuditSpec(expect_donation=("epoch",), large_bytes=1 << 8))


def _grad_row(vec, w, hyper):
    del hyper
    return (vec @ w) * vec


# ---------------------------------------------------------------------------
# serving hot paths
# ---------------------------------------------------------------------------

@register("serve.fused_decode",
          "ServeEngine._decode: one fused decode step over the shared "
          "slot cache")
def _build_fused_decode() -> AuditTarget:
    import jax.numpy as jnp

    _, params, engine = _smoke_lm()
    cache = engine.init_shared_cache()
    toks = jnp.zeros((engine.batch, 1), jnp.int32)
    pos = jnp.zeros((engine.batch,), jnp.int32)
    return AuditTarget(
        trace=lambda: engine._decode(params, toks, pos, cache),
        spec=AuditSpec())


@register("serve.ragged_prefill",
          "ServeEngine._prefill_ragged: one right-padded mixed-length "
          "admission wave")
def _build_ragged_prefill() -> AuditTarget:
    import jax.numpy as jnp

    _, params, engine = _smoke_lm()
    wb, S = 4, 16
    sub = engine.model.init_cache(wb, engine.max_seq)
    toks = jnp.zeros((wb, S), jnp.int32)
    lens = jnp.full((wb,), S, jnp.int32)
    return AuditTarget(
        trace=lambda: engine._prefill_ragged(params, toks, lens, sub),
        spec=AuditSpec())


@register("serve.offset_prefill",
          "prefill_ragged(start_pos=): the prefix-cache tail prefill")
def _build_offset_prefill() -> AuditTarget:
    import jax
    import jax.numpy as jnp

    _, params, engine = _smoke_lm()
    wb, S = 2, 8
    # the exact lambda ServeEngine builds when a prefix cache is attached
    fn = jax.jit(lambda p, t, n, s, c: engine.model.prefill_ragged(
        p, t, n, c, start_pos=s))
    sub = engine.model.init_cache(wb, engine.max_seq)
    toks = jnp.zeros((wb, S), jnp.int32)
    lens = jnp.full((wb,), S, jnp.int32)
    starts = jnp.full((wb,), 8, jnp.int32)
    return AuditTarget(
        trace=lambda: fn(params, toks, lens, starts, sub),
        spec=AuditSpec())


@register("serve.span_decode",
          "ReplicaRouter fused span decode: active-lane slice + writeback, "
          "donated fleet cache")
def _build_span_decode() -> AuditTarget:
    import jax.numpy as jnp
    from repro.serve.router import ReplicaRouter

    cfg, params, _ = _smoke_lm()
    router = ReplicaRouter(cfg, params, slots_per_replica=2, max_replicas=2,
                           max_seq=64)
    span = 2
    fn = router._step_for_span(span)
    cache = router.engine.init_shared_cache()
    toks = jnp.zeros((span, 1), jnp.int32)
    pos = jnp.zeros((span,), jnp.int32)
    return AuditTarget(
        trace=lambda: fn(router.engine.params, toks, pos, cache),
        spec=AuditSpec(expect_donation=("step",), large_bytes=1 << 12))


@register("kernels.quant_matmul",
          "int8 quantized matmul wrapper (Pallas on TPU, fp32 dequant "
          "fallback elsewhere)")
def _build_quant_matmul() -> AuditTarget:
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    x = jnp.ones((8, 32), jnp.float32)
    w = jnp.ones((32, 16), jnp.float32)

    def path():
        xq, xs = ops.quantize_rows(x)
        wq_t, ws = ops.quantize_rows(w.T)
        return ops.quant_matmul(xq, xs, wq_t.T, ws)

    return AuditTarget(trace=jax.jit(path), spec=AuditSpec())


# ---------------------------------------------------------------------------
# mesh hot paths (real collectives; every CollectiveSchedule lowering)
# ---------------------------------------------------------------------------

@register("mesh.allreduce_round",
          "shard_map round with ALLREDUCE (pmean) combine on the data axis",
          requires_devices=8)
def _build_mesh_allreduce() -> AuditTarget:
    import jax
    import jax.numpy as jnp

    runner = _mesh_runner("allreduce")
    n = len(jax.devices())
    data = jnp.ones((8 * n, 16), jnp.float32)
    w0 = jnp.ones((16, 32), jnp.float32)
    return AuditTarget(
        trace=lambda: runner.run_rounds(_Table(data), w0, _sgd_step, 2),
        spec=AuditSpec(declared_axes=frozenset({"data"}),
                       expect_donation=("run",), large_bytes=1 << 10))


@register("mesh.gather_broadcast_epoch",
          "shard_map epoch with GATHER_BROADCAST (all_gather) combine",
          requires_devices=8)
def _build_mesh_gather() -> AuditTarget:
    import jax
    import jax.numpy as jnp

    runner = _mesh_runner("gather_broadcast")
    epoch = runner.epoch_fn(_sgd_step, chunks_per_epoch=1)
    n = len(jax.devices())
    window = jnp.ones((8 * n, 16), jnp.float32)
    state = jnp.ones((16, 32), jnp.float32)
    rounds = jnp.arange(1, dtype=jnp.int32)
    return AuditTarget(
        trace=lambda: epoch(state, window, rounds),
        spec=AuditSpec(declared_axes=frozenset({"data"}),
                       expect_donation=("epoch",), large_bytes=1 << 10))


@register("mesh.reduce_scatter_epoch",
          "shard_map epoch with REDUCE_SCATTER (psum_scatter + all_gather) "
          "combine",
          requires_devices=8)
def _build_mesh_reduce_scatter() -> AuditTarget:
    import jax
    import jax.numpy as jnp

    runner = _mesh_runner("reduce_scatter")
    epoch = runner.epoch_fn(_sgd_step, chunks_per_epoch=1)
    n = len(jax.devices())
    window = jnp.ones((8 * n, 16), jnp.float32)
    state = jnp.ones((16, 32), jnp.float32)
    rounds = jnp.arange(1, dtype=jnp.int32)
    return AuditTarget(
        trace=lambda: epoch(state, window, rounds),
        spec=AuditSpec(declared_axes=frozenset({"data"}),
                       expect_donation=("epoch",), large_bytes=1 << 10))
