"""CLI for the analysis subsystem.

    python -m repro.analysis lint [paths...]          # default: src/
    python -m repro.analysis audit [--check] [--require-mesh] [names...]
    python -m repro.analysis audit --list

Exit code 0 = clean, 1 = findings (or, with ``--require-mesh``, skipped
mesh paths).  Output is one finding per line, stable order, so the CI
log diff against a previous run is meaningful.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List, Optional


def _cmd_lint(args: argparse.Namespace) -> int:
    from .finding import format_findings
    from .lint import lint_paths

    paths = args.paths or ["src"]
    findings = lint_paths(paths)
    if findings:
        print(format_findings(findings))
        print(f"\nlint: {len(findings)} finding(s) in {', '.join(paths)}",
              file=sys.stderr)
        return 1
    print(f"lint: clean ({', '.join(paths)})")
    return 0


def _cmd_audit(args: argparse.Namespace) -> int:
    from .finding import format_findings
    from .manifest import hot_paths, run_audit

    if args.list:
        for hp in hot_paths():
            mesh = (f"  [needs {hp.requires_devices} devices]"
                    if hp.requires_devices > 1 else "")
            print(f"{hp.name:32s} {hp.description}{mesh}")
        return 0

    start = time.perf_counter()
    findings, audited, skipped = run_audit(args.names or None,
                                           require_mesh=args.require_mesh)
    elapsed = time.perf_counter() - start

    for name in audited:
        hits = [f for f in findings if f.where == f"hotpath:{name}"]
        print(f"{'FAIL' if hits else 'ok  '} {name}")
    for name in skipped:
        print(f"skip {name} (not enough devices)")
    if findings:
        print()
        print(format_findings(findings))
    print(f"\naudit: {len(audited)} hot path(s) audited, "
          f"{len(skipped)} skipped, {len(findings)} finding(s) "
          f"in {elapsed:.1f}s", file=sys.stderr)
    if args.check:
        return 1 if findings else 0
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = parser.add_subparsers(dest="cmd", required=True)

    lint_p = sub.add_parser("lint", help="AST lint over source trees")
    lint_p.add_argument("paths", nargs="*", help="files/dirs (default: src)")
    lint_p.set_defaults(fn=_cmd_lint)

    audit_p = sub.add_parser("audit", help="jaxpr audit of registered hot paths")
    audit_p.add_argument("names", nargs="*",
                         help="hot-path names (default: all)")
    audit_p.add_argument("--check", action="store_true",
                         help="exit 1 on any finding")
    audit_p.add_argument("--require-mesh", action="store_true",
                         help="fail instead of skipping paths that need "
                              "more devices")
    audit_p.add_argument("--list", action="store_true",
                         help="list registered hot paths and exit")
    audit_p.set_defaults(fn=_cmd_audit)

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
