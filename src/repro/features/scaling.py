"""Numeric feature transforms on the device tier — fitted transformers.

:class:`Standardizer` and :class:`BiasAdder` implement the
:class:`repro.core.interfaces.Transformer` contract: column statistics are
computed once at ``fit`` with the table's explicit global reduces (the
shared-nothing rule) and *replayed* at ``transform`` on any table — or, via
``apply``, on label-free feature rows inside a serving jit.

Label safety: supervised tables carry the label in column 0 (library
convention), and standardizing it silently corrupts training targets — the
seed-era ``standardize`` function did exactly that.  Both transformers skip
label/bias columns by default: ``skip="auto"`` passes through any column
named ``label``/``bias`` plus (for the Standardizer) near-constant columns
(a bias column is constant by construction), and pipelines additionally
pass the supervised label index explicitly.  The seed functions
(``standardize``, ``add_bias``) remain as thin shims over the fitted
classes.
"""
from __future__ import annotations

from typing import Any, Iterable, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.interfaces import FittedTransformer, Transformer
from repro.core.numeric_table import MLNumericTable

__all__ = [
    "Standardizer",
    "FittedStandardizer",
    "BiasAdder",
    "FittedBiasAdder",
    "standardize",
    "add_bias",
    "AUTO_SKIP_NAMES",
]

#: column names passed through untouched under ``skip="auto"``
AUTO_SKIP_NAMES = ("label", "bias")

SkipSpec = Union[str, None, Iterable[Any]]


def _table_names(table: Any) -> Tuple[Any, ...]:
    return tuple(getattr(table, "names", None) or
                 getattr(getattr(table, "schema", None), "names", None) or ())


def resolve_skip(table: Any, skip: SkipSpec, default_skip: Sequence[int] = ()
                 ) -> Tuple[int, ...]:
    """Resolve a skip spec to sorted column indices of ``table``.

    ``"auto"`` matches :data:`AUTO_SKIP_NAMES` by column name (when the
    table carries names) and unions ``default_skip`` (the pipeline's
    supervised-label indices); an explicit iterable mixes names and
    indices; ``None``/``()`` skips nothing.
    """
    ncols = int(table.num_cols)
    names = _table_names(table)
    idx = set()
    if isinstance(skip, str):
        if skip != "auto":
            raise ValueError(
                f"skip={skip!r}: the only string spec is 'auto' — pass an "
                f"iterable of names/indices (e.g. skip=[{skip!r}])")
        for i, n in enumerate(names):
            if n and str(n).lower() in AUTO_SKIP_NAMES:
                idx.add(i)
        idx.update(int(i) for i in default_skip)
    elif skip is not None:
        for s in skip:
            if isinstance(s, str):
                if s in names:
                    idx.add(names.index(s))
                else:
                    raise KeyError(f"no column named {s!r} to skip")
            else:
                idx.add(int(s))
    return tuple(sorted(i for i in idx if 0 <= i < ncols))


def resolve_labels(table: Any, default_skip: Sequence[int] = ()
                   ) -> Tuple[int, ...]:
    """The *label* columns of a table — the columns a raw serving row does
    not carry (columns named ``label`` plus the pipeline's supervised
    indices).  Other skipped columns (a ``bias`` column, near-constant
    features) exist in serving rows and pass through ``apply`` as
    identities instead of being dropped."""
    names = _table_names(table)
    labels = set(int(i) for i in default_skip)
    for i, n in enumerate(names):
        if n and str(n).lower() == "label":
            labels.add(i)
    return tuple(sorted(i for i in labels if 0 <= i < int(table.num_cols)))


def _feature_cols(ncols: int, skip_idx: Tuple[int, ...]) -> np.ndarray:
    return np.asarray([i for i in range(ncols) if i not in set(skip_idx)],
                      np.int32)


class FittedStandardizer(FittedTransformer):
    """Column-wise ``(x - shift) / scale`` with fitted statistics.

    ``shift``/``scale`` span the fitted table's full column width; skipped
    columns (labels, bias, near-constant) carry the identity ``(0, 1)``,
    so :meth:`transform` is one elementwise map.  :meth:`apply` replays on
    label-free serving rows: only the *label* columns are absent there —
    other skipped columns (a bias column) are present and pass through as
    identities.
    """

    tier = "device"

    def __init__(self, shift: jnp.ndarray, scale: jnp.ndarray,
                 skip_idx: Tuple[int, ...],
                 label_idx: Tuple[int, ...] = ()) -> None:
        self.shift = jnp.asarray(shift)
        self.scale = jnp.asarray(scale)
        self.skip_idx = tuple(int(i) for i in skip_idx)
        self.label_idx = tuple(int(i) for i in label_idx)
        self._feat = _feature_cols(self.shift.shape[0], self.label_idx)

    def transform(self, table: MLNumericTable) -> MLNumericTable:
        if table.num_cols != self.shift.shape[0]:
            raise ValueError(
                f"fitted on {self.shift.shape[0]} columns, table has "
                f"{table.num_cols}")
        data = (table.data - self.shift) / self.scale
        return MLNumericTable(data, num_shards=table.num_shards,
                              mesh=table.mesh, names=table.names,
                              data_axes=table.data_axes or None)

    def apply(self, feats: jnp.ndarray) -> jnp.ndarray:
        """Replay on (n, f) serving rows — f excludes only the label
        columns; skipped non-label columns pass through as identities."""
        return (feats - self.shift[self._feat]) / self.scale[self._feat]

    @property
    def partial(self):
        return {"shift": self.shift, "scale": self.scale}

    def host_state(self) -> dict:
        return {"kind": "standardizer", "skip": list(self.skip_idx),
                "label": list(self.label_idx),
                "num_cols": int(self.shift.shape[0])}

    @staticmethod
    def partial_template(host_state: dict):
        n = int(host_state["num_cols"])
        return {"shift": jnp.zeros((n,), jnp.float32),
                "scale": jnp.zeros((n,), jnp.float32)}

    @classmethod
    def from_state(cls, host_state: dict, partial: dict
                   ) -> "FittedStandardizer":
        return cls(partial["shift"], partial["scale"],
                   tuple(host_state["skip"]),
                   tuple(host_state.get("label", host_state["skip"])))


class Standardizer(Transformer):
    """Fit column means/stds with explicit global reduces; replay anywhere.

    ``skip="auto"`` (default) protects label/bias-named columns and
    near-constant columns (variance ≤ ``min_variance`` — a bias column is
    constant by construction) from being standardized: they pass through
    unchanged.
    """

    tier = "device"

    def __init__(self, eps: float = 1e-8, skip: SkipSpec = "auto",
                 min_variance: float = 1e-12) -> None:
        self.eps = float(eps)
        self.skip = skip
        self.min_variance = float(min_variance)
        self._config = {"eps": eps, "skip": skip, "min_variance": min_variance}

    def fit(self, table: MLNumericTable, default_skip: Sequence[int] = ()
            ) -> FittedStandardizer:
        skip_idx = resolve_skip(table, self.skip, default_skip)
        label_idx = resolve_labels(table, default_skip)
        n = table.num_rows
        s = table.sum_rows()
        ss = jnp.sum(table.data * table.data, axis=0)
        mean = s / n
        var = jnp.maximum(ss / n - mean * mean, 0.0)
        std = jnp.sqrt(var) + self.eps
        passthrough = np.zeros(table.num_cols, bool)
        passthrough[list(skip_idx)] = True
        passthrough = jnp.asarray(passthrough) | (var <= self.min_variance)
        shift = jnp.where(passthrough, 0.0, mean)
        scale = jnp.where(passthrough, 1.0, std)
        return FittedStandardizer(shift, scale, skip_idx, label_idx)


class FittedBiasAdder(FittedTransformer):
    """Insert a constant-1 column at a fitted table index (named ``bias``
    so downstream auto-skip recognizes it)."""

    tier = "device"

    def __init__(self, at: int, num_cols: int, skip_idx: Tuple[int, ...],
                 label_idx: Tuple[int, ...] = ()) -> None:
        self.at = int(at)
        self.num_cols = int(num_cols)
        self.skip_idx = tuple(int(i) for i in skip_idx)
        self.label_idx = tuple(int(i) for i in label_idx)
        # serving-row insert position: table index minus the preceding
        # label columns (raw rows carry everything except the labels)
        self._feat_at = self.at - sum(1 for i in self.label_idx
                                      if i < self.at)

    def _names_out(self, names):
        if names is None:
            return None
        names = list(names)
        return tuple(names[: self.at] + ["bias"] + names[self.at:])

    def transform(self, table: MLNumericTable) -> MLNumericTable:
        if table.num_cols != self.num_cols:
            raise ValueError(
                f"fitted on {self.num_cols} columns, table has "
                f"{table.num_cols}")
        ones = jnp.ones((table.num_rows, 1), table.data.dtype)
        data = jnp.concatenate(
            [table.data[:, : self.at], ones, table.data[:, self.at:]], axis=1)
        return MLNumericTable(data, num_shards=table.num_shards,
                              mesh=table.mesh,
                              names=self._names_out(table.names),
                              data_axes=table.data_axes or None)

    def apply(self, feats: jnp.ndarray) -> jnp.ndarray:
        ones = jnp.ones(feats.shape[:-1] + (1,), feats.dtype)
        return jnp.concatenate(
            [feats[..., : self._feat_at], ones, feats[..., self._feat_at:]],
            axis=-1)

    def host_state(self) -> dict:
        return {"kind": "bias", "at": self.at, "num_cols": self.num_cols,
                "skip": list(self.skip_idx), "label": list(self.label_idx)}

    @staticmethod
    def partial_template(host_state: dict):
        return {}

    @classmethod
    def from_state(cls, host_state: dict, partial: dict) -> "FittedBiasAdder":
        return cls(host_state["at"], host_state["num_cols"],
                   tuple(host_state["skip"]),
                   tuple(host_state.get("label", host_state["skip"])))


class BiasAdder(Transformer):
    """Insert a constant-1 bias column after the label columns (``at=None``
    → immediately after the skipped columns; an explicit ``at`` is a table
    column index)."""

    tier = "device"

    def __init__(self, at: Optional[int] = None, skip: SkipSpec = "auto"
                 ) -> None:
        self.at = at
        self.skip = skip
        self._config = {"at": at, "skip": skip}

    def fit(self, table: MLNumericTable, default_skip: Sequence[int] = ()
            ) -> FittedBiasAdder:
        skip_idx = resolve_skip(table, self.skip, default_skip)
        label_idx = resolve_labels(table, default_skip)
        at = self.at if self.at is not None else len(skip_idx)
        return FittedBiasAdder(at, table.num_cols, skip_idx, label_idx)


# --------------------------------------------------------------------------- #
# seed-era function shims
# --------------------------------------------------------------------------- #
def standardize(table: MLNumericTable, eps: float = 1e-8,
                skip: SkipSpec = "auto") -> MLNumericTable:
    """Column-wise ``(x - mean) / std`` (shim over :class:`Standardizer`).

    Label/bias-named columns and constant columns pass through unchanged by
    default (``skip="auto"``) — pass ``skip=None`` for the seed behavior of
    standardizing every column regardless.
    """
    f, out = Standardizer(eps=eps, skip=skip).fit_transform(table)
    return out


def add_bias(table: MLNumericTable, at: int = 1) -> MLNumericTable:
    """Insert a constant-1 bias column at index ``at`` (after the label
    col) — shim over :class:`BiasAdder`."""
    f, out = BiasAdder(at=at, skip=None).fit_transform(table)
    return out
