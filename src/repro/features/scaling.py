"""Numeric feature transforms on the device tier."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.numeric_table import MLNumericTable

__all__ = ["standardize", "add_bias"]


def standardize(table: MLNumericTable, eps: float = 1e-8) -> MLNumericTable:
    """Column-wise (x - mean) / std.  Means/stds are computed with explicit
    global reduces (sum, sum-of-squares), honouring the shared-nothing rule."""
    n = table.num_rows
    s = table.sum_rows()
    ss = jnp.sum(table.data * table.data, axis=0)
    mean = s / n
    var = jnp.maximum(ss / n - mean * mean, 0.0)
    std = jnp.sqrt(var) + eps
    data = (table.data - mean) / std
    return MLNumericTable(data, num_shards=table.num_shards, mesh=table.mesh,
                          names=table.names, data_axes=table.data_axes or None)


def add_bias(table: MLNumericTable, at: int = 1) -> MLNumericTable:
    """Insert a constant-1 bias column at index ``at`` (after the label col)."""
    ones = jnp.ones((table.num_rows, 1), table.data.dtype)
    data = jnp.concatenate([table.data[:, :at], ones, table.data[:, at:]], axis=1)
    return MLNumericTable(data, num_shards=table.num_shards, mesh=table.mesh,
                          data_axes=table.data_axes or None)
