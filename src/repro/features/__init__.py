"""Feature extraction (paper Fig. A2: nGrams → tfIdf → KMeans pipeline)."""
from repro.features.text import n_grams, tf_idf, hashing_vectorizer
from repro.features.scaling import standardize, add_bias

__all__ = ["n_grams", "tf_idf", "hashing_vectorizer", "standardize", "add_bias"]
