"""Feature extraction (paper Fig. A2: nGrams → tfIdf → train pipeline).

Fitted transformers (:class:`NGrams`, :class:`TfIdf`,
:class:`HashingVectorizer`, :class:`Standardizer`, :class:`BiasAdder`)
compute corpus statistics once at ``fit`` and replay them at ``transform``
on any table or raw serving row — the building blocks of
:class:`repro.pipeline.Pipeline`.  The seed-era one-shot functions remain
as fit+transform shims.
"""
from repro.features.scaling import (
    BiasAdder,
    FittedBiasAdder,
    FittedStandardizer,
    Standardizer,
    add_bias,
    standardize,
)
from repro.features.text import (
    FittedHashingVectorizer,
    FittedNGrams,
    FittedTfIdf,
    HashingVectorizer,
    NGrams,
    TfIdf,
    hashing_vectorizer,
    n_grams,
    tf_idf,
)

__all__ = [
    "NGrams", "FittedNGrams", "TfIdf", "FittedTfIdf",
    "HashingVectorizer", "FittedHashingVectorizer",
    "Standardizer", "FittedStandardizer", "BiasAdder", "FittedBiasAdder",
    "n_grams", "tf_idf", "hashing_vectorizer", "standardize", "add_bias",
]
