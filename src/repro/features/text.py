"""Text featurization (paper §III-A, Fig. A2) — fitted transformers.

The paper's pipeline ``nGrams(rawText, n=2, top=30000) → tfIdf(...)`` is
expressed here as :class:`repro.core.interfaces.Transformer` objects whose
corpus statistics are computed once at ``fit`` and *replayed* at
``transform``:

  * :class:`NGrams` — fits the vocabulary (the corpus's ``top`` most
    frequent n-grams); transform maps any table (or raw serving row) onto
    that fixed vocabulary.  Fitting on the train view only and replaying on
    validation/serving rows is what closes the seed-era train/test-leakage
    trap (the one-shot ``n_grams`` function refit its vocabulary on
    whatever table it was handed).
  * :class:`TfIdf` — fits document frequencies (→ IDF weights) with one
    shared-nothing reduce; transform is the pure per-row map
    ``tf * idf`` and runs on the device tier (inside the serving jit).
  * :class:`HashingVectorizer` — the stateless streaming-friendly variant
    (fit records only configuration; the hash is a stable CRC so replay is
    identical across processes — a fitted transformer must survive
    checkpoint/restore into a fresh interpreter).

Non-target columns (labels) pass through in their original order ahead of
the generated feature columns, so the library's label-in-column-0
convention survives featurization.  The seed-era one-shot functions
(``n_grams``, ``tf_idf``, ``hashing_vectorizer``) remain as fit+transform
shims.
"""
from __future__ import annotations

import re
import zlib
from collections import Counter
from typing import Any, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.interfaces import FittedTransformer, Transformer
from repro.core.mltable import MLTable
from repro.core.numeric_table import MLNumericTable
from repro.core.schema import Column, ColumnType, MLRow, Schema
from repro.features.scaling import (
    SkipSpec,
    _feature_cols,
    resolve_labels,
    resolve_skip,
)

__all__ = [
    "NGrams", "FittedNGrams",
    "TfIdf", "FittedTfIdf",
    "HashingVectorizer", "FittedHashingVectorizer",
    "n_grams", "tf_idf", "hashing_vectorizer",
]

_TOKEN = re.compile(r"[a-z0-9']+")


def _tokens(text: str) -> List[str]:
    return _TOKEN.findall(text.lower())


def _grams(text: str, n: int) -> List[str]:
    toks = _tokens(text)
    return [" ".join(toks[i: i + n]) for i in range(len(toks) - n + 1)]


def _stable_hash(gram: str) -> int:
    """Process-independent gram hash (python's ``hash`` is salted per
    interpreter, which would break checkpoint-restore replay)."""
    return zlib.crc32(gram.encode("utf-8"))


def _text_col(table: MLTable, column: Union[int, str]) -> int:
    return (table.schema.index_of(column) if isinstance(column, str)
            else int(column))


def _passthrough_idx(table: MLTable, col: int, keep_columns: bool
                     ) -> Tuple[int, ...]:
    if not keep_columns:
        return ()
    return tuple(i for i in range(table.num_cols) if i != col)


def _vectorized_table(table: MLTable, col: int, passthrough: Tuple[int, ...],
                      feat_names: Sequence[str],
                      row_vec) -> MLTable:
    """Rebuild a table: passthrough columns (original order) + generated
    feature columns, preserving the partition layout."""
    in_cols = table.schema.columns
    schema = Schema(
        tuple(in_cols[i] for i in passthrough)
        + tuple(Column(ColumnType.SCALAR, n) for n in feat_names))
    parts = []
    for p in table.partitions:
        out = []
        for row in p:
            vec = row_vec(str(row[col]))
            out.append(MLRow(tuple(row[i] for i in passthrough) + tuple(vec),
                             schema))
        parts.append(out)
    return MLTable(parts, schema)


class FittedNGrams(FittedTransformer):
    """Replay a fitted n-gram vocabulary over tables or raw text rows."""

    tier = "host"

    def __init__(self, vocab: Sequence[str], n: int, column: Union[int, str],
                 keep_columns: bool = True) -> None:
        self.vocab = list(vocab)
        self.n = int(n)
        self.column = column
        self.keep_columns = bool(keep_columns)
        self._index = {g: i for i, g in enumerate(self.vocab)}

    def _vec(self, text: str) -> List[float]:
        vec = [0.0] * len(self.vocab)
        for gram, c in Counter(_grams(text, self.n)).items():
            j = self._index.get(gram)
            if j is not None:
                vec[j] = float(c)
        return vec

    def transform(self, table: MLTable) -> MLTable:
        col = _text_col(table, self.column)
        passthrough = _passthrough_idx(table, col, self.keep_columns)
        # generated columns are namespaced (``ng:<gram>``) so a corpus that
        # happens to contain the token "label" or "bias" can never collide
        # with the auto-skip names of the passthrough columns; the seed
        # shim (keep_columns=False) keeps raw gram names for fidelity
        names = ([f"ng:{g}" for g in self.vocab] if self.keep_columns
                 else list(self.vocab))
        return _vectorized_table(table, col, passthrough, names, self._vec)

    def transform_rows(self, rows: Any) -> np.ndarray:
        """Raw serving rows (a str or sequence of str) → (n, |vocab|)
        count matrix — the vocab-lookup step of a served pipeline."""
        if isinstance(rows, str):
            rows = [rows]
        return np.asarray([self._vec(str(r)) for r in rows], np.float32)

    def host_state(self) -> dict:
        return {"kind": "ngrams", "vocab": list(self.vocab), "n": self.n,
                "column": self.column, "keep_columns": self.keep_columns}

    @staticmethod
    def partial_template(host_state: dict):
        return {}

    @classmethod
    def from_state(cls, host_state: dict, partial: dict) -> "FittedNGrams":
        return cls(host_state["vocab"], host_state["n"], host_state["column"],
                   host_state["keep_columns"])


class NGrams(Transformer):
    """Fit the corpus's ``top`` most frequent n-grams of one STRING column
    (Fig. A2 ``nGrams(rawTextTable, n=2, top=30000)``); transform emits one
    SCALAR count column per vocabulary gram, after the passthrough columns.
    """

    tier = "host"

    def __init__(self, n: int = 2, top: int = 30000,
                 column: Union[int, str] = 0, keep_columns: bool = True
                 ) -> None:
        self.n = int(n)
        self.top = int(top)
        self.column = column
        self.keep_columns = bool(keep_columns)
        self._config = {"n": n, "top": top, "column": column,
                        "keep_columns": keep_columns}

    def fit(self, table: MLTable, default_skip: Sequence[int] = ()
            ) -> FittedNGrams:
        col = _text_col(table, self.column)
        corpus: Counter = Counter()
        for row in table.rows():
            corpus.update(Counter(_grams(str(row[col]), self.n)))
        vocab = [g for g, _ in corpus.most_common(self.top)]
        return FittedNGrams(vocab, self.n, self.column, self.keep_columns)


class FittedHashingVectorizer(FittedTransformer):
    """Replay feature hashing (stateless statistics, fixed configuration)."""

    tier = "host"

    def __init__(self, num_features: int, n: int, column: Union[int, str],
                 keep_columns: bool = True) -> None:
        self.num_features = int(num_features)
        self.n = int(n)
        self.column = column
        self.keep_columns = bool(keep_columns)

    def _vec(self, text: str) -> List[float]:
        vec = [0.0] * self.num_features
        for gram in _grams(text, self.n):
            vec[_stable_hash(gram) % self.num_features] += 1.0
        return vec

    def transform(self, table: MLTable) -> MLTable:
        col = _text_col(table, self.column)
        passthrough = _passthrough_idx(table, col, self.keep_columns)
        names = [f"h{i}" for i in range(self.num_features)]
        return _vectorized_table(table, col, passthrough, names, self._vec)

    def transform_rows(self, rows: Any) -> np.ndarray:
        if isinstance(rows, str):
            rows = [rows]
        return np.asarray([self._vec(str(r)) for r in rows], np.float32)

    def host_state(self) -> dict:
        return {"kind": "hashing", "num_features": self.num_features,
                "n": self.n, "column": self.column,
                "keep_columns": self.keep_columns}

    @staticmethod
    def partial_template(host_state: dict):
        return {}

    @classmethod
    def from_state(cls, host_state: dict, partial: dict
                   ) -> "FittedHashingVectorizer":
        return cls(host_state["num_features"], host_state["n"],
                   host_state["column"], host_state["keep_columns"])


class HashingVectorizer(Transformer):
    """Stateless n-gram → bucket counts (streaming-friendly; no corpus
    pass, so ``fit`` only freezes the configuration)."""

    tier = "host"

    def __init__(self, num_features: int = 1024, n: int = 1,
                 column: Union[int, str] = 0, keep_columns: bool = True
                 ) -> None:
        self.num_features = int(num_features)
        self.n = int(n)
        self.column = column
        self.keep_columns = bool(keep_columns)
        self._config = {"num_features": num_features, "n": n,
                        "column": column, "keep_columns": keep_columns}

    def fit(self, table: MLTable, default_skip: Sequence[int] = ()
            ) -> FittedHashingVectorizer:
        return FittedHashingVectorizer(self.num_features, self.n, self.column,
                                       self.keep_columns)


class FittedTfIdf(FittedTransformer):
    """Replay fitted IDF weights: per row, ``tf = count / row_total`` over
    the feature columns, output ``tf * idf``.  Skipped columns (labels,
    bias) pass through; :meth:`apply` replays on label-free serving rows
    inside a jit — only the *label* columns are absent there, other
    skipped columns are present and pass through as identities."""

    tier = "device"

    def __init__(self, idf: jnp.ndarray, skip_idx: Tuple[int, ...],
                 num_cols: int, label_idx: Tuple[int, ...] = ()) -> None:
        self.idf = jnp.asarray(idf)          # full table width; skips carry 0
        self.skip_idx = tuple(int(i) for i in skip_idx)
        self.label_idx = tuple(int(i) for i in label_idx)
        self.num_cols = int(num_cols)
        # serving-row columns = everything except the labels
        self._feat = _feature_cols(self.num_cols, self.label_idx)

    def _mask_for(self, cols: np.ndarray, dtype) -> jnp.ndarray:
        """1.0 at true feature columns of ``cols``, 0.0 at skips."""
        skip = set(self.skip_idx)
        return jnp.asarray([0.0 if int(c) in skip else 1.0 for c in cols],
                           dtype)

    def _apply_cols(self, data: jnp.ndarray, cols: np.ndarray) -> jnp.ndarray:
        """tf-idf over the true feature columns of ``data`` (whose columns
        are the table columns ``cols``); skipped columns pass through."""
        mask = self._mask_for(cols, data.dtype)
        counts = data * mask
        tot = jnp.maximum(jnp.sum(counts, axis=-1, keepdims=True), 1.0)
        tfidf = counts / tot * self.idf[np.asarray(cols)]
        return jnp.where(mask > 0, tfidf, data)

    def _apply_full(self, data: jnp.ndarray) -> jnp.ndarray:
        return self._apply_cols(data, np.arange(self.num_cols))

    def transform(self, table: Any) -> Any:
        if isinstance(table, MLTable):
            mat = np.asarray([r.to_floats() for r in table.rows()],
                             np.float64)
            out = np.asarray(self._apply_full(jnp.asarray(mat)), np.float32)
            return MLTable.from_numpy(out, num_partitions=table.num_partitions,
                                      names=table.schema.names)
        if table.num_cols != self.num_cols:
            raise ValueError(f"fitted on {self.num_cols} columns, table has "
                             f"{table.num_cols}")
        data = self._apply_full(table.data)
        return MLNumericTable(data, num_shards=table.num_shards,
                              mesh=table.mesh, names=table.names,
                              data_axes=table.data_axes or None)

    def apply(self, feats: jnp.ndarray) -> jnp.ndarray:
        """(n, f) serving rows (label columns absent) → tf-idf rows."""
        return self._apply_cols(feats, np.asarray(self._feat))

    @property
    def partial(self):
        return {"idf": self.idf}

    def host_state(self) -> dict:
        return {"kind": "tfidf", "skip": list(self.skip_idx),
                "label": list(self.label_idx), "num_cols": self.num_cols}

    @staticmethod
    def partial_template(host_state: dict):
        return {"idf": jnp.zeros((int(host_state["num_cols"]),), jnp.float32)}

    @classmethod
    def from_state(cls, host_state: dict, partial: dict) -> "FittedTfIdf":
        return cls(partial["idf"], tuple(host_state["skip"]),
                   host_state["num_cols"],
                   tuple(host_state.get("label", host_state["skip"])))


class TfIdf(Transformer):
    """Fit smooth IDF weights ``log((1 + N) / (1 + df)) ≥ 0`` over a count
    table (Fig. A2 ``tfIdf(...)``) with one global reduce; transform is the
    pure per-row ``tf * idf`` map."""

    tier = "device"

    def __init__(self, skip: SkipSpec = "auto") -> None:
        self.skip = skip
        self._config = {"skip": skip}

    def fit(self, table: Any, default_skip: Sequence[int] = ()
            ) -> FittedTfIdf:
        if isinstance(table, MLTable):
            data = jnp.asarray(
                np.asarray([r.to_floats() for r in table.rows()], np.float64))
        else:
            data = table.data
        skip_idx = resolve_skip(table, self.skip, default_skip)
        label_idx = resolve_labels(table, default_skip)
        n_docs = data.shape[0]
        df = jnp.sum((data > 0).astype(jnp.float32), axis=0)
        idf = jnp.log((1.0 + n_docs) / (1.0 + df)).astype(jnp.float32)
        if skip_idx:
            zero = np.ones(data.shape[1], np.float32)
            zero[list(skip_idx)] = 0.0
            idf = idf * jnp.asarray(zero)
        return FittedTfIdf(idf, skip_idx, int(data.shape[1]), label_idx)


# --------------------------------------------------------------------------- #
# seed-era function shims (fit + transform on the same table)
# --------------------------------------------------------------------------- #
def n_grams(table: MLTable, n: int = 2, top: int = 30000,
            column: Union[int, str] = 0) -> MLTable:
    """One-shot corpus fit + transform (shim over :class:`NGrams` with
    ``keep_columns=False`` — the seed behavior of emitting only the gram
    columns).  Prefer the fitted class: fit on the train view, replay on
    validation/serving rows."""
    f, out = NGrams(n=n, top=top, column=column,
                    keep_columns=False).fit_transform(table)
    return out


def tf_idf(table: MLTable) -> MLTable:
    """One-shot TF-IDF over a count table (shim over :class:`TfIdf`)."""
    f, out = TfIdf(skip=None).fit_transform(table)
    return out


def hashing_vectorizer(table: MLTable, num_features: int = 1024, n: int = 1,
                       column: Union[int, str] = 0) -> MLTable:
    """Feature hashing (shim over :class:`HashingVectorizer` with
    ``keep_columns=False``)."""
    f, out = HashingVectorizer(num_features=num_features, n=n, column=column,
                               keep_columns=False).fit_transform(table)
    return out
