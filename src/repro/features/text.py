"""Text featurization transforms (paper §III-A, Fig. A2).

Data transformations are functions MLTable -> MLTable (potentially of a
different schema).  ``n_grams`` produces per-document n-gram counts for the
``top`` most frequent grams in the corpus; ``tf_idf`` converts the count
table to TF-IDF; ``hashing_vectorizer`` is the streaming-friendly variant
(beyond-paper convenience, same contract).
"""
from __future__ import annotations

import math
import re
from collections import Counter
from typing import List

import numpy as np

from repro.core.mltable import MLTable
from repro.core.schema import ColumnType, MLRow, Schema

__all__ = ["n_grams", "tf_idf", "hashing_vectorizer"]

_TOKEN = re.compile(r"[a-z0-9']+")


def _tokens(text: str) -> List[str]:
    return _TOKEN.findall(text.lower())


def _grams(text: str, n: int) -> List[str]:
    toks = _tokens(text)
    return [" ".join(toks[i : i + n]) for i in range(len(toks) - n + 1)]


def n_grams(table: MLTable, n: int = 2, top: int = 30000, column: int = 0) -> MLTable:
    """Per-document frequency of the corpus's ``top`` n-grams (Fig. A2
    ``nGrams(rawTextTable, n=2, top=30000)``).

    Input: a table with a STRING column.  Output schema: one SCALAR column per
    selected gram (named by the gram), rows aligned with input rows.
    """
    col = table.schema.index_of(column) if isinstance(column, str) else column
    corpus = Counter()
    per_doc: List[Counter] = []
    for row in table.rows():
        g = Counter(_grams(str(row[col]), n))
        per_doc.append(g)
        corpus.update(g)
    vocab = [g for g, _ in corpus.most_common(top)]
    index = {g: i for i, g in enumerate(vocab)}
    schema = Schema.of(*([ColumnType.SCALAR] * len(vocab)), names=vocab)
    rows = []
    for g in per_doc:
        vec = [0.0] * len(vocab)
        for gram, c in g.items():
            j = index.get(gram)
            if j is not None:
                vec[j] = float(c)
        rows.append(MLRow(vec, schema))
    from repro.core.mltable import _chunk  # same partitioning policy

    return MLTable(_chunk(rows, table.num_partitions), schema)


def tf_idf(table: MLTable) -> MLTable:
    """TF-IDF over a count table (Fig. A2 ``tfIdf(...)``):
    tf = count / doc_total, smooth idf = log((1 + N) / (1 + df)) ≥ 0."""
    counts = np.asarray([r.to_floats() for r in table.rows()], dtype=np.float64)
    n_docs = counts.shape[0]
    doc_tot = np.maximum(counts.sum(axis=1, keepdims=True), 1.0)
    tf = counts / doc_tot
    df = (counts > 0).sum(axis=0)
    idf = np.log((1.0 + n_docs) / (1.0 + df))
    mat = (tf * idf).astype(np.float32)
    out = MLTable.from_numpy(mat, num_partitions=table.num_partitions,
                             names=table.schema.names)
    return out


def hashing_vectorizer(table: MLTable, num_features: int = 1024, n: int = 1,
                       column: int = 0) -> MLTable:
    """Feature hashing: stateless n-gram → bucket counts (streaming-friendly)."""
    col = table.schema.index_of(column) if isinstance(column, str) else column
    rows_out = []
    schema = Schema.of(*([ColumnType.SCALAR] * num_features))
    for row in table.rows():
        vec = [0.0] * num_features
        for gram in _grams(str(row[col]), n):
            vec[hash(gram) % num_features] += 1.0
        rows_out.append(MLRow(vec, schema))
    from repro.core.mltable import _chunk

    return MLTable(_chunk(rows_out, table.num_partitions), schema)
