"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E].

MoE: 16 experts, top-1 routing, plus a shared expert.  Attention is the
iRoPE interleave — 3 chunked-local (RoPE) layers per 1 global NoPE layer —
which is Llama 4's documented long-context recipe, so this arch *runs*
long_500k.  48L · d_model 5120 · 40H (GQA kv=8) · d_ff 8192 · vocab 202048.
"""
from repro.models.config import ArchConfig, BlockKind

FULL = ArchConfig(
    name="llama4-scout-17b-16e",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    pattern=(BlockKind.ATTN_CHUNKED, BlockKind.ATTN_CHUNKED,
             BlockKind.ATTN_CHUNKED, BlockKind.ATTN_NOPE),
    attn_chunk=8192,
    num_experts=16,
    top_k=1,
    shared_expert=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE = FULL.scaled(
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
    vocab_size=512, num_experts=4, attn_chunk=64, q_chunk=64,
    max_seq_len=512, dtype="float32", remat=False,
)
