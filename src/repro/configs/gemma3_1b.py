"""Gemma-3 1B [hf:google/gemma-3-1b-pt].

Dense with 5:1 local:global attention interleave and 128k context (the
sliding window keeps the KV footprint bounded → runs long_500k).  The
assigned depth 26 is not a multiple of the 6-layer (5L+1G) period; we encode
the same cadence as a 13-layer period — kind(i) = ATTN if i % 6 == 5 else
ATTN_LOCAL — giving globals at layers 6, 12, 19, 25 of 26 (Gemma 3's
"every 6th layer global" with depth 26).  d_model 1152 · 4H (GQA kv=1,
head_dim 256) · d_ff 6912 · vocab 262144 · window 512.
"""
from repro.models.config import ArchConfig, BlockKind

_PERIOD = tuple(
    BlockKind.ATTN if i % 6 == 5 else BlockKind.ATTN_LOCAL for i in range(13)
)

FULL = ArchConfig(
    name="gemma3-1b",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    d_ff=6912,
    vocab_size=262_144,
    head_dim=256,
    pattern=_PERIOD,
    probe_pattern=tuple(
        BlockKind.ATTN if i % 6 == 5 else BlockKind.ATTN_LOCAL
        for i in range(6)),
    window=512,
    rope_base=1_000_000.0,
    source="hf:google/gemma-3-1b-pt",
)

SMOKE = FULL.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=1, d_ff=256,
    vocab_size=512, head_dim=32, window=32, q_chunk=64, max_seq_len=512,
    dtype="float32", remat=False,
    pattern=(BlockKind.ATTN_LOCAL, BlockKind.ATTN),
)
