"""Granite-3 8B [hf:ibm-granite/granite-3.0-2b-base family, 8B point].

Dense GQA decoder: 40L · d_model 4096 · 32H (GQA kv=8) · d_ff 12800 ·
vocab 49155.  Pure full attention → long_500k skipped (DESIGN.md §skips).
"""
from repro.models.config import ArchConfig, BlockKind

FULL = ArchConfig(
    name="granite-3-8b",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49_155,
    pattern=(BlockKind.ATTN,),
    source="hf:ibm-granite/granite-3.0-2b-base",
)

SMOKE = FULL.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
    vocab_size=512, q_chunk=64, max_seq_len=512, dtype="float32", remat=False,
)
