"""Assigned-architecture configs (public-literature pool) + input shapes.

Each module exports ``FULL`` (the exact assigned config, exercised only via
the ShapeDtypeStruct dry-run) and ``SMOKE`` (a reduced same-family variant —
≤2-ish layers, d_model ≤ 512, ≤4 experts — run for real on CPU by the smoke
tests).  ``get_config(name)`` / ``get_smoke(name)`` look both up; the train
and serve launchers expose them as ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple

from repro.models.config import ArchConfig

__all__ = ["ARCH_IDS", "SHAPES", "InputShape", "get_config", "get_smoke",
           "shape_for"]

ARCH_IDS = (
    "llama4-scout-17b-16e",
    "mixtral-8x22b",
    "whisper-small",
    "granite-3-8b",
    "llava-next-34b",
    "qwen1.5-32b",
    "recurrentgemma-9b",
    "gemma3-1b",
    "mamba2-2.7b",
    "qwen2-1.5b",
)

_MODULES = {name: name.replace("-", "_").replace(".", "_") for name in ARCH_IDS}
# accept the assignment's exact spelling too
_ALIASES = {"llama4-scout-17b-a16e": "llama4-scout-17b-16e"}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


def _module(name: str):
    name = _ALIASES.get(name, name)
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _module(name).FULL


def get_smoke(name: str) -> ArchConfig:
    return _module(name).SMOKE


def shape_for(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {tuple(SHAPES)}")
    return SHAPES[name]
