"""Mamba-2 2.7B [arXiv:2405.21060].

Attention-free SSM using SSD (state-space duality): chunked dual form for
training/prefill, O(1) recurrent state for decode → runs long_500k
naturally.  64L · d_model 2560 · d_ff 0 (the SSD block is self-contained,
no MLP) · vocab 50280 · ssm_state N=128 · head_dim P=64 · expand 2
(d_inner 5120, 80 ssd heads).
"""
from repro.models.config import ArchConfig, BlockKind

FULL = ArchConfig(
    name="mamba2-2.7b",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    pattern=(BlockKind.SSD,),
    mlp_kind="none",
    ssm_state=128,
    ssd_head_dim=64,
    ssd_expand=2,
    ssd_chunk=256,
    use_rope=False,
    source="arXiv:2405.21060",
)

SMOKE = FULL.scaled(
    num_layers=2, d_model=128, num_heads=0, num_kv_heads=0, d_ff=0,
    vocab_size=512, ssm_state=16, ssd_head_dim=16, ssd_chunk=32,
    max_seq_len=512, dtype="float32", remat=False,
)
