"""LLaVA-NeXT 34B [hf:llava-hf/llava-v1.6-mistral-7b-hf family, 34B point].

VLM: the vision tower + anyres tiling projector are a STUB per the
assignment carve-out — ``input_specs`` supplies (B, 2880, d_model) patch
embeddings (5 anyres tiles × 576 patches) which are prepended to the text
tokens.  Language backbone: dense 60L · d_model 7168 · 56H (GQA kv=8) ·
d_ff 20480 · vocab 64000.  Full attention → long_500k skipped.
"""
from repro.models.config import ArchConfig, BlockKind

FULL = ArchConfig(
    name="llava-next-34b",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64_000,
    pattern=(BlockKind.ATTN,),
    vision_tokens=2880,           # 5 anyres tiles x 576 patches
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

SMOKE = FULL.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
    vocab_size=512, vision_tokens=16, q_chunk=64, max_seq_len=512,
    dtype="float32", remat=False,
)
