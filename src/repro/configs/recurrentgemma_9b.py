"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

Hybrid: RG-LRU recurrent blocks interleaved with local (sliding-window)
attention at 1 attention : 2 recurrent.  The assigned depth is 38, which a
pure (R,R,A) period cannot tile (38 % 3 ≠ 0); we encode the same cadence as
a 19-layer period — kind(i) = ATTN_LOCAL if i % 3 == 2 else RGLRU — i.e. the
RRA cycle with one extra R per 19 layers (12 A + 26 R over the full 38,
matching Griffin's "start and end on recurrent blocks").  Recurrence is
O(1)-state → runs long_500k.  d_model 4096 · 16H (GQA kv=1 for the local
attention) · d_ff 12288 · vocab 256000 · rnn width 4096 · window 2048.
"""
from repro.models.config import ArchConfig, BlockKind

_PERIOD = tuple(
    BlockKind.ATTN_LOCAL if i % 3 == 2 else BlockKind.RGLRU for i in range(19)
)

FULL = ArchConfig(
    name="recurrentgemma-9b",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    pattern=_PERIOD,
    probe_pattern=(BlockKind.RGLRU, BlockKind.RGLRU, BlockKind.ATTN_LOCAL),
    window=2048,
    rnn_width=4096,
    conv_width=4,
    source="arXiv:2402.19427",
)

SMOKE = FULL.scaled(
    num_layers=3, d_model=128, num_heads=4, num_kv_heads=1, d_ff=256,
    vocab_size=512, rnn_width=128, window=32, q_chunk=64, max_seq_len=512,
    dtype="float32", remat=False,
    pattern=(BlockKind.RGLRU, BlockKind.RGLRU, BlockKind.ATTN_LOCAL),
)
