"""Whisper-small [arXiv:2212.04356].

Encoder–decoder: 12L encoder over 1500 precomputed mel/conv frame embeddings
(the conv frontend is a STUB per the assignment carve-out — ``input_specs``
supplies (B, 1500, 768) frames), 12L decoder with cross-attention, LayerNorm,
GELU MLP, learned/sinusoidal absolute positions (no RoPE).  d_model 768 ·
12H (kv=12, i.e. MHA) · d_ff 3072 · vocab 51865.

long_500k is skipped (full-attention decoder, 448-token spec anyway) — see
DESIGN.md §skips.
"""
from repro.models.config import ArchConfig, BlockKind

FULL = ArchConfig(
    name="whisper-small",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    pattern=(BlockKind.ATTN,),
    encoder_layers=12,
    encoder_seq=1500,
    cross_attention=True,
    mlp_kind="gelu",
    norm_kind="layernorm",
    use_rope=False,
    learned_pos=True,
    max_seq_len=448,
    source="arXiv:2212.04356",
)

SMOKE = FULL.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
    vocab_size=512, encoder_layers=2, encoder_seq=64, q_chunk=64,
    max_seq_len=128, dtype="float32", remat=False,
)
