"""Qwen1.5-32B [hf:Qwen/Qwen1.5-0.5B family, 32B point].

Dense decoder with QKV bias and kv=40 (MHA-like: every q head has its own kv
head).  64L · d_model 5120 · 40H (kv=40) · d_ff 27392 · vocab 152064.
Full attention → long_500k skipped.
"""
from repro.models.config import ArchConfig, BlockKind

FULL = ArchConfig(
    name="qwen1.5-32b",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152_064,
    pattern=(BlockKind.ATTN,),
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)

SMOKE = FULL.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
    vocab_size=512, q_chunk=64, max_seq_len=512, dtype="float32", remat=False,
)
