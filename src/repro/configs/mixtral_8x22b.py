"""Mixtral-8x22B [arXiv:2401.04088].

8 experts, top-2 routing, sliding-window attention (every layer) — SWA makes
the arch sub-quadratic, so it runs long_500k.  56L · d_model 6144 · 48H
(GQA kv=8) · d_ff 16384 · vocab 32768.
"""
from repro.models.config import ArchConfig, BlockKind

FULL = ArchConfig(
    name="mixtral-8x22b",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32_768,
    pattern=(BlockKind.ATTN_LOCAL,),
    window=4096,
    num_experts=8,
    top_k=2,
    source="arXiv:2401.04088",
)

SMOKE = FULL.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
    vocab_size=512, num_experts=4, window=32, q_chunk=64,
    max_seq_len=512, dtype="float32", remat=False,
)
