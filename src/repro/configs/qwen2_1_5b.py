"""Qwen2-1.5B [arXiv:2407.10671].

Dense GQA decoder with QKV bias.  28L · d_model 1536 · 12H (GQA kv=2) ·
d_ff 8960 · vocab 151936.  Full attention → long_500k skipped.
"""
from repro.models.config import ArchConfig, BlockKind

FULL = ArchConfig(
    name="qwen2-1.5b",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    pattern=(BlockKind.ATTN,),
    qkv_bias=True,
    source="arXiv:2407.10671",
)

SMOKE = FULL.scaled(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
    vocab_size=512, q_chunk=64, max_seq_len=512, dtype="float32", remat=False,
)
