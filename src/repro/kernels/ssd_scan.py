"""Mamba-2 SSD chunk scan as a Pallas TPU kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060 §6): the paper's CUDA
implementation pipelines warp-level scans; on TPU the right decomposition is
the *chunked dual form* — the intra-chunk term is an (L, L) masked matmul
chain that maps straight onto the MXU, and the inter-chunk recurrence is a
sequential grid dimension whose (P, N) state lives in VMEM scratch (exactly
the flash-attention accumulator pattern, with a decaying state instead of a
softmax numerator).

Grid: (B·H, n_chunks), chunk axis innermost/"arbitrary" (sequential on TPU),
so the state never round-trips HBM between chunks.  Per grid step the
working set is L·(P + 2N) + L² + P·N floats — for the defaults (L=64,
P=64, N=128) about 100 KB, far under VMEM with room for double buffering.

Numerics match ``ref.ssd_chunk_scan_ref`` (fp32 throughout; the exponent
clamp keeps masked entries finite before the mask-multiply).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

__all__ = ["ssd_chunk_scan"]


def _ssd_kernel(la_ref, dx_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
                h_ref, *, L: int, P: int, N: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    la = la_ref[0, 0, 0].astype(jnp.float32)          # (L,)
    dx = dx_ref[0, 0, 0].astype(jnp.float32)          # (L, P)
    Bc = b_ref[0, 0].astype(jnp.float32)              # (L, N)
    Cc = c_ref[0, 0].astype(jnp.float32)              # (L, N)
    h = h_ref[...]                                    # (P, N)

    cum = jnp.cumsum(la)                              # (L,)
    # intra-chunk dual (attention-like) term
    CB = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (L, L)
    diff = cum[:, None] - cum[None, :]
    decay = jnp.exp(jnp.minimum(diff, 0.0))
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    M = CB * decay * (s_idx <= t_idx)
    y_intra = jax.lax.dot_general(M, dx, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (L, P)
    # inter-chunk: carried state contribution
    Ch = jax.lax.dot_general(Cc, h, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (L, P)
    y_inter = jnp.exp(cum)[:, None] * Ch
    y_ref[0, 0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = a_chunk·h + Σ_s w_tail(s)·dx(s)⊗B(s)
    w_tail = jnp.exp(cum[-1] - cum)                   # (L,)
    wdx = w_tail[:, None] * dx                        # (L, P)
    h_new = h * jnp.exp(cum[-1]) + jax.lax.dot_general(
        wdx, Bc, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    h_ref[...] = h_new

    @pl.when(ci == nc - 1)
    def _final():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(
    log_a: jnp.ndarray,   # (B, H, S)
    dx: jnp.ndarray,      # (B, H, S, P)
    Bm: jnp.ndarray,      # (B, S, N)
    Cm: jnp.ndarray,      # (B, S, N)
    h0: Optional[jnp.ndarray] = None,   # (B, H, P, N)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,H,S,P) fp32, h_final (B,H,P,N) fp32)."""
    B, H, S, P = dx.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    if S % L:
        raise ValueError(f"S={S} must divide chunk {L}")
    C = S // L
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    la_c = log_a.reshape(B, H, C, L)
    dx_c = dx.reshape(B, H, C, L, P)
    B_c = Bm.reshape(B, C, L, N)
    C_c = Cm.reshape(B, C, L, N)

    kernel = functools.partial(_ssd_kernel, L=L, P=P, N=N)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=(B * H, C),
        in_specs=[
            pl.BlockSpec((1, 1, 1, L), lambda bh, c: (bh // H, bh % H, c, 0)),
            pl.BlockSpec((1, 1, 1, L, P),
                         lambda bh, c: (bh // H, bh % H, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda bh, c: (bh // H, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda bh, c: (bh // H, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bh, c: (bh // H, bh % H, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, P),
                         lambda bh, c: (bh // H, bh % H, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bh, c: (bh // H, bh % H, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, C, L, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(la_c, dx_c, B_c, C_c, h0)
    return y.reshape(B, H, S, P), h_fin
