"""Fused logistic-regression gradient — the paper's §IV-A inner loop — as
two Pallas TPU kernels.

The gradient ∇f = Xᵀ(σ(Xw) − y) has a true data dependency (the residual z
needs the *full-row* margin before any column of the second pass can start),
so with feature tiling the minimum traffic is two streamed passes over X:

  pass 1 (margin):    z = σ(Xw) − y        grid (row-block, col-block),
                      margin accumulated in the output block across the
                      col-block axis; σ and the label subtraction fused into
                      the final col step — z never round-trips HBM unscaled.
  pass 2 (gradient):  g = Xᵀz              grid (col-block, row-block),
                      accumulated across the row-block axis.

A naive jnp implementation materializes the margin and residual in HBM and
reads X twice anyway — the kernels win by (a) fusing σ/subtract into the
matmul epilogue and (b) fp32 accumulation with bf16 streaming of X, halving
the X bytes for the paper's 160K-feature regime (the memory-bound term; see
EXPERIMENTS.md §Perf).

Block shapes default to (256 rows × 512 features): X tile 256·512·2B = 256KB
in VMEM, w/z/g tiles trivially small — comfortably inside the ~16MB VMEM
budget with double buffering, and both matmul dims are multiples of the
128-lane MXU tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

__all__ = ["logreg_margin", "logreg_xt_z", "logreg_grad_pallas"]


def _margin_kernel(x_ref, w_ref, y_ref, z_ref, acc_ref):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)       # (BR, BC)
    w = w_ref[...].astype(jnp.float32)       # (BC, 1)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _epilogue():
        y = y_ref[...].astype(jnp.float32)   # (BR, 1)
        z_ref[...] = (jax.nn.sigmoid(acc_ref[...]) - y).astype(z_ref.dtype)


def _xtz_kernel(x_ref, z_ref, g_ref, acc_ref):
    ri = pl.program_id(1)
    nr = pl.num_programs(1)

    @pl.when(ri == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)       # (BR, BC)
    z = z_ref[...].astype(jnp.float32)       # (BR, 1)
    acc_ref[...] += jax.lax.dot_general(
        x, z, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ri == nr - 1)
    def _write():
        g_ref[...] = acc_ref[...].astype(g_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def logreg_margin(X, y, w, *, block_rows=256, block_cols=512, interpret=False):
    """z = σ(Xw) − y.  X: (n, d), y: (n,), w: (d,) → z: (n,) fp32."""
    n, d = X.shape
    br = min(block_rows, n)
    bc = min(block_cols, d)
    if n % br or d % bc:
        raise ValueError(f"(n,d)=({n},{d}) must divide blocks ({br},{bc})")
    z = pl.pallas_call(
        _margin_kernel,
        grid=(n // br, d // bc),
        in_specs=[
            pl.BlockSpec((br, bc), lambda ri, ci: (ri, ci)),
            pl.BlockSpec((bc, 1), lambda ri, ci: (ci, 0)),
            pl.BlockSpec((br, 1), lambda ri, ci: (ri, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda ri, ci: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((br, 1), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(X, w.reshape(d, 1), y.reshape(n, 1))
    return z[:, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def logreg_xt_z(X, z, *, block_rows=256, block_cols=512, interpret=False):
    """g = Xᵀz.  X: (n, d), z: (n,) → g: (d,) fp32."""
    n, d = X.shape
    br = min(block_rows, n)
    bc = min(block_cols, d)
    if n % br or d % bc:
        raise ValueError(f"(n,d)=({n},{d}) must divide blocks ({br},{bc})")
    g = pl.pallas_call(
        _xtz_kernel,
        grid=(d // bc, n // br),
        in_specs=[
            pl.BlockSpec((br, bc), lambda ci, ri: (ri, ci)),
            pl.BlockSpec((br, 1), lambda ci, ri: (ri, 0)),
        ],
        out_specs=pl.BlockSpec((bc, 1), lambda ci, ri: (ci, 0)),
        out_shape=jax.ShapeDtypeStruct((d, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bc, 1), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(X, z.reshape(n, 1))
    return g[:, 0]


def logreg_grad_pallas(X, y, w, *, block_rows=256, block_cols=512,
                       interpret=False):
    """Full fused gradient: ∇f = Xᵀ(σ(Xw) − y), fp32, cast to w.dtype."""
    z = logreg_margin(X, y, w, block_rows=block_rows, block_cols=block_cols,
                      interpret=interpret)
    g = logreg_xt_z(X, z, block_rows=block_rows, block_cols=block_cols,
                    interpret=interpret)
    return g.astype(w.dtype)
