"""Public jit'd wrappers for the Pallas kernels.

Each wrapper validates shapes, auto-selects ``interpret=True`` off-TPU (this
container is CPU-only; the TPU is the deployment target), and falls back to
the pure-jnp oracle for shapes the kernels' block constraints cannot tile
(non-divisible sequence lengths etc.) so callers never have to branch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.kmeans_assign import kmeans_assign_pallas
from repro.kernels.logreg_grad import logreg_grad_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_chunk_scan as _ssd_scan

__all__ = ["flash_attention", "kmeans_assign", "logreg_grad", "rmsnorm",
           "ssd_chunk_scan", "on_tpu"]


@functools.lru_cache(None)
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return not on_tpu()


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, chunk: Optional[int] = None,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128) -> jnp.ndarray:
    """(B, H, Sq, hd) x (B, KV, Sk, hd)² -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    if v.shape != k.shape:
        raise ValueError(f"k/v shape mismatch: {k.shape} vs {v.shape}")
    if H % KV:
        raise ValueError(f"H={H} not divisible by KV={KV}")
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    if Sq % bq or Sk % bk:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       chunk=chunk, scale=scale)
    return _flash(q, k, v, causal=causal, window=window, chunk=chunk,
                  scale=scale, block_q=bq, block_k=bk, interpret=_interp())


def kmeans_assign(X, C, *, block_rows: int = 256,
                  block_cols: int = 512) -> jnp.ndarray:
    """Nearest-centroid assignment argmin_c ||x − c||² (fused pairwise
    distances).  X: (n, d), C: (k, d) → (n,) int32."""
    if X.ndim != 2 or C.ndim != 2 or X.shape[1] != C.shape[1]:
        raise ValueError(f"shape mismatch: X{X.shape} C{C.shape}")
    n, d = X.shape
    br = min(block_rows, n)
    bc = min(block_cols, d)
    if n % br or d % bc:
        return ref.kmeans_assign_ref(X, C)
    return kmeans_assign_pallas(X, C, block_rows=br, block_cols=bc,
                                interpret=_interp())


def logreg_grad(X, y, w, *, block_rows: int = 256, block_cols: int = 512) -> jnp.ndarray:
    """∇f = Xᵀ(σ(Xw) − y) fused.  X: (n,d), y: (n,), w: (d,)."""
    n, d = X.shape
    if y.shape != (n,) or w.shape != (d,):
        raise ValueError(f"shape mismatch: X{X.shape} y{y.shape} w{w.shape}")
    br = min(block_rows, n)
    bc = min(block_cols, d)
    if n % br or d % bc:
        return ref.logreg_grad_ref(X, y, w)
    return logreg_grad_pallas(X, y, w, block_rows=br, block_cols=bc,
                              interpret=_interp())


def rmsnorm(x, weight, *, eps: float = 1e-6, block_rows: int = 64) -> jnp.ndarray:
    """RMSNorm over the last dim.  x: (..., d), weight: (d,)."""
    if weight.shape != (x.shape[-1],):
        raise ValueError(f"weight {weight.shape} vs x feature dim {x.shape[-1]}")
    return rmsnorm_pallas(x, weight, eps=eps, block_rows=block_rows,
                          interpret=_interp())


def ssd_chunk_scan(log_a, dx, Bm, Cm, h0=None, *, chunk: int = 64):
    """Mamba-2 SSD chunked scan.  log_a: (B,H,S), dx: (B,H,S,P),
    Bm/Cm: (B,S,N) → (y (B,H,S,P), h_final (B,H,P,N))."""
    B, H, S, P = dx.shape
    if log_a.shape != (B, H, S) or Bm.shape[:2] != (B, S):
        raise ValueError(f"shape mismatch: log_a{log_a.shape} dx{dx.shape} "
                         f"Bm{Bm.shape}")
    if S % min(chunk, S):
        return ref.ssd_chunk_scan_ref(log_a, dx, Bm, Cm, h0, chunk=S)
    return _ssd_scan(log_a, dx, Bm, Cm, h0, chunk=chunk, interpret=_interp())
