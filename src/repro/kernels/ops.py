"""Public jit'd wrappers for the Pallas kernels.

Each wrapper validates shapes, auto-selects ``interpret=True`` off-TPU (this
container is CPU-only; the TPU is the deployment target), and falls back to
the pure-jnp oracle for shapes the kernels' block constraints cannot tile
(non-divisible sequence lengths etc.) so callers never have to branch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.kmeans_assign import kmeans_assign_pallas
from repro.kernels.logreg_grad import logreg_grad_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_chunk_scan as _ssd_scan

__all__ = ["flash_attention", "kmeans_assign", "logreg_grad", "quant_matmul",
           "quantize_rows", "rmsnorm", "ssd_chunk_scan", "on_tpu"]


@functools.lru_cache(None)
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return not on_tpu()


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, chunk: Optional[int] = None,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128) -> jnp.ndarray:
    """(B, H, Sq, hd) x (B, KV, Sk, hd)² -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    if v.shape != k.shape:
        raise ValueError(f"k/v shape mismatch: {k.shape} vs {v.shape}")
    if H % KV:
        raise ValueError(f"H={H} not divisible by KV={KV}")
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    if Sq % bq or Sk % bk:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       chunk=chunk, scale=scale)
    return _flash(q, k, v, causal=causal, window=window, chunk=chunk,
                  scale=scale, block_q=bq, block_k=bk, interpret=_interp())


def kmeans_assign(X, C, *, block_rows: int = 256,
                  block_cols: int = 512) -> jnp.ndarray:
    """Nearest-centroid assignment argmin_c ||x − c||² (fused pairwise
    distances).  X: (n, d), C: (k, d) → (n,) int32."""
    if X.ndim != 2 or C.ndim != 2 or X.shape[1] != C.shape[1]:
        raise ValueError(f"shape mismatch: X{X.shape} C{C.shape}")
    n, d = X.shape
    br = min(block_rows, n)
    bc = min(block_cols, d)
    if n % br or d % bc:
        return ref.kmeans_assign_ref(X, C)
    return kmeans_assign_pallas(X, C, block_rows=br, block_cols=bc,
                                interpret=_interp())


def logreg_grad(X, y, w, *, block_rows: int = 256, block_cols: int = 512) -> jnp.ndarray:
    """∇f = Xᵀ(σ(Xw) − y) fused.  X: (n,d), y: (n,), w: (d,)."""
    n, d = X.shape
    if y.shape != (n,) or w.shape != (d,):
        raise ValueError(f"shape mismatch: X{X.shape} y{y.shape} w{w.shape}")
    br = min(block_rows, n)
    bc = min(block_cols, d)
    if n % br or d % bc:
        return ref.logreg_grad_ref(X, y, w)
    return logreg_grad_pallas(X, y, w, block_rows=br, block_cols=bc,
                              interpret=_interp())


def quantize_rows(x):
    """Symmetric per-row int8 quantization: returns ``(xq, scale)`` with
    ``xq`` int8 of ``x.shape`` and ``scale`` fp32 of ``x.shape[:-1]`` such
    that ``xq * scale[..., None] ≈ x``.  The ``1e-8`` floor keeps all-zero
    rows from dividing by zero (they quantize to zeros with a tiny scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0 + 1e-8
    xq = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return xq, scale


def quant_matmul(xq, x_scale, wq, w_scale, *, block_m: int = 256,
                 block_n: int = 256, block_k: int = 512) -> jnp.ndarray:
    """Quantized int8×int8 matmul with fp32 dequantizing epilogue.
    xq: (M, K) int8, x_scale: (M,), wq: (K, N) int8, w_scale: (N,) → (M, N)
    fp32 equal to ``(xq·wq) * x_scale[:,None] * w_scale[None,:]``.

    On TPU with tilable shapes this is the Pallas kernel (int32 MXU
    accumulation, bit-exact vs ``ref.quant_matmul_ref``).  Everywhere else —
    including the CPU serving path — the dequantized product is taken in
    fp32, which is mathematically the same sum and exact as long as every
    int32 partial fits an fp32 mantissa (K·127² < 2²⁴, i.e. K ≲ 1000; true
    for every config in this repo).  We do NOT run the interpret-mode kernel
    here: per-element Pallas interpretation is orders of magnitude too slow
    for a decode hot loop (same policy as ``use_flash_kernel`` off-TPU)."""
    M, K = xq.shape
    K2, N = wq.shape
    if K != K2 or x_scale.shape != (M,) or w_scale.shape != (N,):
        raise ValueError(f"shape mismatch: xq{xq.shape} wq{wq.shape} "
                         f"x_scale{x_scale.shape} w_scale{w_scale.shape}")
    bm = min(block_m, M)
    bn = min(block_n, N)
    bk = min(block_k, K)
    if on_tpu() and not (M % bm or N % bn or K % bk):
        return quant_matmul_pallas(xq, x_scale, wq, w_scale,
                                   block_m=bm, block_n=bn, block_k=bk)
    acc = jnp.dot(xq.astype(jnp.float32), wq.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return (acc * x_scale.astype(jnp.float32)[:, None]
            * w_scale.astype(jnp.float32)[None, :])


def rmsnorm(x, weight, *, eps: float = 1e-6, block_rows: int = 64) -> jnp.ndarray:
    """RMSNorm over the last dim.  x: (..., d), weight: (d,)."""
    if weight.shape != (x.shape[-1],):
        raise ValueError(f"weight {weight.shape} vs x feature dim {x.shape[-1]}")
    return rmsnorm_pallas(x, weight, eps=eps, block_rows=block_rows,
                          interpret=_interp())


def ssd_chunk_scan(log_a, dx, Bm, Cm, h0=None, *, chunk: int = 64):
    """Mamba-2 SSD chunked scan.  log_a: (B,H,S), dx: (B,H,S,P),
    Bm/Cm: (B,S,N) → (y (B,H,S,P), h_final (B,H,P,N))."""
    B, H, S, P = dx.shape
    if log_a.shape != (B, H, S) or Bm.shape[:2] != (B, S):
        raise ValueError(f"shape mismatch: log_a{log_a.shape} dx{dx.shape} "
                         f"Bm{Bm.shape}")
    if S % min(chunk, S):
        return ref.ssd_chunk_scan_ref(log_a, dx, Bm, Cm, h0, chunk=S)
    return _ssd_scan(log_a, dx, Bm, Cm, h0, chunk=chunk, interpret=_interp())
