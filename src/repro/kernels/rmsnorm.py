"""RMSNorm as a Pallas TPU kernel: one HBM read of x, fp32 statistics.

Grid walks row blocks; each step holds a (block_rows, d) tile plus the (d,)
weight in VMEM.  The unfused jnp version reads x twice (once for the mean of
squares, once for the scale-multiply) when XLA fails to fuse across the
reduction; the kernel guarantees the single pass.  Default tile:
64 rows × d ≤ 8192 → 2MB fp32, well under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

__all__ = ["rmsnorm_pallas"]


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)          # (BR, d)
    w = w_ref[...].astype(jnp.float32)          # (1, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(x: jnp.ndarray, weight: jnp.ndarray, *, eps: float = 1e-6,
                   block_rows: int = 64, interpret: bool = False) -> jnp.ndarray:
    """x: (..., d), weight: (d,).  Returns same shape/dtype as x."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    if rows % br:
        br = 1  # always divides
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda ri: (ri, 0)),
            pl.BlockSpec((1, d), lambda ri: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda ri: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, weight.reshape(1, d))
    return out.reshape(orig_shape)
