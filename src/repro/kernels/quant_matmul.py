"""Quantized int8 matmul as a Pallas TPU kernel — the serving fast path.

The decode hot loop is a stack of skinny matmuls (activations (B·S, K)
against projection weights (K, N)).  At serving time the weights are
static, so they ride quantized: symmetric per-output-channel int8
(``models/layers/quant.quantize_weight``), and the activation rows are
quantized on the fly (``ops.quantize_rows``).  The kernel computes

    out = (xq · wq) * x_scale[:, None] * w_scale[None, :]

with the product accumulated on the MXU in **int32** — integer addition is
exact whatever the K-grid order, so the kernel is *bitwise* equal to
``ref.quant_matmul_ref`` (not merely close), and the fp32 epilogue applies
both scale vectors in the oracle's operand order.  int8 operands draw
half the HBM bandwidth of bf16 and a quarter of fp32 — on decode shapes
(M small, K·N the traffic) the weight stream IS the roofline, which is
the whole point of the quantized path.

Same two-pass discipline and accumulator layout as ``kmeans_assign`` /
``logreg_grad`` next door: 3-D grid (rows, cols, k-blocks) with the
k-axis innermost ("arbitrary"), an (BM, BN) int32 VMEM scratch
accumulator initialized at the first k-step, and the dequantizing
epilogue fused into the last k-step so the int32 partials never
round-trip HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

__all__ = ["quant_matmul_pallas"]


def _qmm_kernel(xq_ref, wq_ref, xs_ref, ws_ref, out_ref, acc_ref):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        xq_ref[...], wq_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(ki == nk - 1)
    def _epilogue():
        out_ref[...] = (acc_ref[...].astype(jnp.float32)
                        * xs_ref[...] * ws_ref[...])


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def quant_matmul_pallas(xq, xs, wq, ws, *, block_m=256, block_n=256,
                        block_k=512, interpret=False):
    """Quantized matmul.  xq: (M, K) int8, xs: (M,) fp32 row scales,
    wq: (K, N) int8, ws: (N,) fp32 column scales → (M, N) fp32."""
    M, K = xq.shape
    K2, N = wq.shape
    if K != K2 or xs.shape != (M,) or ws.shape != (N,):
        raise ValueError(f"shape mismatch: xq{xq.shape} wq{wq.shape} "
                         f"xs{xs.shape} ws{ws.shape}")
    bm = min(block_m, M)
    bn = min(block_n, N)
    bk = min(block_k, K)
    if M % bm or N % bn or K % bk:
        raise ValueError(f"(M,N,K)=({M},{N},{K}) must divide blocks "
                         f"({bm},{bn},{bk})")
    xs2 = xs.astype(jnp.float32)[:, None]              # (M, 1)
    ws2 = ws.astype(jnp.float32)[None, :]              # (1, N)
    return pl.pallas_call(
        _qmm_kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((bm, 1), lambda mi, ni, ki: (mi, 0)),
            pl.BlockSpec((1, bn), lambda mi, ni, ki: (0, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xq, wq, xs2, ws2)
