"""Flash attention as a Pallas TPU kernel (online-softmax, VMEM-tiled).

TPU adaptation of the Flash-Attention-2 schedule: the (Sq, Sk) logits matrix
is never materialized in HBM.  The grid walks (batch·q-head, q-block, k-block)
with the k-block axis innermost; running max/denominator/accumulator live in
VMEM scratch and the output block is written once, on the final k step.  All
matmuls hit the MXU with fp32 accumulation; block shapes are multiples of 128
on the lane dim so the MXU tiles are hardware-aligned.

Mask variants (static Python switches — each compiles to its own kernel):
  causal              k_pos ≤ q_pos
  sliding window W    q_pos − k_pos < W      (mixtral, gemma3, recurrentgemma)
  chunked C           same attention chunk   (llama4 iRoPE)

GQA: the kv-head index for block fetch is derived from the fused (b·H + h)
grid coordinate, so kv tensors stay un-broadcast in HBM (memory term wins vs
jnp.repeat — see EXPERIMENTS.md §Perf).

Validated against ``ref.flash_attention_ref`` in interpret mode (this
container is CPU-only; the TPU is the deployment target).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

__all__ = ["flash_attention"]

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  chunk: Optional[int], block_q: int, block_k: int,
                  sq: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (BQ, hd)
    k = k_ref[0, 0].astype(jnp.float32)               # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)               # (BK, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # absolute positions (prefill convention: queries are the last sq of sk)
    pos_q = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + (sk - sq)
    pos_k = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_q - pos_k < window
    if chunk is not None:
        mask &= (pos_q // chunk) == (pos_k // chunk)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (BQ, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)     # (BQ, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0)=1
    s_exp = jnp.where((s > NEG_INF / 2), jnp.exp(s - m_new), 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)

    l_new = alpha * l_ref[...] + jnp.sum(s_exp, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        s_exp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "chunk", "scale", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jnp.ndarray,            # (B, H, Sq, hd)
    k: jnp.ndarray,            # (B, KV, Sk, hd)
    v: jnp.ndarray,            # (B, KV, Sk, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    if H % KV:
        raise ValueError(f"q heads {H} not divisible by kv heads {KV}")
    groups = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    if Sq % bq or Sk % bk:
        raise ValueError(f"seq lens ({Sq},{Sk}) must divide blocks ({bq},{bk})")
    scale = hd ** -0.5 if scale is None else scale

    grid = (B * H, Sq // bq, Sk // bk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, chunk=chunk,
        block_q=bq, block_k=bk, sq=Sq, sk=Sk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bh, qi, ki: (bh // H, (bh % H) // groups, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bh, qi, ki: (bh // H, (bh % H) // groups, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom l
            pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(
        q.reshape(B, H, Sq, hd),
        k,
        v,
    )
