"""Pallas TPU kernels for the compute hot spots (validated interpret=True).

    flash_attention — online-softmax attention (causal/SWA/chunked, GQA)
    kmeans_assign   — fused pairwise-distance Lloyd assignment argmin
    logreg_grad     — the paper's §IV-A fused gradient  Xᵀ(σ(Xw) − y)
    rmsnorm         — single-pass fused RMSNorm
    ssd_scan        — Mamba-2 SSD chunked dual-form scan (state in VMEM)

``ops`` holds the public wrappers; ``ref`` the pure-jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
