"""Fused pairwise-distance k-means assignment as a Pallas TPU kernel.

The Lloyd assignment hot path (``core/algorithms/kmeans._local_stats``)
computes, for every row x and centroid c, ``argmin_c ||x − c||²``.  The
naive jnp form materializes the full (rows, k, d) difference tensor (or at
best the (rows, k) distance matrix after an (n,k,d) broadcast) in HBM.
The kernel streams X once and never leaves VMEM:

    ||x − c||² = ||x||² − 2·x·c + ||c||²   and   ||x||² is constant per row,

so the argmin needs only the (rows, k) relative score ``||c||² − 2·x·c``:
one MXU matmul per (row-block × feature-block) tile, accumulated in fp32
scratch across the feature grid axis, with the centroid-norm add and the
argmin fused into the final feature step — the (rows, k) scores never
round-trip HBM.  Same two-pass discipline and block shapes as the logreg
gradient kernel next door (256×512 tiles: X tile 256·512·4B = 512KB fp32
in VMEM, scores 256·k trivially small for practical k).

Centroids ride along transposed, (d, k), so the matmul contracts the
feature-block axis directly; their norms are precomputed once by the
wrapper (O(k·d), negligible next to the O(n·d·k) assignment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

__all__ = ["kmeans_assign_pallas"]


def _assign_kernel(x_ref, ct_ref, cn_ref, out_ref, acc_ref):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)        # (BR, BC)
    ct = ct_ref[...].astype(jnp.float32)      # (BC, k)
    acc_ref[...] += jax.lax.dot_general(
        x, ct, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _epilogue():
        score = cn_ref[...] - 2.0 * acc_ref[...]          # (BR, k)
        best = jnp.min(score, axis=1, keepdims=True)
        # first index attaining the min (ties → lowest index, matching
        # jnp.argmin); TPU needs ≥2-D iota, hence broadcasted_iota
        idx = jax.lax.broadcasted_iota(jnp.int32, score.shape, 1)
        k = score.shape[1]
        out_ref[...] = jnp.min(jnp.where(score <= best, idx, k),
                               axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols",
                                             "interpret"))
def kmeans_assign_pallas(X, C, *, block_rows=256, block_cols=512,
                        interpret=False):
    """Nearest-centroid assignment.  X: (n, d), C: (k, d) → (n,) int32."""
    n, d = X.shape
    k = C.shape[0]
    br = min(block_rows, n)
    bc = min(block_cols, d)
    if n % br or d % bc:
        raise ValueError(f"(n,d)=({n},{d}) must divide blocks ({br},{bc})")
    ct = C.T.astype(jnp.float32)                           # (d, k)
    cn = jnp.sum(ct * ct, axis=0, keepdims=True)           # (1, k)
    out = pl.pallas_call(
        _assign_kernel,
        grid=(n // br, d // bc),
        in_specs=[
            pl.BlockSpec((br, bc), lambda ri, ci: (ri, ci)),
            pl.BlockSpec((bc, k), lambda ri, ci: (ci, 0)),
            pl.BlockSpec((1, k), lambda ri, ci: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda ri, ci: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((br, k), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(X, ct, cn)
    return out[:, 0]
