"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel test sweeps shapes/dtypes and
asserts ``allclose(kernel(interpret=True), ref)``.  They are also the CPU
fallback path used by the layers when no TPU is present.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "kmeans_assign_ref", "logreg_grad_ref",
           "quant_matmul_ref", "rmsnorm_ref", "ssd_chunk_scan_ref"]

NEG_INF = -2.0e38


def flash_attention_ref(
    q: jnp.ndarray,            # (B, H, Sq, hd)
    k: jnp.ndarray,            # (B, KV, Sk, hd)
    v: jnp.ndarray,            # (B, KV, Sk, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,     # sliding-window span (None = global)
    chunk: Optional[int] = None,      # chunked-local attention span
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Naive attention with fp32 softmax — the oracle for the flash kernel."""
    B, H, Sq, hd = q.shape
    KV = k.shape[1]
    groups = H // KV
    if groups > 1:
        k = jnp.repeat(k, groups, axis=1)
        v = jnp.repeat(v, groups, axis=1)
    scale = hd ** -0.5 if scale is None else scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    Sk = k.shape[2]
    # prefill convention: query i sits at absolute position (Sk - Sq + i)
    pos_q = jnp.arange(Sq) + (Sk - Sq)
    pos_k = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= pos_k[None, :] <= pos_q[:, None]
    if window is not None:
        mask &= pos_q[:, None] - pos_k[None, :] < window
    if chunk is not None:
        mask &= (pos_q[:, None] // chunk) == (pos_k[None, :] // chunk)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def kmeans_assign_ref(X: jnp.ndarray, C: jnp.ndarray) -> jnp.ndarray:
    """Nearest-centroid assignment: argmin_c ||x − c||².  X: (n, d),
    C: (k, d) → (n,) int32.

    Computed in the kernel's expanded form — ``||x||²`` is constant per row,
    so ``argmin_c (||c||² − 2·x·c)`` is the same assignment — with the same
    fp32 matmul accumulation, making this the *exact* oracle for the Pallas
    kernel (bitwise-equal scores, not merely the same argmin on
    well-separated data)."""
    Xf = X.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    score = jnp.sum(Cf * Cf, axis=1)[None, :] - 2.0 * (Xf @ Cf.T)
    return jnp.argmin(score, axis=1).astype(jnp.int32)


def quant_matmul_ref(xq: jnp.ndarray, x_scale: jnp.ndarray,
                     wq: jnp.ndarray, w_scale: jnp.ndarray) -> jnp.ndarray:
    """Symmetric int8×int8 matmul with per-row/per-column scales — the
    *exact* oracle for the Pallas quantized-matmul kernel.

    xq: (M, K) int8, x_scale: (M,) fp32 (row scales of the activation);
    wq: (K, N) int8, w_scale: (N,) fp32 (output-channel scales).  Returns
    ``(xq · wq) * x_scale[:, None] * w_scale[None, :]`` in fp32.  The
    accumulation is *integer* (int32, exact — addition order cannot change
    the sum), and the epilogue multiplies in the same operand order as the
    kernel, so the kernel must match this bitwise, not merely to fp
    tolerance (asserted in ``tests/test_quant_kernels.py``)."""
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32)
            * x_scale.astype(jnp.float32)[:, None]
            * w_scale.astype(jnp.float32)[None, :])


def logreg_grad_ref(X: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. (1): ∇f = Xᵀ(σ(Xw) − y).  X: (n, d), y: (n,), w: (d,)."""
    margin = X.astype(jnp.float32) @ w.astype(jnp.float32)
    z = jax.nn.sigmoid(margin) - y.astype(jnp.float32)
    return (X.astype(jnp.float32).T @ z).astype(w.dtype)


def ssd_chunk_scan_ref(
    log_a: jnp.ndarray,   # (B, H, S) per-step log decay  (≤ 0)
    dx: jnp.ndarray,      # (B, H, S, P) Δ·x
    Bm: jnp.ndarray,      # (B, S, N) input projections (shared across heads)
    Cm: jnp.ndarray,      # (B, S, N) output projections
    h0: Optional[jnp.ndarray] = None,   # (B, H, P, N) initial state
    *,
    chunk: int = 64,
):
    """Mamba-2 SSD chunked dual form (arXiv:2405.21060) — the oracle for the
    Pallas kernel.  Returns (y (B,H,S,P), h_final (B,H,P,N))."""
    B, H, S, P = dx.shape
    N = Bm.shape[-1]
    L = chunk
    assert S % L == 0
    C = S // L
    la = log_a.reshape(B, H, C, L).astype(jnp.float32)
    dxc = dx.reshape(B, H, C, L, P).astype(jnp.float32)
    Bc = Bm.reshape(B, C, L, N).astype(jnp.float32)
    Cc = Cm.reshape(B, C, L, N).astype(jnp.float32)
    h = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    causal = jnp.tril(jnp.ones((L, L), bool))

    ys = []
    for c in range(C):
        cum = jnp.cumsum(la[:, :, c], axis=-1)                 # (B,H,L)
        CB = jnp.einsum("btn,bsn->bts", Cc[:, c], Bc[:, c])    # (B,L,L)
        decay = jnp.exp(jnp.minimum(cum[:, :, :, None] - cum[:, :, None, :], 0.0))
        M = CB[:, None] * decay * causal[None, None]
        y_intra = jnp.einsum("bhts,bhsp->bhtp", M, dxc[:, :, c])
        y_inter = jnp.exp(cum)[..., None] * jnp.einsum(
            "btn,bhpn->bhtp", Cc[:, c], h)
        w_tail = jnp.exp(cum[:, :, -1:] - cum)                 # (B,H,L)
        h = h * jnp.exp(cum[:, :, -1])[..., None, None] + jnp.einsum(
            "bhs,bhsp,bsn->bhpn", w_tail, dxc[:, :, c], Bc[:, c])
        ys.append(y_intra + y_inter)
    y = jnp.stack(ys, axis=2).reshape(B, H, S, P)
    return y, h


def rmsnorm_ref(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with fp32 statistics: x * rsqrt(mean(x²)+eps) * w."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(x.dtype)
