"""Logical-axis → mesh-axis mapping with divisibility fallback.

Parameters are annotated with *logical* axes at init (see
``repro.models.params``).  One rule table maps logical names to mesh axes;
if a tensor dimension is not divisible by the mapped axis size, the mapping
is dropped for that dimension (recorded for diagnostics) instead of failing —
this is what makes ONE init work for head counts like 40 or 12 on a 16-way
"model" axis (the weight then relies on its FSDP "data"-axis dim for
storage; see DESIGN.md §5).

Rule summary (training defaults):
    tensor-parallel  → "model":  ffn, heads, kv_heads, vocab, experts, rnn,
                                  ssd_heads
    FSDP storage     → "data":   embed
    replicated       →  None:    head, state, layers, conv taps, biases
    activations      → batch: ("pod","data"), seq: None
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "DEFAULT_RULES", "logical_to_spec", "shardings_for",
           "constrain"]

AxisLeaf = Tuple[Optional[str], ...]


def _is_axes_leaf(x: Any) -> bool:
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical name -> mesh axis (or tuple of mesh axes)."""
    table: Tuple[Tuple[str, Any], ...] = (
        ("ffn", "model"),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("vocab", "model"),
        ("experts", "model"),
        ("rnn", "model"),
        ("ssd_heads", "model"),
        ("embed", "data"),        # FSDP/ZeRO-3 storage axis
        ("expert_embed", "data"),
        ("batch", ("pod", "data")),
        ("kv_seq", "data"),       # context-parallel long decode
    )

    def lookup(self, name: Optional[str]) -> Any:
        if name is None:
            return None
        for k, v in self.table:
            if k == name:
                return v
        return None

    def replace(self, **updates: Any) -> "ShardingRules":
        table = dict(self.table)
        table.update(updates)
        return ShardingRules(tuple(table.items()))

    def without(self, *names: str) -> "ShardingRules":
        return ShardingRules(tuple((k, v) for k, v in self.table if k not in names))


DEFAULT_RULES = ShardingRules()

# Serving rules (§Perf hillclimb H1).  Two findings from the decode HLO:
#   (a) dropping the FSDP mapping ("embed"→data) alone does NOT remove the
#       dominant collective — GSPMD was re-gathering the KV cache itself
#       (f32-upcast, kv_heads-partitioned) every step (H1a, refuted);
#   (b) the winning layout shards the cache *sequence* over the otherwise
#       idle "model" axis: attention becomes a sharded softmax reduction
#       (flash-decode expressed declaratively) whose collectives are
#       (B, H, 1)-sized partials instead of cache-sized gathers.
SERVE_RULES = (DEFAULT_RULES
               .without("embed", "expert_embed")
               .replace(kv_seq=("data", "model")))


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def logical_to_spec(axes: AxisLeaf, shape: Tuple[int, ...], mesh: Mesh,
                    rules: ShardingRules,
                    dropped: Optional[List[str]] = None) -> P:
    """Map one tensor's logical axes to a PartitionSpec, dropping any mapping
    whose dimension is not divisible by the mesh axis size."""
    sizes = _axis_sizes(mesh)
    spec: List[Any] = []
    used: set = set()
    for dim, name in zip(shape, axes):
        target = rules.lookup(name)
        if target is None:
            spec.append(None)
            continue
        targets = target if isinstance(target, tuple) else (target,)
        targets = tuple(t for t in targets if t in sizes and t not in used)
        total = int(np.prod([sizes[t] for t in targets])) if targets else 0
        if targets and dim % max(total, 1) == 0 and total > 0:
            spec.append(targets if len(targets) > 1 else targets[0])
            used.update(targets)
        else:
            if dropped is not None and targets:
                dropped.append(f"{name}:{dim} !% {targets}")
            spec.append(None)
    return P(*spec)


def shardings_for(axes_tree: Any, params_tree: Any, mesh: Mesh,
                  rules: ShardingRules = DEFAULT_RULES,
                  report: Optional[List[str]] = None) -> Any:
    """NamedSharding tree matching params_tree's structure."""
    def one(axes: AxisLeaf, leaf) -> NamedSharding:
        shape = getattr(leaf, "shape", None)
        if shape is None:
            raise TypeError(f"param leaf without shape: {leaf}")
        return NamedSharding(mesh, logical_to_spec(axes, tuple(shape), mesh,
                                                   rules, report))

    return jax.tree.map(one, axes_tree, params_tree, is_leaf=_is_axes_leaf)


def constrain(x, mesh: Mesh, *axes: Any, rules: ShardingRules = DEFAULT_RULES):
    """with_sharding_constraint by logical names (activations)."""
    spec = logical_to_spec(tuple(axes), tuple(x.shape), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
