from repro.sharding.rules import (
    ShardingRules,
    DEFAULT_RULES,
    logical_to_spec,
    shardings_for,
    constrain,
)

__all__ = ["ShardingRules", "DEFAULT_RULES", "logical_to_spec", "shardings_for",
           "constrain"]
