"""Sharded batch iterator: host numpy batches → device arrays placed with a
NamedSharding over the mesh ("pod","data") axes.

This is the data-pipeline analogue of the paper's partitioned MLTable load:
each host batch is laid out so that device d receives exactly its row
partition — no gather through a driver.  The iterator's position is a
single integer ``step`` and the source is a pure function of it, so the
stream is *seekable*: :meth:`BatchIterator.seek` repositions it exactly,
which is how ``DistributedRunner.resume`` replays a killed run bit-for-bit
(the checkpoint metadata records the step; see docs/architecture.md,
"Streaming epochs and fault tolerance").
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["BatchIterator", "shard_batch"]


def shard_batch(batch: Dict[str, np.ndarray], mesh: Optional[Mesh]) -> Dict[str, Any]:
    """Place a host batch on the mesh: leading (batch) dim over
    ("pod","data") when divisible, replicated otherwise.

    The partitioned placement uses the same spec as
    :func:`repro.core.partition.data_spec`, so a streamed window and a
    resident ``MLNumericTable`` have identical layouts and the runner can
    consume either without resharding.

    On a **multi-host mesh** (``jax.process_count() > 1``) a plain
    ``device_put`` cannot place rows on remote devices, so row-partitioned
    values are assembled from per-process slices instead: every host calls
    this with the identical full host batch (sources are pure functions of
    the step, so they agree), carves out its own contiguous row range, and
    contributes it via :func:`repro.core.hostmesh.place_global_rows`.  The
    resulting global array is bit-identical in layout to the single-process
    placement — the cross-host determinism tests rely on exactly that.
    Replicated (non-divisible) values fall back to a local put of the full
    value, which every host performs identically.
    """
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))
    multihost = jax.process_count() > 1

    def place(v: np.ndarray):
        partitioned = v.shape[0] % n_dev == 0
        if multihost:
            from repro.core import hostmesh

            if partitioned:
                rows = hostmesh.local_row_slice(v.shape[0], mesh, axes)
                return hostmesh.place_global_rows(
                    np.asarray(v)[rows], v.shape[0], mesh, axes)
            # replicated value: every process contributes the full array
            # (a host cannot device_put onto remote devices directly)
            sharding = NamedSharding(mesh, P(*([None] * v.ndim)))
            return jax.make_array_from_process_local_data(
                sharding, np.asarray(v), v.shape)
        spec = P(axes, *([None] * (v.ndim - 1))) if partitioned \
            else P(*([None] * v.ndim))
        return jax.device_put(v, NamedSharding(mesh, spec))

    return {k: place(v) for k, v in batch.items()}


class BatchIterator:
    """Iterate ``source(step) -> host batch`` onto the mesh, prefetch-free
    (CPU container); on a real pod this is where double-buffering would go.

    ``source`` must be a pure function of ``step`` — that determinism is
    what makes kill-and-resume exact: after a restart,
    ``seek(checkpointed_step)`` reproduces the identical remaining batch
    sequence.
    """

    def __init__(self, source: Callable[[int], Dict[str, np.ndarray]],
                 mesh: Optional[Mesh] = None, start_step: int = 0):
        self.source = source
        self.mesh = mesh
        self.step = start_step

    def seek(self, step: int) -> "BatchIterator":
        """Reposition the stream; the next batch will be ``source(step)``.
        Used by ``DistributedRunner.resume`` to fast-forward a fresh
        iterator to the checkpointed position."""
        self.step = int(step)
        return self

    def restrict(self, indices) -> "BatchIterator":
        """Fold-restricted view of this stream: every window the source
        yields is row-gathered to ``indices`` (host-side, before mesh
        placement), so a streamed cross-validation split trains on exactly
        the rows the resident :func:`repro.tune.cv.fold_view` would.

        The restricted source stays a pure function of the step, so the
        view remains seekable and checkpoint/resume-exact.  The returned
        iterator starts at this stream's current position.  Values whose
        leading dim covers every index are row-gathered; shorter values
        (per-window broadcast extras) pass through untouched — but a
        window where NOTHING covers the indices raises, so a fold
        restriction can never be silently ignored (CV leakage).
        """
        idx = np.asarray(indices)
        if idx.ndim != 1:
            raise ValueError(f"indices must be 1-D, got shape {idx.shape}")
        if idx.size == 0:
            raise ValueError("cannot restrict a stream to zero rows")
        needed = int(idx.max()) + 1
        source = self.source

        def restricted(step: int) -> Dict[str, np.ndarray]:
            batch = source(step)
            out = {}
            for k, v in batch.items():
                if np.ndim(v) >= 1 and np.shape(v)[0] >= needed:
                    out[k] = v[idx]
                elif k == "data":
                    # the row-carrying key (library convention: run_epochs
                    # consumes batch["data"]) MUST cover the fold — a
                    # too-short window silently training on unrestricted
                    # rows is exactly the CV leakage this guards against
                    raise ValueError(
                        f"restricted stream at step {step}: 'data' window "
                        f"has {np.shape(v)[0]} rows, cannot cover fold "
                        f"indices up to {needed - 1}")
                else:
                    out[k] = v
            if all(o is v for o, v in zip(out.values(), batch.values())):
                sizes = {k: np.shape(v)[:1] for k, v in batch.items()}
                raise ValueError(
                    f"restricted stream at step {step}: no value covers "
                    f"fold indices up to {needed - 1} (leading dims "
                    f"{sizes}) — the restriction would be silently "
                    f"ignored")
            return out

        return BatchIterator(restricted, mesh=self.mesh, start_step=self.step)

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def __next__(self) -> Dict[str, Any]:
        batch = shard_batch(self.source(self.step), self.mesh)
        self.step += 1
        return batch
