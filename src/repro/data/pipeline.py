"""Sharded batch iterator: host numpy batches → device arrays placed with a
NamedSharding over the mesh ("pod","data") axes.

This is the data-pipeline analogue of the paper's partitioned MLTable load:
each host batch is laid out so that device d receives exactly its row
partition — no gather through a driver.  The iterator's position is a
single integer ``step`` and the source is a pure function of it, so the
stream is *seekable*: :meth:`BatchIterator.seek` repositions it exactly,
which is how ``DistributedRunner.resume`` replays a killed run bit-for-bit
(the checkpoint metadata records the step; see docs/architecture.md,
"Streaming epochs and fault tolerance").
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["BatchIterator", "shard_batch"]


def shard_batch(batch: Dict[str, np.ndarray], mesh: Optional[Mesh]) -> Dict[str, Any]:
    """Place a host batch on the mesh: leading (batch) dim over
    ("pod","data") when divisible, replicated otherwise.

    The partitioned placement uses the same spec as
    :func:`repro.core.partition.data_spec`, so a streamed window and a
    resident ``MLNumericTable`` have identical layouts and the runner can
    consume either without resharding.
    """
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))

    def place(v: np.ndarray):
        spec = P(axes, *([None] * (v.ndim - 1))) if v.shape[0] % n_dev == 0 \
            else P(*([None] * v.ndim))
        return jax.device_put(v, NamedSharding(mesh, spec))

    return {k: place(v) for k, v in batch.items()}


class BatchIterator:
    """Iterate ``source(step) -> host batch`` onto the mesh, prefetch-free
    (CPU container); on a real pod this is where double-buffering would go.

    ``source`` must be a pure function of ``step`` — that determinism is
    what makes kill-and-resume exact: after a restart,
    ``seek(checkpointed_step)`` reproduces the identical remaining batch
    sequence.
    """

    def __init__(self, source: Callable[[int], Dict[str, np.ndarray]],
                 mesh: Optional[Mesh] = None, start_step: int = 0):
        self.source = source
        self.mesh = mesh
        self.step = start_step

    def seek(self, step: int) -> "BatchIterator":
        """Reposition the stream; the next batch will be ``source(step)``.
        Used by ``DistributedRunner.resume`` to fast-forward a fresh
        iterator to the checkpointed position."""
        self.step = int(step)
        return self

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def __next__(self) -> Dict[str, Any]:
        batch = shard_batch(self.source(self.step), self.mesh)
        self.step += 1
        return batch
