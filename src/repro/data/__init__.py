from repro.data.synthetic import (
    synth_classification,
    synth_imagenet_features,
    synth_netflix_tiled,
    synth_text_corpus,
    synth_labeled_text,
    SyntheticLMDataset,
)
from repro.data.pipeline import BatchIterator

__all__ = [
    "synth_classification", "synth_imagenet_features", "synth_netflix_tiled",
    "synth_text_corpus", "synth_labeled_text", "SyntheticLMDataset",
    "BatchIterator",
]
