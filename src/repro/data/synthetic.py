"""Synthetic dataset generators matching the paper's experimental data.

  * ``synth_imagenet_features`` — the paper's §IV-A weak/strong-scaling data:
    dense feature vectors (they used 160K-dim featurized ImageNet) with
    labels from a random ground-truth separator + noise, so logistic
    regression has a recoverable optimum.
  * ``synth_netflix_tiled`` — the paper's §IV-B collaborative-filtering data:
    a base low-rank + noise ratings matrix with Netflix-like sparsity,
    *tiled* t× to scale exactly the way the paper scales ("repeatedly tiling
    the Netflix dataset ... maintains the sparsity structure").
  * ``synth_classification`` — small dense classification sets for tests.
  * ``synth_text_corpus`` / ``SyntheticLMDataset`` — text for the Fig. A2
    pipeline and token streams for transformer training.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["synth_classification", "synth_imagenet_features",
           "synth_netflix_tiled", "synth_text_corpus", "synth_labeled_text",
           "SyntheticLMDataset"]


def synth_classification(n: int, d: int, seed: int = 0, noise: float = 0.05
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Linearly separable-ish binary data.  Returns (X, y, w_true)."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=d) / np.sqrt(d)
    X = rng.normal(size=(n, d)).astype(np.float32)
    margin = X @ w
    flip = rng.random(n) < noise
    y = ((margin > 0) ^ flip).astype(np.float32)
    return X, y, w.astype(np.float32)


def synth_imagenet_features(n: int, d: int = 4096, seed: int = 0
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """Dense featurized-image stand-in (paper used d=160K; tests scale d
    down).  Features are ReLU'd gaussians (non-negative, sparse-ish like
    conv features); labels from a planted linear model."""
    rng = np.random.default_rng(seed)
    X = np.maximum(rng.normal(size=(n, d)), 0).astype(np.float32)
    w = rng.normal(size=d) / np.sqrt(d)
    y = ((X @ w) > np.median(X @ w)).astype(np.float32)
    return X, y


def synth_netflix_tiled(
    users: int = 480, items: int = 178, rank: int = 10, tiles: int = 1,
    density: float = 0.011, seed: int = 0,
) -> np.ndarray:
    """Dense (users·t, items·t) ratings matrix with zeros for unobserved
    entries (the paper's CSR partitions become fixed-shape dense blocks with
    an explicit zero = unobserved convention; see LocalMatrix notes).

    Default users/items keep the Netflix user:item ratio (480K:17.8K) at
    1/1000 scale; ``tiles`` scales the matrix exactly as the paper does —
    block-diagonal tiling preserves per-row/column sparsity structure."""
    rng = np.random.default_rng(seed)
    U = rng.normal(size=(users, rank)) / np.sqrt(rank)
    V = rng.normal(size=(items, rank)) / np.sqrt(rank)
    base = U @ V.T + 0.1 * rng.normal(size=(users, items))
    base = np.clip(2.5 + 1.5 * base, 1.0, 5.0)
    mask = rng.random((users, items)) < density
    base = np.where(mask, base, 0.0).astype(np.float32)
    if tiles == 1:
        return base
    out = np.zeros((users * tiles, items * tiles), np.float32)
    for t in range(tiles):
        out[t * users:(t + 1) * users, t * items:(t + 1) * items] = base
    return out


_WORDS = ("the quick brown fox jumps over lazy dog machine learning api "
          "distributed table matrix gradient descent cluster spark data "
          "feature model train test scale pod mesh kernel").split()


def synth_text_corpus(n_docs: int = 64, words_per_doc: int = 30,
                      seed: int = 0) -> list:
    """Tiny synthetic corpus for the Fig. A2 pipeline (nGrams → tfIdf →
    KMeans).  Docs are drawn from topic-biased word distributions so
    clustering has structure to find."""
    rng = np.random.default_rng(seed)
    n_topics = 4
    topic_bias = rng.dirichlet(np.ones(len(_WORDS)) * 0.3, size=n_topics)
    docs = []
    for i in range(n_docs):
        p = topic_bias[i % n_topics]
        docs.append(" ".join(rng.choice(_WORDS, size=words_per_doc, p=p)))
    return docs


def synth_labeled_text(n_docs: int = 64, words_per_doc: int = 20,
                       seed: int = 0) -> list:
    """Binary text-classification corpus for the Fig. A2 end-to-end story:
    ``(label, text)`` rows whose word distributions are class-biased (each
    class favors half the vocabulary), so a served text pipeline has
    signal to learn.  Pure function of the arguments — a resumed run sees
    the identical table."""
    rng = np.random.default_rng(seed)
    half = len(_WORDS) // 2
    bias = np.full(len(_WORDS), 0.25 / (len(_WORDS) - half))
    p0, p1 = bias.copy(), bias.copy()
    p0[:half] = 0.75 / half
    p1[half:] = 0.75 / (len(_WORDS) - half)
    p0, p1 = p0 / p0.sum(), p1 / p1.sum()
    rows = []
    for i in range(n_docs):
        label = i % 2
        p = p1 if label else p0
        rows.append((float(label),
                     " ".join(rng.choice(_WORDS, size=words_per_doc, p=p))))
    return rows


@dataclasses.dataclass
class SyntheticLMDataset:
    """Deterministic token stream for transformer train/serve examples.

    Tokens follow a planted bigram chain (so a trained model has signal to
    learn: next-token ≈ (token * mult + inc) % vocab with noise)."""
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    noise: float = 0.1

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(self.seed + step)
        B, S = self.batch_size, self.seq_len
        mult = 31
        toks = np.zeros((B, S), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, size=B)
        for t in range(1, S):
            nxt = (toks[:, t - 1] * mult + 7) % self.vocab_size
            noise_mask = rng.random(B) < self.noise
            rand = rng.integers(0, self.vocab_size, size=B)
            toks[:, t] = np.where(noise_mask, rand, nxt)
        return {"tokens": toks, "labels": toks.copy()}
