"""Shard-aware evaluation metrics over row-partitioned tables.

Every metric here is a *sufficient-statistics* computation in MLI form: a
pure local function turns each partition's block into partial sums, one
global ``combine="sum"`` (through :class:`repro.core.runner.
DistributedRunner`, so the wire pattern is the configured
:class:`repro.core.collectives.CollectiveSchedule`) accumulates them, and a
closed-form host-side finalize produces the scalar.  No metric ever gathers
rows to one place — evaluation scales exactly like training.

All metrics accept **stacked** predictors too: a prediction function (or
centroid array) carrying a leading (K, …) trial axis yields a (K,) score
vector from the *same single pass* over the table — this is how the tune
layer scores K device-stacked trials with one collective instead of K
(see ``repro.tune.trials``).

Library convention (paper Fig. A4): supervised tables carry the label in
column 0 and the features in columns 1..d; ``predict`` receives only the
feature columns.
"""
from __future__ import annotations

from typing import Any, Callable, Union

import jax
import jax.numpy as jnp

from repro.core.collectives import CollectiveSchedule
from repro.core.runner import DistributedRunner

__all__ = ["accuracy", "log_loss", "rmse", "silhouette_lite", "predictions",
           "MetricHistory"]

#: predict(X_block) -> (rows,) predictions, or (K, rows) for K stacked trials
PredictFn = Callable[[jnp.ndarray], jnp.ndarray]
Schedule = Union[str, CollectiveSchedule]

_EPS = 1e-7  # log-loss probability clip


def _sum_stats(table: Any, local_fn: Callable[[jnp.ndarray], Any],
               schedule: Schedule) -> Any:
    """One combined pass: ``local_fn(block) -> partial sums`` per partition,
    globally summed under ``schedule``."""
    runner = DistributedRunner.for_table(table, schedule=schedule)
    return runner.run_once(table, local_fn, combine="sum")


def predictions(table: Any, predict: PredictFn, *,
                schedule: Schedule = CollectiveSchedule.GATHER_BROADCAST
                ) -> jnp.ndarray:
    """Shard-aware batched predict: run ``predict`` on every partition's
    feature block and concatenate the per-partition outputs in row order
    (``combine="concat"``, so the wire pattern is the configured
    schedule's broadcast form).

    Unlike the metrics above, the whole table is treated as features — no
    label column is stripped; callers serving supervised models slice it
    themselves.  The serving-side
    :class:`repro.serve.predictor.ModelPredictor` microbatcher compiles
    this same one-pass pattern once per service; one pass serves a whole
    microbatch without ever gathering *rows* to one host.
    """
    runner = DistributedRunner.for_table(table, schedule=schedule)
    return runner.partition_apply(
        table.data, lambda block: jnp.asarray(predict(block)), (), "concat")


def accuracy(table: Any, predict: PredictFn, *,
             schedule: Schedule = CollectiveSchedule.ALLREDUCE) -> jnp.ndarray:
    """Fraction of rows whose predicted label matches column 0.

    ``predict(X)`` returns hard labels (or anything comparable to the label
    column) shaped ``(rows,)`` — or ``(K, rows)`` for K stacked models,
    giving a ``(K,)`` result from one pass.
    """
    def local(block: jnp.ndarray) -> jnp.ndarray:
        pred = predict(block[:, 1:])
        return jnp.sum((pred == block[:, 0]).astype(jnp.float32), axis=-1)

    return _sum_stats(table, local, schedule) / table.num_rows


def log_loss(table: Any, predict_proba: PredictFn, *,
             schedule: Schedule = CollectiveSchedule.ALLREDUCE) -> jnp.ndarray:
    """Mean binary cross-entropy of ``predict_proba(X)`` against the 0/1
    label column (clipped at 1e-7).  Stacked probabilities ``(K, rows)``
    give a ``(K,)`` result."""
    def local(block: jnp.ndarray) -> jnp.ndarray:
        y = block[:, 0]
        p = jnp.clip(predict_proba(block[:, 1:]), _EPS, 1.0 - _EPS)
        nll = -(y * jnp.log(p) + (1.0 - y) * jnp.log1p(-p))
        return jnp.sum(nll, axis=-1)

    return _sum_stats(table, local, schedule) / table.num_rows


def rmse(table: Any, predict: PredictFn, *,
         schedule: Schedule = CollectiveSchedule.ALLREDUCE) -> jnp.ndarray:
    """Root-mean-squared error of ``predict(X)`` against column 0.  Stacked
    predictions ``(K, rows)`` give a ``(K,)`` result."""
    def local(block: jnp.ndarray) -> jnp.ndarray:
        err = predict(block[:, 1:]) - block[:, 0]
        return jnp.sum(err * err, axis=-1)

    return jnp.sqrt(_sum_stats(table, local, schedule) / table.num_rows)


def silhouette_lite(table: Any, centroids: jnp.ndarray, *,
                    schedule: Schedule = CollectiveSchedule.ALLREDUCE
                    ) -> jnp.ndarray:
    """Centroid-based silhouette score in one pass (higher is better).

    The classic silhouette needs all pairwise row distances — O(n²) and a
    full gather, exactly what MLI forbids.  This "lite" variant replaces
    the intra/inter-cluster mean distances with distances to centroids:
    per row, ``a`` = distance to its own (nearest) centroid, ``b`` =
    distance to the second-nearest centroid, score ``(b - a) / max(a, b)``
    — a shard-local computation whose mean is one global sum.

    ``centroids`` is ``(k, d)`` — or ``(K, k, d)`` for K stacked k-means
    trials, giving a ``(K,)`` score vector from the same pass.  The whole
    table is treated as features (no label column).
    """
    C = jnp.asarray(centroids)

    def row_scores(X: jnp.ndarray, cents: jnp.ndarray) -> jnp.ndarray:
        d2 = jnp.sum((X[:, None, :] - cents[None, :, :]) ** 2, axis=-1)
        two, _ = jax.lax.top_k(-d2, 2)               # two smallest, negated
        two = jnp.maximum(-two, 0.0)                 # clamp fp-negative d²
        a = jnp.sqrt(two[:, 0])
        b = jnp.sqrt(two[:, 1])
        denom = jnp.maximum(jnp.maximum(a, b), _EPS)
        return (b - a) / denom

    def local(block: jnp.ndarray) -> jnp.ndarray:
        if C.ndim == 3:
            return jnp.sum(jax.vmap(lambda c: row_scores(block, c))(C), axis=-1)
        return jnp.sum(row_scores(block, C), axis=-1)

    return _sum_stats(table, local, schedule) / table.num_rows


class MetricHistory:
    """Per-rung metric snapshots keyed by ``(trial, metric, epoch)``.

    The storage behind :func:`repro.tune.callback.record_evaluation`: each
    evaluation boundary (a rung in a search, an epoch in a plain loop)
    records one value per (trial, metric).  Recording the same key twice
    **overwrites** — that is the idempotence a killed-and-resumed search
    relies on when it replays boundaries it already recorded.

    ``series`` returns one trial's trajectory as ``[(epoch, value), …]``
    in epoch order, regardless of the order boundaries were recorded in
    (an ASHA resume can backfill early rungs after later ones).
    """

    def __init__(self) -> None:
        # trial -> metric -> {epoch: value}
        self._h: dict = {}

    def record(self, trial: int, metric: str, epoch: int, value: float) -> None:
        self._h.setdefault(int(trial), {}).setdefault(str(metric), {})[
            int(epoch)] = float(value)

    def trials(self) -> list:
        return sorted(self._h)

    def metrics(self, trial: int) -> list:
        return sorted(self._h.get(int(trial), {}))

    def series(self, trial: int, metric: str) -> list:
        points = self._h.get(int(trial), {}).get(str(metric), {})
        return sorted(points.items())

    def last(self, trial: int, metric: str):
        series = self.series(trial, metric)
        return series[-1][1] if series else None

    def to_dict(self) -> dict:
        """JSON-able nested dict (epoch keys become strings)."""
        return {str(t): {m: {str(e): v for e, v in sorted(points.items())}
                         for m, points in metrics.items()}
                for t, metrics in self._h.items()}

    def __len__(self) -> int:
        return sum(len(points) for metrics in self._h.values()
                   for points in metrics.values())
