"""Shard-aware model evaluation (the scoring half of model search)."""
from repro.eval.metrics import (  # noqa: F401
    accuracy,
    log_loss,
    rmse,
    silhouette_lite,
)

__all__ = ["accuracy", "log_loss", "rmse", "silhouette_lite"]
