"""Losses.  Cross-entropy is written max/logsumexp-stable and reduction-
friendly so XLA partitions it cleanly when logits are vocab-sharded.

``chunked_cross_entropy_from_hidden`` is the big-vocab optimization from the
§Perf hillclimb: the (tokens, V) logits tensor is never materialized —
vocab chunks stream through a rematerialized scan carrying the running
max / sum-exp / label logit.  For gemma3 (V=262144) the full fp32 logits
are 4·B·S·V ≈ 1.1 TB global at train_4k; the chunked path keeps only a
(tokens, chunk) block live."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["cross_entropy_loss", "chunked_cross_entropy_from_hidden"]


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """logits: (B, S, V) fp32; labels: (B, S) int32; mask: (B, S) 0/1."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_cross_entropy_from_hidden(
    hidden: jnp.ndarray,          # (N, D) final hidden states (pre-LM-head)
    table: jnp.ndarray,           # (V, D) tied embedding table
    labels: jnp.ndarray,          # (N,) int32
    *,
    chunk: int = 8192,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Streaming log-sum-exp over vocab chunks; O(N·chunk) live memory.

    The chunk body is ``jax.checkpoint``ed so backward recomputes each
    chunk's logits from (hidden, table-chunk) instead of saving them — the
    full (N, V) tensor exists neither forward nor backward.
    """
    N, D = hidden.shape
    V = table.shape[0]
    if V % chunk != 0:
        logits = jnp.einsum("nd,vd->nv", hidden, table,
                            preferred_element_type=jnp.float32)
        return cross_entropy_loss(logits[None], labels[None],
                                  None if mask is None else mask[None])
    n_chunks = V // chunk
    tchunks = table.reshape(n_chunks, chunk, D)

    @jax.checkpoint
    def body(carry, tc_and_idx):
        m, s, ll = carry
        tc, ci = tc_and_idx
        logits = jnp.einsum("nd,cd->nc", hidden, tc,
                            preferred_element_type=jnp.float32)  # (N, chunk)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        s = s * jnp.exp(m - m_new) + jnp.sum(jnp.exp(logits - m_new[:, None]),
                                             axis=-1)
        # label logit if it falls inside this chunk
        local = labels - ci * chunk
        in_chunk = (local >= 0) & (local < chunk)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local, 0, chunk - 1)[:, None], axis=-1)[:, 0]
        ll = jnp.where(in_chunk, picked, ll)
        return (m_new, s, ll), None

    init = (jnp.full((N,), -jnp.inf, jnp.float32),
            jnp.zeros((N,), jnp.float32),
            jnp.zeros((N,), jnp.float32))
    (m, s, ll), _ = jax.lax.scan(body, init, (tchunks, jnp.arange(n_chunks)))
    nll = m + jnp.log(s) - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
