from repro.train.loss import cross_entropy_loss
from repro.train.step import TrainState, make_train_step, init_train_state

__all__ = ["cross_entropy_loss", "TrainState", "make_train_step", "init_train_state"]
