"""Distributed train step builder.

The paper's training contract — partition-local compute, explicit global
combine — appears here at pod scale: the batch shards over ("pod","data"),
parameters FSDP-shard over "data" and tensor-shard over "model", and the
gradient combine is whatever GSPMD lowers for those shardings
(reduce-scatter + all-gather; the §Perf log hillclimbs it).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.transformer import TransformerLM, init_model
from repro.optim.optimizers import OptimizerDef, adamw
from repro.sharding.rules import DEFAULT_RULES, ShardingRules, logical_to_spec, shardings_for
from repro.train.loss import (chunked_cross_entropy_from_hidden,
                              cross_entropy_loss)

__all__ = ["TrainState", "init_train_state", "make_train_step", "batch_specs"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray


def init_train_state(key: jax.Array, cfg: ArchConfig,
                     optimizer: Optional[OptimizerDef] = None
                     ) -> Tuple[TrainState, Any]:
    """Returns (state, axes) — axes is the logical-axis tree for params."""
    optimizer = optimizer or adamw()
    params, axes = init_model(key, cfg)
    opt_state = optimizer.init(params)
    return TrainState(params=params, opt_state=opt_state,
                      step=jnp.zeros((), jnp.int32)), axes


def state_shardings(state: TrainState, axes: Any, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES) -> TrainState:
    """Shardings for the full TrainState: optimizer moments mirror params."""
    p_sh = shardings_for(axes, state.params, mesh, rules)

    def opt_sh(entry):
        # every optimizer-state subtree mirrors the param tree structure
        return jax.tree.map(lambda _, s: s, entry, p_sh) if entry else entry

    o_sh = {k: jax.tree.map(lambda _, s: s, v, p_sh)
            for k, v in state.opt_state.items()}
    return TrainState(params=p_sh, opt_state=o_sh,
                      step=NamedSharding(mesh, P()))


#: logical axes for each possible batch entry (mapped per-mesh by
#: sharding.rules.logical_to_spec)
BATCH_AXES: Dict[str, Tuple] = {
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "vision_embeds": ("batch", None, None),
    "encoder_frames": ("batch", None, None),
}


def batch_specs(batch: Dict[str, Any], mesh: Mesh,
                rules: ShardingRules = DEFAULT_RULES) -> Dict[str, NamedSharding]:
    return {
        k: NamedSharding(mesh, logical_to_spec(BATCH_AXES[k], tuple(v.shape),
                                               mesh, rules))
        for k, v in batch.items()
    }


def make_train_step(cfg: ArchConfig, optimizer: Optional[OptimizerDef] = None,
                    mesh: Optional[Mesh] = None,
                    rules: ShardingRules = DEFAULT_RULES,
                    donate: bool = True,
                    grad_accum: int = 1) -> Callable:
    """Returns jitted ``step(state, batch) -> (state, metrics)``.

    batch: {"tokens": (B,S) int32, "labels": (B,S) int32,
            optional "vision_embeds": (B,Tv,D), "encoder_frames": (B,Se,D)}

    ``grad_accum > 1`` splits the batch into that many microbatches and
    accumulates gradients through a lax.scan before the single optimizer
    update — same math as the full batch (mean-of-means over equal-sized
    microbatches), 1/k the activation footprint.
    """
    optimizer = optimizer or adamw()
    model = TransformerLM(cfg)

    def loss_fn(params, batch):
        if cfg.loss_vocab_chunk:
            # §Perf chunked-xent path: LM head fused into the loss, the
            # (tokens, V) logits tensor is never materialized.
            hidden, aux = model.forward_hidden(
                params, batch["tokens"],
                vision_embeds=batch.get("vision_embeds"),
                encoder_frames=batch.get("encoder_frames"))
            if cfg.vision_tokens:
                hidden = hidden[:, cfg.vision_tokens:]
            B, S, D = hidden.shape
            table = params["embed"]["head"].T if "head" in params["embed"] \
                else params["embed"]["tok"]
            loss = chunked_cross_entropy_from_hidden(
                hidden[:, :-1].reshape(B * (S - 1), D), table,
                batch["labels"][:, 1:].reshape(B * (S - 1)),
                chunk=cfg.loss_vocab_chunk)
        else:
            logits, aux = model.forward(
                params, batch["tokens"],
                vision_embeds=batch.get("vision_embeds"),
                encoder_frames=batch.get("encoder_frames"))
            # logits cover [vision_tokens + text]; labels align with text tail
            if cfg.vision_tokens:
                logits = logits[:, cfg.vision_tokens:]
            loss = cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:])
        total = loss + cfg.router_aux_weight * aux
        return total, {"loss": loss, "aux": aux}

    def grads_of(params, batch):
        if grad_accum <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        B = batch["tokens"].shape[0]
        if B % grad_accum:
            raise ValueError(f"batch {B} not divisible by grad_accum {grad_accum}")
        micro = {k: v.reshape((grad_accum, B // grad_accum) + v.shape[1:])
                 for k, v in batch.items()}

        def body(acc, mb):
            (t, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            acc_g, acc_t, acc_m = acc
            acc_g = jax.tree.map(jnp.add, acc_g, g)
            return (acc_g, acc_t + t, jax.tree.map(jnp.add, acc_m, m)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g_sum, t_sum, m_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32),
                   {"loss": jnp.zeros((), jnp.float32),
                    "aux": jnp.zeros((), jnp.float32)}), micro)
        k = float(grad_accum)
        grads = jax.tree.map(lambda g: (g / k).astype(jnp.float32), g_sum)
        return (t_sum / k, jax.tree.map(lambda x: x / k, m_sum)), grads

    def step_fn(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        if mesh is not None:
            spec = logical_to_spec(("batch", None), batch["tokens"].shape, mesh, rules)
            batch = dict(batch)
            batch["tokens"] = jax.lax.with_sharding_constraint(
                batch["tokens"], NamedSharding(mesh, spec))
        (total, metrics), grads = grads_of(state.params, batch)
        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params, state.step)
        metrics = dict(metrics)
        metrics["total_loss"] = total
        metrics["grad_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return TrainState(params=new_params, opt_state=new_opt,
                          step=state.step + 1), metrics

    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0,) if donate else ())

    return step_fn  # caller jits with explicit in/out shardings (launch.dryrun)
