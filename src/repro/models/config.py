"""Architecture configuration.

One ``ArchConfig`` drives the whole zoo: the decoder (and optional encoder)
stack is a repeated *period* of blocks (``pattern``), which expresses the
assigned architectures' layer interleavings:

    dense GQA            pattern=[ATTN]
    gemma3 5:1 local     pattern=[ATTN_LOCAL]*5 + [ATTN]
    llama4 3:1 chunked   pattern=[ATTN_CHUNKED]*3 + [ATTN_NOPE]   (iRoPE)
    recurrentgemma 1:2   pattern=[RGLRU, RGLRU, ATTN_LOCAL]       (Griffin)
    mamba2               pattern=[SSD]
    whisper              decoder pattern=[ATTN] + cross-attention, encoder stack

Every block is followed by its MLP (dense or MoE) except SSD/RGLRU blocks,
which are self-contained (mamba2 has no MLP: d_ff=0).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

__all__ = ["BlockKind", "ArchConfig"]


class BlockKind(str, enum.Enum):
    ATTN = "attn"                  # global causal attention (RoPE unless nope)
    ATTN_LOCAL = "attn_local"      # sliding-window attention
    ATTN_CHUNKED = "attn_chunked"  # llama4-style chunked local attention
    ATTN_NOPE = "attn_nope"        # global attention, no positional encoding
    RGLRU = "rglru"                # RecurrentGemma RG-LRU recurrent block
    SSD = "ssd"                    # Mamba-2 state-space duality block


ATTENTION_KINDS = (BlockKind.ATTN, BlockKind.ATTN_LOCAL,
                   BlockKind.ATTN_CHUNKED, BlockKind.ATTN_NOPE)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads

    # stack pattern (one period; layers = periods * len(pattern))
    pattern: Tuple[BlockKind, ...] = (BlockKind.ATTN,)
    # reduced same-mix pattern for the dry-run cost probes (archs whose
    # period is too long to unroll twice, e.g. recurrentgemma's 19);
    # None → probe with the full pattern
    probe_pattern: Optional[Tuple[BlockKind, ...]] = None

    # attention details
    window: int = 4096                      # for ATTN_LOCAL
    attn_chunk: int = 8192                  # for ATTN_CHUNKED
    qkv_bias: bool = False                  # qwen-style
    rope_base: float = 10000.0
    causal: bool = True

    # MLP / MoE
    mlp_kind: str = "swiglu"                # "swiglu" | "gelu" | "none"
    num_experts: int = 0                    # 0 == dense MLP
    top_k: int = 1
    capacity_factor: float = 1.25
    shared_expert: bool = False             # llama4 has a shared expert
    router_aux_weight: float = 0.01         # load-balance loss weight
    moe_dispatch: str = "einsum"            # "einsum" (one-hot dispatch
    #   tensors, GSPMD all-to-all friendly) | "gather" (§Perf: sort-based
    #   index dispatch, no (g,s,E,C) blowup — O(tokens·d) traffic)

    # recurrent / ssm
    rnn_width: Optional[int] = None         # RG-LRU recurrence width
    conv_width: int = 4
    ssm_state: int = 128                    # mamba2 N
    ssd_head_dim: int = 64                  # mamba2 P
    ssd_expand: int = 2
    ssd_chunk: int = 256

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500                 # whisper 30s → 1500 frames
    cross_attention: bool = False

    # multimodal stub frontend
    vision_tokens: int = 0                  # llava: patch embeddings prepended

    # norms / embeddings
    norm_kind: str = "rmsnorm"              # "rmsnorm" | "layernorm" (whisper)
    use_rope: bool = True                   # False → absolute positions
    learned_pos: bool = False               # whisper decoder learned pos table
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    max_seq_len: int = 524288

    # numerics & perf knobs
    dtype: str = "bfloat16"                 # activation / param dtype
    remat: bool = True
    unroll_periods: bool = False            # Python-loop the period stack
    loss_vocab_chunk: Optional[int] = None  # chunked streaming xent (§Perf):
    #   never materialize (tokens, V) logits in training; value = vocab chunk
    #   (dry-run probes: XLA's cost_analysis counts a lax.scan body ONCE
    #    regardless of trip count, so the roofline extrapolates from two
    #    unrolled shallow probes; see launch/dryrun.py)
    q_chunk: int = 1024                     # q-block size for chunked attention scan
    use_flash_kernel: bool = False          # Pallas path (TPU); jnp path on CPU
    cache_dtype: str = "bfloat16"           # "int8" enables quantized KV cache
    quantize: str = "none"                  # weight quantization for serving:
    #   "none" | "bf16" (cast float params) | "int8" (symmetric per-channel
    #   attention/MLP projections via models.layers.quant, served through the
    #   quant_matmul kernel path)

    # provenance
    source: str = ""

    def __post_init__(self):
        if self.num_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers {self.num_layers} not divisible by "
                f"pattern period {len(self.pattern)}"
            )
        if self.num_heads and self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: heads must divide into kv groups")
        if self.quantize not in ("none", "bf16", "int8"):
            raise ValueError(
                f"{self.name}: quantize={self.quantize!r} (want none|bf16|int8)")

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.num_heads

    @property
    def num_periods(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def is_attention_free(self) -> bool:
        return all(b in (BlockKind.SSD, BlockKind.RGLRU) for b in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True iff no block attends globally with RoPE-free unbounded span —
        i.e. the arch can run long_500k."""
        full = (BlockKind.ATTN, BlockKind.ATTN_NOPE)
        # chunked/local/global-NoPE mixes still qualify if *most* layers are
        # bounded; llama4/gemma3 style interleaves are their documented
        # long-context recipe.  Pure full-attention stacks do not qualify.
        return any(b not in full for b in self.pattern)

    @property
    def d_inner_ssd(self) -> int:
        return self.ssd_expand * self.d_model

    @property
    def ssd_heads(self) -> int:
        return self.d_inner_ssd // self.ssd_head_dim

    def scaled(self, *, num_layers=None, d_model=None, num_heads=None,
               num_kv_heads=None, d_ff=None, vocab_size=None, num_experts=None,
               **kw) -> "ArchConfig":
        """Reduced variant of the same family (for CPU smoke tests)."""
        updates = dict(
            num_layers=num_layers or self.num_layers,
            d_model=d_model or self.d_model,
            num_heads=num_heads or self.num_heads,
            num_kv_heads=num_kv_heads or self.num_kv_heads,
            d_ff=d_ff if d_ff is not None else self.d_ff,
            vocab_size=vocab_size or self.vocab_size,
        )
        if num_experts is not None:
            updates["num_experts"] = num_experts
        updates.update(kw)
        if d_model and self.head_dim is not None and "head_dim" not in kw:
            updates["head_dim"] = max(8, d_model // max(updates.get("num_heads") or 1, 1))
        if d_model and self.rnn_width is not None and "rnn_width" not in kw:
            updates["rnn_width"] = updates["d_model"]
        return dataclasses.replace(self, **updates)
