"""Transformer model zoo: the beyond-paper substrate the MLI Optimizer/
Algorithm contracts are exercised against at pod scale."""
from repro.models.config import ArchConfig, BlockKind
from repro.models.transformer import TransformerLM, init_model

__all__ = ["ArchConfig", "BlockKind", "TransformerLM", "init_model"]
