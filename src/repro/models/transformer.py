"""Composable transformer stack driven by ArchConfig.

The decoder is ``num_periods`` repetitions of the config's block *pattern*;
parameters for each pattern position are stacked along a leading "layers"
axis and the stack executes as ONE ``jax.lax.scan`` over periods (HLO size —
and hence CPU compile time for the 70-compile dry-run matrix — stays
independent of depth).  Per-period caches ride along the same scan.

Public entry points:

    init_model(key, cfg)                 -> (params, axes)
    TransformerLM.forward(...)           -> logits (+ aux losses)   [train]
    TransformerLM.prefill(...)           -> logits, cache
    TransformerLM.decode_step(...)       -> logits, cache           [1 token]
    TransformerLM.init_cache(...)        -> cache pytree
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ATTENTION_KINDS, ArchConfig, BlockKind
from repro.models.layers.attention import attn_apply, attn_init, init_kv_cache
from repro.models.layers.embedding import (embed_init, embed_tokens,
                                           logits_from, sinusoidal_positions)
from repro.models.layers.mlp import mlp_apply, mlp_init
from repro.models.layers.moe import moe_apply, moe_init
from repro.models.layers.norms import (layernorm, layernorm_init, rmsnorm,
                                       rmsnorm_init)
from repro.models.layers.rglru import init_rglru_cache, rglru_apply, rglru_init
from repro.models.layers.ssd import init_ssd_cache, ssd_apply, ssd_init
from repro.models.params import split_tree_of, stack_bundles

__all__ = ["init_model", "TransformerLM"]


def _norm_init(cfg: ArchConfig, dtype):
    return rmsnorm_init(cfg.d_model, dtype) if cfg.norm_kind == "rmsnorm" \
        else layernorm_init(cfg.d_model, dtype)


def _norm_apply(cfg: ArchConfig, params, x):
    return rmsnorm(params, x, cfg.norm_eps) if cfg.norm_kind == "rmsnorm" \
        else layernorm(params, x, cfg.norm_eps)


# --------------------------------------------------------------------------- #
# per-period init
# --------------------------------------------------------------------------- #
def _block_init(key: jax.Array, cfg: ArchConfig, kind: BlockKind, dtype,
                cross: bool):
    ks = jax.random.split(key, 6)
    mixed: Dict[str, Any] = {}
    mixed["ln1"] = split_tree_of(_norm_init(cfg, dtype))
    if kind in ATTENTION_KINDS:
        mixed["attn"] = attn_init(ks[0], cfg, dtype)
        if cross:
            mixed["ln_cross"] = split_tree_of(_norm_init(cfg, dtype))
            mixed["cross"] = attn_init(ks[1], cfg, dtype, cross=True)
        if cfg.mlp_kind != "none":
            mixed["ln2"] = split_tree_of(_norm_init(cfg, dtype))
            if cfg.num_experts > 0:
                mixed["moe"] = moe_init(ks[2], cfg, dtype)
            else:
                mixed["mlp"] = mlp_init(ks[3], cfg, dtype)
    elif kind == BlockKind.RGLRU:
        mixed["rglru"] = rglru_init(ks[0], cfg, dtype)
        if cfg.mlp_kind != "none":
            mixed["ln2"] = split_tree_of(_norm_init(cfg, dtype))
            mixed["mlp"] = mlp_init(ks[3], cfg, dtype)
    elif kind == BlockKind.SSD:
        mixed["ssd"] = ssd_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    params = {k: v[0] for k, v in mixed.items()}
    axes = {k: v[1] for k, v in mixed.items()}
    return params, axes


def _period_init(key: jax.Array, cfg: ArchConfig, dtype, cross: bool):
    params, axes = {}, {}
    ks = jax.random.split(key, len(cfg.pattern))
    for i, kind in enumerate(cfg.pattern):
        params[f"b{i}"], axes[f"b{i}"] = _block_init(ks[i], cfg, kind, dtype, cross)
    return params, axes


def init_model(key: jax.Array, cfg: ArchConfig) -> Tuple[Dict, Dict]:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4 + cfg.num_periods)
    params: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}

    params["embed"], axes["embed"] = embed_init(ks[0], cfg, dtype)

    periods = [
        _period_init(ks[4 + p], cfg, dtype, cross=cfg.cross_attention)
        for p in range(cfg.num_periods)
    ]
    params["blocks"], axes["blocks"] = stack_bundles(periods)

    params["final_norm"], axes["final_norm"] = split_tree_of(_norm_init(cfg, dtype))

    if cfg.encoder_layers > 0:
        enc_cfg = dataclasses.replace(cfg, causal=False, cross_attention=False,
                                      num_experts=0, pattern=(BlockKind.ATTN,),
                                      num_layers=cfg.encoder_layers)
        enc_periods = [
            _period_init(jax.random.fold_in(ks[1], p), enc_cfg, dtype, cross=False)
            for p in range(cfg.encoder_layers)
        ]
        enc: Dict[str, Any] = {}
        enc_axes: Dict[str, Any] = {}
        enc["blocks"], enc_axes["blocks"] = stack_bundles(enc_periods)
        enc["final_norm"], enc_axes["final_norm"] = split_tree_of(_norm_init(cfg, dtype))
        params["encoder"], axes["encoder"] = enc, enc_axes

    return params, axes


# --------------------------------------------------------------------------- #
# caches
# --------------------------------------------------------------------------- #
def _block_cache(cfg: ArchConfig, kind: BlockKind, batch: int, max_seq: int,
                 cross: bool, enc_seq: int, dtype):
    c: Dict[str, Any] = {}
    if kind in ATTENTION_KINDS:
        c["attn"] = init_kv_cache(cfg, kind, batch, max_seq, dtype)
        if cross:
            kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            c["cross"] = {
                "k": jnp.zeros((batch, enc_seq, kv, hd), dtype),
                "v": jnp.zeros((batch, enc_seq, kv, hd), dtype),
            }
    elif kind == BlockKind.RGLRU:
        c["rglru"] = init_rglru_cache(cfg, batch, dtype)
    elif kind == BlockKind.SSD:
        c["ssd"] = init_ssd_cache(cfg, batch, dtype)
    return c


# --------------------------------------------------------------------------- #
# model
# --------------------------------------------------------------------------- #
class TransformerLM:
    """Stateless functional model bound to an ArchConfig."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ---------------- caches ---------------- #
    def init_cache(self, batch: int, max_seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        per_period = []
        for _ in range(cfg.num_periods):
            c = {
                f"b{i}": _block_cache(cfg, kind, batch, max_seq,
                                      cfg.cross_attention, cfg.encoder_seq, dtype)
                for i, kind in enumerate(cfg.pattern)
            }
            per_period.append(c)
        return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *per_period)

    # ---------------- encoder ---------------- #
    def encode(self, params: Dict, frames: jnp.ndarray) -> jnp.ndarray:
        """Whisper-style encoder over precomputed frame embeddings (stub
        frontend per the assignment carve-out): adds sinusoidal positions,
        runs bidirectional attention layers."""
        cfg = self.cfg
        enc_cfg = dataclasses.replace(cfg, causal=False)
        x = frames + sinusoidal_positions(frames.shape[1], cfg.d_model,
                                          frames.dtype)[None]

        def one_layer(x, lp):
            h = _norm_apply(cfg, lp["b0"]["ln1"], x)
            h, _ = attn_apply(lp["b0"]["attn"], h, cfg=enc_cfg, kind=BlockKind.ATTN,
                              mode="prefill", positions=jnp.arange(x.shape[1]),
                              use_rope=False)
            x = x + h
            h = _norm_apply(cfg, lp["b0"]["ln2"], x)
            x = x + mlp_apply(lp["b0"]["mlp"], h)
            return x, None

        body = jax.checkpoint(one_layer) if cfg.remat else one_layer
        if cfg.unroll_periods:
            for i in range(cfg.encoder_layers):
                x, _ = body(x, jax.tree.map(lambda t: t[i],
                                            params["encoder"]["blocks"]))
        else:
            x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
        return _norm_apply(cfg, params["encoder"]["final_norm"], x)

    # ---------------- block application ---------------- #
    def _apply_block(self, cfg: ArchConfig, kind: BlockKind, bp: Dict, x, *,
                     mode: str, positions=None, pos=None, cache=None,
                     memory=None, lengths=None, start_pos=None):
        aux = jnp.zeros((), jnp.float32)
        new_cache: Dict[str, Any] = {}
        if kind in ATTENTION_KINDS:
            h = _norm_apply(cfg, bp["ln1"], x)
            h, c = attn_apply(bp["attn"], h, cfg=cfg, kind=kind, mode=mode,
                              positions=positions, pos=pos,
                              cache=None if cache is None else cache.get("attn"),
                              use_rope=cfg.use_rope, lengths=lengths,
                              start_pos=start_pos)
            if c is not None:
                new_cache["attn"] = c
            x = x + h
            has_cross_cache = cache is not None and "cross" in cache
            if "cross" in bp and (memory is not None or has_cross_cache):
                h = _norm_apply(cfg, bp["ln_cross"], x)
                h, cc = attn_apply(bp["cross"], h, cfg=cfg, kind=BlockKind.ATTN,
                                   mode=mode, positions=positions, pos=pos,
                                   cache=None if cache is None else cache.get("cross"),
                                   kv_src=memory, is_cross=True, use_rope=False)
                if cc is not None:
                    new_cache["cross"] = cc
                x = x + h
            if "moe" in bp:
                h = _norm_apply(cfg, bp["ln2"], x)
                h, aux = moe_apply(bp["moe"], h, cfg)
                x = x + h
            elif "mlp" in bp:
                h = _norm_apply(cfg, bp["ln2"], x)
                x = x + mlp_apply(bp["mlp"], h)
        elif kind == BlockKind.RGLRU:
            h = _norm_apply(cfg, bp["ln1"], x)
            h, c = rglru_apply(bp["rglru"], h, cfg=cfg, mode=mode,
                               cache=None if cache is None else cache.get("rglru"))
            if c is not None:
                new_cache["rglru"] = c
            x = x + h
            if "mlp" in bp:
                h = _norm_apply(cfg, bp["ln2"], x)
                x = x + mlp_apply(bp["mlp"], h)
        elif kind == BlockKind.SSD:
            h = _norm_apply(cfg, bp["ln1"], x)
            h, c = ssd_apply(bp["ssd"], h, cfg=cfg, mode=mode,
                             cache=None if cache is None else cache.get("ssd"))
            if c is not None:
                new_cache["ssd"] = c
            x = x + h
        return x, new_cache, aux

    def _run_stack(self, params, x, *, mode, positions=None, pos=None,
                   cache=None, memory=None, lengths=None, start_pos=None):
        cfg = self.cfg

        def period_fn(carry, scanned):
            x, aux_tot = carry
            if cache is None:
                pp, pc = scanned, None
            else:
                pp, pc = scanned
            new_pc: Dict[str, Any] = {}
            for i, kind in enumerate(cfg.pattern):
                x, nc, aux = self._apply_block(
                    cfg, kind, pp[f"b{i}"], x, mode=mode, positions=positions,
                    pos=pos, cache=None if pc is None else pc[f"b{i}"],
                    memory=memory, lengths=lengths, start_pos=start_pos)
                new_pc[f"b{i}"] = nc
                aux_tot = aux_tot + aux
            return (x, aux_tot), (new_pc if cache is not None else None)

        body = jax.checkpoint(period_fn) if (cfg.remat and mode != "decode") else period_fn
        xs = params["blocks"] if cache is None else (params["blocks"], cache)
        carry0 = (x, jnp.zeros((), jnp.float32))
        if cfg.unroll_periods:
            # Python loop (dry-run probes): every period appears in the HLO,
            # so cost_analysis counts all of them (scan bodies count once).
            carry = carry0
            caches = []
            for i in range(cfg.num_periods):
                xi = jax.tree.map(lambda t: t[i], xs)
                carry, pc = body(carry, xi)
                caches.append(pc)
            (x, aux) = carry
            new_cache = None if cache is None else jax.tree.map(
                lambda *cs: jnp.stack(cs, 0), *caches)
            return x, aux, new_cache
        (x, aux), new_cache = jax.lax.scan(body, carry0, xs)
        return x, aux, new_cache

    # ---------------- public entry points ---------------- #
    def forward(self, params: Dict, tokens: jnp.ndarray, *,
                vision_embeds: Optional[jnp.ndarray] = None,
                encoder_frames: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Training forward: full-sequence logits.  Returns (logits, aux)."""
        x, aux = self.forward_hidden(params, tokens,
                                     vision_embeds=vision_embeds,
                                     encoder_frames=encoder_frames)
        return logits_from(params["embed"], x), aux

    def forward_hidden(self, params: Dict, tokens: jnp.ndarray, *,
                       vision_embeds: Optional[jnp.ndarray] = None,
                       encoder_frames: Optional[jnp.ndarray] = None,
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Forward up to the final norm, WITHOUT the LM-head matmul —
        the chunked-loss path (§Perf) fuses logits into the loss instead.
        Returns (hidden (B, S, D), aux)."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens,
                         jnp.arange(tokens.shape[1]) if cfg.learned_pos else None)
        if vision_embeds is not None:
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
        S = x.shape[1]
        positions = jnp.arange(S)
        memory = None
        if encoder_frames is not None:
            memory = self.encode(params, encoder_frames)
        x, aux, _ = self._run_stack(params, x, mode="prefill",
                                    positions=positions, memory=memory)
        x = _norm_apply(cfg, params["final_norm"], x)
        return x, aux

    def prefill(self, params: Dict, tokens: jnp.ndarray, cache: Dict, *,
                vision_embeds: Optional[jnp.ndarray] = None,
                encoder_frames: Optional[jnp.ndarray] = None):
        """Prefill: runs the full prompt, fills the cache, returns
        (last-token logits, cache)."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens,
                         jnp.arange(tokens.shape[1]) if cfg.learned_pos else None)
        if vision_embeds is not None:
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
        S = x.shape[1]
        positions = jnp.arange(S)
        memory = None
        if encoder_frames is not None:
            memory = self.encode(params, encoder_frames)
        x, aux, new_cache = self._run_stack(params, x, mode="prefill",
                                            positions=positions, cache=cache,
                                            memory=memory)
        x = _norm_apply(cfg, params["final_norm"], x[:, -1:])
        return logits_from(params["embed"], x), new_cache

    def prefill_ragged(self, params: Dict, tokens: jnp.ndarray,
                       lengths: jnp.ndarray, cache: Dict,
                       start_pos: Optional[jnp.ndarray] = None):
        """Mixed-length prefill for continuous batching: ``tokens`` is
        (B, S) with slot b's prompt *right-padded* — real tokens in columns
        0..lengths[b]-1, pad after.  Causal masking means a real token never
        attends a pad column, and the cache fill drops pad columns entirely
        (see ``_prefill_fill_cache``), so each slot's cache is exactly what
        a lone batch-1 prefill of its prompt would have written.  Returns
        (per-slot next-token logits (B, 1, V), cache).

        Restricted to attention-only dense stacks: a recurrent state (RG-LRU
        h, SSD h, conv taps) would absorb the pad tail, and MoE
        capacity-factor routing couples slots through the shared token
        budget — those architectures prefill per-request instead (the serve
        engine handles the fallback).

        ``start_pos`` (B,) turns this into a **tail** prefill: slot b's
        tokens are the uncached suffix of its prompt, occupying absolute
        positions ``start_pos[b]..start_pos[b]+lengths[b]-1``, and the
        cache arrives with the prefix K/V already restored
        (``serve/prefix_cache.py``).  Rows with ``start_pos[b] == 0``
        degrade to a plain full prefill, so one compiled program serves
        waves mixing cache hits and misses.
        """
        cfg = self.cfg
        if any(k not in ATTENTION_KINDS for k in cfg.pattern):
            raise ValueError(f"{cfg.name}: prefill_ragged requires an "
                             "attention-only pattern (recurrent state would "
                             "absorb the pad tail)")
        if cfg.num_experts or cfg.cross_attention or cfg.vision_tokens:
            raise ValueError(f"{cfg.name}: prefill_ragged supports dense "
                             "text-only decoders")
        lengths = jnp.asarray(lengths, jnp.int32)
        B, S = tokens.shape
        if start_pos is None:
            emb_pos = jnp.arange(S) if cfg.learned_pos else None
        else:
            start_pos = jnp.asarray(start_pos, jnp.int32)
            emb_pos = (start_pos[:, None] + jnp.arange(S)[None, :]
                       if cfg.learned_pos else None)
        x = embed_tokens(params["embed"], tokens, emb_pos)
        positions = jnp.arange(S)
        x, aux, new_cache = self._run_stack(params, x, mode="prefill",
                                            positions=positions, cache=cache,
                                            lengths=lengths,
                                            start_pos=start_pos)
        # gather each slot's last *real* token (right-padding puts it at
        # column lengths[b]-1), then norm + LM head on (B, 1, D) only
        x_last = jnp.take_along_axis(
            x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)
        x_last = _norm_apply(cfg, params["final_norm"], x_last)
        return logits_from(params["embed"], x_last), new_cache

    def decode_step(self, params: Dict, token: jnp.ndarray, pos: jnp.ndarray,
                    cache: Dict):
        """One decode step.  token: (B, 1) int32; pos: scalar int32 (all
        rows at the same position) or (B,) int32 (continuous batching:
        per-slot positions).  Returns (logits (B,1,V), new_cache)."""
        cfg = self.cfg
        pos = jnp.asarray(pos, jnp.int32)
        if cfg.learned_pos:
            emb_pos = pos[:, None] if pos.ndim == 1 else pos[None]
        else:
            emb_pos = None
        x = embed_tokens(params["embed"], token, emb_pos)
        x, aux, new_cache = self._run_stack(params, x, mode="decode", pos=pos,
                                            cache=cache)
        x = _norm_apply(cfg, params["final_norm"], x)
        return logits_from(params["embed"], x), new_cache
