"""Parameter construction with logical sharding axes.

Every parameter leaf is created through :func:`linear`/:func:`table`/... which
return ``(array, axes)`` where ``axes`` is a tuple of *logical* axis names
(or None) per dimension.  ``repro.sharding.rules`` later maps logical names to
mesh axes with divisibility checking, so one init works for every arch and
every mesh (see DESIGN.md §5).

Logical axis vocabulary:
    embed      — d_model dims (FSDP storage axis → "data")
    ffn        — MLP hidden (tensor-parallel → "model")
    heads      — q heads    (tensor-parallel → "model" when divisible)
    kv_heads   — kv heads   ("model" when divisible, else replicated)
    head       — per-head dim (replicated)
    vocab      — vocabulary ("model" when divisible)
    experts    — MoE experts ("model" when divisible)
    rnn        — RG-LRU recurrence width ("model")
    ssd_heads  — mamba2 heads ("model")
    state      — SSM state dim (replicated)
    layers     — stacked-scan leading axis (replicated)
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ParamBundle", "linear", "bias", "table", "scalar_vec", "stack_bundles"]

Axes = Tuple[Optional[str], ...]
ParamBundle = Tuple[Dict[str, Any], Dict[str, Any]]  # (params, axes)


def _he(key: jax.Array, shape: Sequence[int], fan_in: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, tuple(shape), jnp.float32) * scale).astype(dtype)


def linear(key: jax.Array, shape: Sequence[int], axes: Axes,
           fan_in: Optional[int] = None, dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Axes]:
    """Dense weight with fan-in scaled normal init."""
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} vs axes {axes} rank mismatch")
    if fan_in is None:
        fan_in = shape[0]
    return _he(key, shape, fan_in, dtype), axes


def bias(shape: Sequence[int], axes: Axes, dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Axes]:
    return jnp.zeros(tuple(shape), dtype), axes


def ones_vec(shape: Sequence[int], axes: Axes, dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Axes]:
    return jnp.ones(tuple(shape), dtype), axes


def table(key: jax.Array, shape: Sequence[int], axes: Axes,
          dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Axes]:
    """Embedding table: unit-variance rows scaled by 1/sqrt(d)."""
    d = shape[-1]
    return (jax.random.normal(key, tuple(shape), jnp.float32) / math.sqrt(d)).astype(dtype), axes


def scalar_vec(value: float, shape: Sequence[int], axes: Axes,
               dtype=jnp.float32) -> Tuple[jnp.ndarray, Axes]:
    return jnp.full(tuple(shape), value, dtype), axes


def split_tree(bundle_fn: Callable[..., ParamBundle]):
    """Decorator-free helper: bundle_fn builds {'name': (arr, axes), ...};
    split into (params, axes) trees."""
    def build(*args, **kw) -> ParamBundle:
        mixed = bundle_fn(*args, **kw)
        params = {k: (v[0] if isinstance(v, tuple) else split_tree_of(v)[0])
                  for k, v in mixed.items()}
        axes = {k: (v[1] if isinstance(v, tuple) else split_tree_of(v)[1])
                for k, v in mixed.items()}
        return params, axes
    return build


def split_tree_of(mixed: Dict[str, Any]) -> ParamBundle:
    """Recursively split a dict whose leaves are (array, axes) pairs."""
    params, axes = {}, {}
    for k, v in mixed.items():
        if isinstance(v, tuple) and len(v) == 2 and not isinstance(v[0], dict):
            params[k], axes[k] = v
        elif isinstance(v, dict):
            params[k], axes[k] = split_tree_of(v)
        else:
            raise TypeError(f"unexpected leaf for {k}: {type(v)}")
    return params, axes


def stack_bundles(bundles: Sequence[ParamBundle]) -> ParamBundle:
    """Stack per-period param trees along a leading 'layers' axis so the
    transformer can lax.scan over periods."""
    params_list = [b[0] for b in bundles]
    axes0 = bundles[0][1]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *params_list)

    def prepend(ax):
        return ("layers",) + tuple(ax)

    axes = jax.tree.map(prepend, axes0,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))
    return stacked, axes
