"""RMSNorm / LayerNorm.  The fused Pallas kernel lives in repro.kernels;
this jnp implementation is the portable path (and the kernel oracle)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ones_vec

__all__ = ["rmsnorm_init", "rmsnorm", "layernorm_init", "layernorm"]


def rmsnorm_init(d: int, dtype=jnp.bfloat16):
    return {"scale": ones_vec((d,), ("embed",), dtype)}


def rmsnorm(params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(d: int, dtype=jnp.bfloat16):
    return {
        "scale": ones_vec((d,), ("embed",), dtype),
        "bias": (jnp.zeros((d,), dtype), ("embed",)),
    }


def layernorm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * (var + eps) ** -0.5
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)
