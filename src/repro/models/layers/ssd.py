"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

The SSD layer computes a selective state-space model

    h_t = a_t ⊙ h_{t−1} + (Δ_t x_t) ⊗ B_t          a_t = exp(Δ_t · A),  A < 0
    y_t = C_t · h_t + D ⊙ x_t

with scalar-per-head decay (the "SSD" restriction), multi-head over the
expanded inner width (P = head dim, N = state dim).  Training/prefill uses
the paper's *chunked dual form*: within a chunk of length L the output is an
attention-like matmul ``M = (C Bᵀ) ⊙ decay`` (the "duality"); across chunks a
single recurrent state is carried by a ``lax.scan``.  This is the TPU-native
adaptation: the intra-chunk quadratic form maps onto the MXU, the inter-chunk
scan is O(S/L) sequential steps — no CUDA-style warp-level scan needed.

Decode is the O(1) recurrence.

Projections are kept un-fused (wz/wx/wB/wC/wdt instead of mamba2's packed
in_proj) so each output dimension shards cleanly; the math is identical.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.params import bias as bias_init
from repro.models.params import linear, ones_vec, split_tree_of

__all__ = ["ssd_init", "ssd_apply", "init_ssd_cache"]


def ssd_init(key: jax.Array, cfg: ArchConfig, dtype):
    d = cfg.d_model
    di = cfg.d_inner_ssd
    n = cfg.ssm_state
    h = cfg.ssd_heads
    k = cfg.conv_width
    ks = jax.random.split(key, 8)
    a0 = jax.random.uniform(ks[0], (h,), jnp.float32, 1.0, 16.0)
    mixed = {
        "wz": linear(ks[1], (d, di), ("embed", "rnn"), fan_in=d, dtype=dtype),
        "wx": linear(ks[2], (d, di), ("embed", "rnn"), fan_in=d, dtype=dtype),
        "wB": linear(ks[3], (d, n), ("embed", "state"), fan_in=d, dtype=dtype),
        "wC": linear(ks[4], (d, n), ("embed", "state"), fan_in=d, dtype=dtype),
        "wdt": linear(ks[5], (d, h), ("embed", "ssd_heads"), fan_in=d, dtype=dtype),
        "dt_bias": bias_init((h,), ("ssd_heads",), jnp.float32),
        "A_log": (jnp.log(a0), ("ssd_heads",)),
        "D": (jnp.ones((h,), jnp.float32), ("ssd_heads",)),
        "conv_x": linear(ks[6], (k, di), (None, "rnn"), fan_in=k, dtype=dtype),
        "conv_b": bias_init((di,), ("rnn",), dtype),
        "norm_scale": ones_vec((di,), ("rnn",), dtype),
        "w_out": linear(ks[7], (di, d), ("rnn", "embed"), fan_in=di, dtype=dtype),
    }
    return split_tree_of(mixed)


def init_ssd_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    return {
        "h": jnp.zeros((batch, cfg.ssd_heads, cfg.ssd_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner_ssd), dtype),
    }


def _causal_conv(x, w, b, state):
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K)) + b
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), xp[:, -(K - 1):]


def _gated_norm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                eps: float) -> jnp.ndarray:
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def ssd_apply(params: Dict[str, jnp.ndarray], x: jnp.ndarray, *,
              cfg: ArchConfig, mode: str,
              cache: Optional[Dict[str, jnp.ndarray]] = None,
              ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    Bsz, S, D = x.shape
    H, P, N = cfg.ssd_heads, cfg.ssd_head_dim, cfg.ssm_state

    z = jnp.einsum("bsd,de->bse", x, params["wz"], preferred_element_type=jnp.float32).astype(x.dtype)
    xin = jnp.einsum("bsd,de->bse", x, params["wx"], preferred_element_type=jnp.float32).astype(x.dtype)
    conv_state = cache["conv"] if cache is not None else None
    xin, new_conv = _causal_conv(xin, params["conv_x"], params["conv_b"], conv_state)

    Bmat = jnp.einsum("bsd,dn->bsn", x, params["wB"], preferred_element_type=jnp.float32)
    Cmat = jnp.einsum("bsd,dn->bsn", x, params["wC"], preferred_element_type=jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, params["wdt"], preferred_element_type=jnp.float32)
        + params["dt_bias"])                                   # (B,S,H) fp32
    A = -jnp.exp(params["A_log"])                              # (H,) negative
    log_a = dt * A                                             # (B,S,H) ≤ 0

    xh = xin.reshape(Bsz, S, H, P).astype(jnp.float32)
    dtx = dt[..., None] * xh                                   # (B,S,H,P)

    if mode == "decode":
        assert cache is not None
        a = jnp.exp(log_a[:, 0])                               # (B,H)
        h_new = cache["h"] * a[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", dtx[:, 0], Bmat[:, 0])
        y = jnp.einsum("bhpn,bn->bhp", h_new, Cmat[:, 0])
        y = y + params["D"][None, :, None] * xh[:, 0]
        y = y.reshape(Bsz, 1, H * P).astype(x.dtype)
        out = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps)
        out = jnp.einsum("bse,ed->bsd", out, params["w_out"],
                         preferred_element_type=jnp.float32).astype(x.dtype)
        return out, {"h": h_new, "conv": new_conv}

    # ---------------- chunked dual form ---------------- #
    L = min(cfg.ssd_chunk, S)
    if S % L != 0:
        L = S
    n_chunks = S // L

    def to_chunks(t):
        return t.reshape((Bsz, n_chunks, L) + t.shape[2:])

    log_a_c = to_chunks(log_a)       # (B,c,L,H)
    dtx_c = to_chunks(dtx)           # (B,c,L,H,P)
    B_c = to_chunks(Bmat)            # (B,c,L,N)
    C_c = to_chunks(Cmat)            # (B,c,L,N)

    h0 = cache["h"] if cache is not None else jnp.zeros((Bsz, H, P, N), jnp.float32)

    causal = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(h, inputs):
        la, dx, Bc, Cc = inputs                       # (B,L,H) (B,L,H,P) (B,L,N) (B,L,N)
        cum = jnp.cumsum(la, axis=1)                  # inclusive (B,L,H)
        # intra-chunk dual (attention-like) term
        CB = jnp.einsum("btn,bsn->bts", Cc, Bc)       # (B,L,L)
        # clamp the (masked-out) s > t entries before exp — they would
        # overflow to inf and poison the mask-multiply with inf*0=NaN.
        # For s ≤ t the exponent is ≤ 0, so the clamp is exact.
        decay = jnp.exp(jnp.minimum(cum[:, :, None, :] - cum[:, None, :, :], 0.0))
        M = CB[..., None] * decay * causal[None, :, :, None]
        y_intra = jnp.einsum("btsh,bshp->bthp", M, dx)
        # inter-chunk: contribution of carried state
        y_inter = jnp.exp(cum)[..., None] * jnp.einsum("btn,bhpn->bthp", Cc, h)
        # state update
        w_tail = jnp.exp(cum[:, -1:, :] - cum)        # (B,L,H)
        h_new = h * jnp.exp(cum[:, -1])[..., None, None] + jnp.einsum(
            "bsh,bshp,bsn->bhpn", w_tail, dx, Bc)
        return h_new, y_intra + y_inter

    h_fin, y = jax.lax.scan(chunk_step, h0,
                            (jnp.moveaxis(log_a_c, 1, 0),
                             jnp.moveaxis(dtx_c, 1, 0),
                             jnp.moveaxis(B_c, 1, 0),
                             jnp.moveaxis(C_c, 1, 0)))
    y = jnp.moveaxis(y, 0, 1).reshape(Bsz, S, H, P)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(Bsz, S, H * P).astype(x.dtype)
    out = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", out, params["w_out"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    new_cache = {"h": h_fin, "conv": new_conv} if cache is not None else None
    return out, new_cache
