"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The Griffin recurrent block is:

    x ─ norm ─┬─ linear → GeLU ────────────────────────┐
              └─ linear → conv1d(4) → RG-LRU ──────────┴─ ⊙ ─ linear → out

RG-LRU recurrence (per channel):
    r_t = σ(W_a x_t + b_a)                  (recurrence gate)
    i_t = σ(W_x x_t + b_x)                  (input gate)
    a_t = a^(c·r_t)        a = σ(Λ) ∈ (0,1)  (learned decay, c = 8)
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Prefill/train uses an associative scan over time (log-depth on TPU);
decode is the O(1) recurrent update.  The recurrence width shards on the
mesh "model" axis — channels are independent, so the scan needs no
cross-device communication (this is the TPU-native adaptation of the
paper-family's sequential CUDA kernel).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.params import bias as bias_init
from repro.models.params import linear, split_tree_of

__all__ = ["rglru_init", "rglru_apply", "init_rglru_cache"]

_C = 8.0  # Griffin's fixed exponent scale


def rglru_init(key: jax.Array, cfg: ArchConfig, dtype):
    d = cfg.d_model
    r = cfg.rnn_width or cfg.d_model
    ks = jax.random.split(key, 7)
    # Λ init so that a = σ(Λ)^c spreads decay rates in (0.9, 0.999)
    u = jax.random.uniform(ks[0], (r,), jnp.float32, 0.9, 0.999)
    lam = jnp.log((u ** (1.0 / _C)) / (1.0 - u ** (1.0 / _C)))
    mixed = {
        "w_gate_branch": linear(ks[1], (d, r), ("embed", "rnn"), fan_in=d, dtype=dtype),
        "w_rec_branch": linear(ks[2], (d, r), ("embed", "rnn"), fan_in=d, dtype=dtype),
        "conv_w": linear(ks[3], (cfg.conv_width, r), (None, "rnn"),
                         fan_in=cfg.conv_width, dtype=dtype),
        "conv_b": bias_init((r,), ("rnn",), dtype),
        "w_a": linear(ks[4], (r, r), ("rnn", None), fan_in=r, dtype=dtype),
        "b_a": bias_init((r,), (None,), jnp.float32),
        "w_i": linear(ks[5], (r, r), ("rnn", None), fan_in=r, dtype=dtype),
        "b_i": bias_init((r,), (None,), jnp.float32),
        "lam": (lam, ("rnn",)),
        "w_out": linear(ks[6], (r, d), ("rnn", "embed"), fan_in=r, dtype=dtype),
    }
    return split_tree_of(mixed)


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    r = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, r), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, r), dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d.  x: (B, S, r), w: (K, r).  Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)              # (B, S+K-1, r)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K)) + b
    return y.astype(x.dtype), xp[:, -(K - 1):]


def _rglru_gates(params, xr: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compute (log_a, gated_input) in fp32.  xr: (..., r)."""
    xf = xr.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32) + params["b_a"])
    i_gate = jax.nn.sigmoid(xf @ params["w_i"].astype(jnp.float32) + params["b_i"])
    log_a = -_C * r_gate * jax.nn.softplus(params["lam"])   # log a_t ≤ 0
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i_gate * xf)
    return log_a, gated


def rglru_apply(params: Dict[str, jnp.ndarray], x: jnp.ndarray, *,
                cfg: ArchConfig, mode: str,
                cache: Optional[Dict[str, jnp.ndarray]] = None,
                ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """x: (B, S, D) -> (out (B, S, D), new_cache)."""
    gate_branch = jax.nn.gelu(
        jnp.einsum("bsd,dr->bsr", x, params["w_gate_branch"],
                   preferred_element_type=jnp.float32)).astype(x.dtype)
    xr = jnp.einsum("bsd,dr->bsr", x, params["w_rec_branch"],
                    preferred_element_type=jnp.float32).astype(x.dtype)

    conv_state = cache["conv"] if cache is not None else None
    xr, new_conv = _causal_conv(xr, params["conv_w"], params["conv_b"], conv_state)

    log_a, gated = _rglru_gates(params, xr)

    if mode == "decode":
        assert cache is not None
        h = cache["h"] * jnp.exp(log_a[:, 0]) + gated[:, 0]   # (B, r)
        hs = h[:, None, :]
        new_cache = {"h": h, "conv": new_conv}
    else:
        h0 = cache["h"] if cache is not None else jnp.zeros(
            (x.shape[0], xr.shape[-1]), jnp.float32)
        # associative scan over time: elements (A=exp(log_a), b=gated)
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        A = jnp.exp(log_a)                                    # (B, S, r)
        hs_a, hs_b = jax.lax.associative_scan(combine, (A, gated), axis=1)
        hs = hs_b + hs_a * h0[:, None, :]
        new_cache = None
        if cache is not None:
            new_cache = {"h": hs[:, -1], "conv": new_conv}

    out = hs.astype(x.dtype) * gate_branch
    out = jnp.einsum("bsr,rd->bsd", out, params["w_out"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, new_cache
