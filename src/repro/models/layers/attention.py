"""Attention: GQA with RoPE/NoPE, global / sliding-window / chunked-local
variants, KV-cache decode (full and ring-buffer), optional int8 cache,
cross-attention for encoder–decoder stacks.

Memory discipline: prefill never materializes the full (S, S) logits — the
query dimension is processed in ``q_chunk`` blocks via ``lax.scan`` and each
block sees only the key span its mask admits:

    global/NoPE   key span = all keys ≤ chunk end      O(S·S) flops, O(S·C) mem
    sliding W     key span = C + W_pad                  O(S·W)
    chunked C_a   key span = its own attention chunk    O(S·C_a)

GQA is computed by broadcasting kv heads to q heads (``jnp.repeat``) so the
head dimension shards cleanly on the mesh "model" axis; XLA fuses the
broadcast into the einsum.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, BlockKind
from repro.models.layers.quant import linear_or_quant
from repro.models.layers.rope import apply_rope
from repro.models.params import bias as bias_init
from repro.models.params import linear, split_tree_of

__all__ = ["attn_init", "attn_apply", "init_kv_cache", "NEG_INF"]

NEG_INF = -2.0e38  # fp32-safe mask value


# --------------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------------- #
def attn_init(key: jax.Array, cfg: ArchConfig, dtype, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    mixed: Dict[str, Any] = {
        "wq": linear(ks[0], (d, h, hd), ("embed", "heads", "head"), fan_in=d, dtype=dtype),
        "wk": linear(ks[1], (d, kv, hd), ("embed", "kv_heads", "head"), fan_in=d, dtype=dtype),
        "wv": linear(ks[2], (d, kv, hd), ("embed", "kv_heads", "head"), fan_in=d, dtype=dtype),
        "wo": linear(ks[3], (h, hd, d), ("heads", "head", "embed"), fan_in=h * hd, dtype=dtype),
    }
    if cfg.qkv_bias:
        mixed["bq"] = bias_init((h, hd), ("heads", "head"), dtype)
        mixed["bk"] = bias_init((kv, hd), ("kv_heads", "head"), dtype)
        mixed["bv"] = bias_init((kv, hd), ("kv_heads", "head"), dtype)
    return split_tree_of(mixed)


# --------------------------------------------------------------------------- #
# kv cache
# --------------------------------------------------------------------------- #
def init_kv_cache(cfg: ArchConfig, kind: BlockKind, batch: int, max_seq: int,
                  dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """Cache for ONE attention layer.  Local/chunked layers keep a ring of
    ``window``/``attn_chunk`` slots; global layers keep ``max_seq``."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if kind == BlockKind.ATTN_LOCAL:
        slots = min(cfg.window, max_seq)
    elif kind == BlockKind.ATTN_CHUNKED:
        slots = min(cfg.attn_chunk, max_seq)
    else:
        slots = max_seq
    if cfg.cache_dtype == "int8":
        return {
            "k": jnp.zeros((batch, slots, kv, hd), jnp.int8),
            "v": jnp.zeros((batch, slots, kv, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, slots, kv), jnp.float32),
            "v_scale": jnp.zeros((batch, slots, kv), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, slots, kv, hd), dtype),
        "v": jnp.zeros((batch, slots, kv, hd), dtype),
    }


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _cache_write(cache: Dict[str, jnp.ndarray], slot: jnp.ndarray,
                 k_new: jnp.ndarray, v_new: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Write one token (B, kv, hd) at ring slot ``slot`` — a scalar (all
    batch rows share the position) or a (B,) vector (continuous-batching
    decode, where every slot sits at its own position)."""
    out = dict(cache)
    slot = jnp.asarray(slot)
    if slot.ndim == 0:
        idx = (slice(None), slot)
    else:
        idx = (jnp.arange(k_new.shape[0]), slot)
    if "k_scale" in cache:
        kq, ks = _quantize(k_new)
        vq, vs = _quantize(v_new)
        out["k"] = cache["k"].at[idx].set(kq)
        out["v"] = cache["v"].at[idx].set(vq)
        out["k_scale"] = cache["k_scale"].at[idx].set(ks)
        out["v_scale"] = cache["v_scale"].at[idx].set(vs)
    else:
        out["k"] = cache["k"].at[idx].set(k_new.astype(cache["k"].dtype))
        out["v"] = cache["v"].at[idx].set(v_new.astype(cache["v"].dtype))
    return out


def _cache_read(cache: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if "k_scale" in cache:
        k = cache["k"].astype(jnp.float32) * cache["k_scale"][..., None]
        v = cache["v"].astype(jnp.float32) * cache["v_scale"][..., None]
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    return cache["k"], cache["v"]


# --------------------------------------------------------------------------- #
# core attention math
# --------------------------------------------------------------------------- #
def _sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
          mask: jnp.ndarray, scale: float) -> jnp.ndarray:
    """q: (B, Sq, H, hd)  k/v: (B, Sk, H, hd)  mask: (B|1, 1, Sq, Sk) bool.
    fp32 softmax, bf16 matmuls with fp32 accumulation."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    return jnp.repeat(k, groups, axis=2) if groups > 1 else k


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# --------------------------------------------------------------------------- #
# prefill / train forward
# --------------------------------------------------------------------------- #
def _prefill_attend(q, k, v, kind: BlockKind, cfg: ArchConfig,
                    positions: jnp.ndarray) -> jnp.ndarray:
    """q/k/v: (B, S, H, hd) with kv already broadcast to H heads.
    positions: (S,) int32 absolute positions (shared across batch)."""
    B, S, H, hd = q.shape
    scale = hd ** -0.5
    C = min(cfg.q_chunk, S)
    if S % C != 0:
        C = S  # fall back to single block (configs keep shapes divisible)
    n_chunks = S // C

    pos_q_all = positions
    pos_k_all = positions

    def mask_for(pq, pk):
        m = pk[None, :] <= pq[:, None] if cfg.causal else jnp.ones((pq.shape[0], pk.shape[0]), bool)
        if kind == BlockKind.ATTN_LOCAL:
            m = m & (pq[:, None] - pk[None, :] < cfg.window)
        elif kind == BlockKind.ATTN_CHUNKED:
            m = m & ((pq[:, None] // cfg.attn_chunk) == (pk[None, :] // cfg.attn_chunk))
        return m[None, None]  # (1, 1, Sq, Sk)

    if n_chunks == 1:
        return _sdpa(q, k, v, mask_for(pos_q_all, pos_k_all), scale)

    # key span per chunk kind
    if kind == BlockKind.ATTN_LOCAL:
        span = C + _round_up(cfg.window, C)
    elif kind == BlockKind.ATTN_CHUNKED:
        span = max(cfg.attn_chunk, C)
    else:
        span = S  # causal global: masked full span (flash kernel is the
        #           optimized path; see repro.kernels.flash_attention)

    q_c = q.reshape(B, n_chunks, C, H, hd)

    def body(_, i):
        qi = q_c[:, i]                                   # (B, C, H, hd)
        q_start = i * C
        pos_q = jax.lax.dynamic_slice_in_dim(pos_q_all, q_start, C)
        if span >= S:
            ki, vi, pos_k = k, v, pos_k_all
        else:
            if kind == BlockKind.ATTN_CHUNKED:
                start = (q_start // cfg.attn_chunk) * cfg.attn_chunk
            else:
                start = q_start + C - span
            start = jnp.clip(start, 0, S - span)
            ki = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            pos_k = jax.lax.dynamic_slice_in_dim(pos_k_all, start, span)
        out = _sdpa(qi, ki, vi, mask_for(pos_q, pos_k), scale)
        return None, out

    _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))
    # outs: (n_chunks, B, C, H, hd) -> (B, S, H, hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)


# --------------------------------------------------------------------------- #
# public apply
# --------------------------------------------------------------------------- #
def attn_apply(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    *,
    cfg: ArchConfig,
    kind: BlockKind,
    mode: str,                       # "prefill" | "decode"
    positions: Optional[jnp.ndarray] = None,   # (S,) prefill positions
    pos: Optional[jnp.ndarray] = None,         # scalar decode position
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    kv_src: Optional[jnp.ndarray] = None,      # cross-attention source (B,Se,D)
    is_cross: bool = False,
    use_rope: bool = True,
    lengths: Optional[jnp.ndarray] = None,     # (B,) ragged prefill lengths
    start_pos: Optional[jnp.ndarray] = None,   # (B,) tail-prefill offsets
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Returns (output (B,S,D), updated_cache_or_None)."""
    B, S, D = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    groups = h // kv
    rope_on = use_rope and kind != BlockKind.ATTN_NOPE

    q = linear_or_quant(x, params["wq"], "bsd,dhk->bshk")
    if "bq" in params:
        q = q + params["bq"]

    cross = is_cross or kv_src is not None
    if cross and cache is not None and mode == "decode":
        # cross K/V were cached at prefill; nothing to project
        k, v = _cache_read(cache)
        new_cache = cache
    else:
        src = kv_src if cross else x
        k = linear_or_quant(src, params["wk"], "bsd,dhk->bshk")
        v = linear_or_quant(src, params["wv"], "bsd,dhk->bshk")
        if "bk" in params:
            k = k + params["bk"]
            v = v + params["bv"]
        new_cache = None

    if mode == "prefill":
        if cross:
            pos_q = positions if positions is not None else jnp.arange(S)
            if rope_on:
                q = apply_rope(q, pos_q[None, :], cfg.rope_base)
            kf = _repeat_kv(k, groups)
            vf = _repeat_kv(v, groups)
            mask = jnp.ones((1, 1, S, k.shape[1]), bool)
            out = _sdpa(q, kf, vf, mask, hd ** -0.5)
            cross_cache = {"k": k, "v": v} if cache is not None else None
            return _out_proj(out, params), cross_cache
        if start_pos is not None:
            return _prefill_offset(params, q, k, v, cfg=cfg, kind=kind,
                                   cache=cache, lengths=lengths,
                                   start_pos=start_pos, groups=groups,
                                   rope_on=rope_on)
        pos_q = positions if positions is not None else jnp.arange(S)
        if rope_on:
            q = apply_rope(q, pos_q[None, :], cfg.rope_base)
            k = apply_rope(k, pos_q[None, :], cfg.rope_base)
        if cache is not None:
            # write the (possibly windowed) tail of K/V into the cache for
            # subsequent decode
            cache = _prefill_fill_cache(cache, k, v, lengths)
        out = _prefill_attend(q, _repeat_kv(k, groups), _repeat_kv(v, groups),
                              kind, cfg, pos_q)
        return _out_proj(out, params), cache

    # ---------------- decode: S == 1, attend to cache ---------------- #
    # ``pos`` is a scalar (all rows at the same position) or a (B,) vector
    # (continuous batching: every slot decodes at its own position).  The
    # scalar form is the vector form with identical rows, so one code path
    # serves both.
    assert mode == "decode" and cache is not None and pos is not None
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    pos_b = pos if per_slot else jnp.broadcast_to(pos, (B,))   # (B,)
    if rope_on:
        q = apply_rope(q, pos_b[:, None], cfg.rope_base)
    if not cross:
        if rope_on:
            k = apply_rope(k, pos_b[:, None], cfg.rope_base)
        slots = cache["k"].shape[1]
        cache = _cache_write(cache, (pos_b if per_slot else pos) % slots,
                             k[:, 0], v[:, 0])
        kc, vc = _cache_read(cache)
        slot_ids = jnp.arange(slots)
        # most recent position ≡ slot (mod slots) that is ≤ pos, per row
        pc = pos_b[:, None]                                    # (B, 1)
        slot_pos = pc - (pc - slot_ids[None, :]) % slots       # (B, slots)
        valid = slot_pos >= 0
        if kind == BlockKind.ATTN_LOCAL:
            valid &= slot_pos > pc - cfg.window
        elif kind == BlockKind.ATTN_CHUNKED:
            valid &= (slot_pos // cfg.attn_chunk) == (pc // cfg.attn_chunk)
        else:
            valid &= slot_pos <= pc
        mask = valid[:, None, None, :]
        new_cache = cache
    else:
        kc, vc = _cache_read(cache)
        mask = jnp.ones((1, 1, 1, kc.shape[1]), bool)
        new_cache = cache
    out = _sdpa(q, _repeat_kv(kc, groups), _repeat_kv(vc, groups), mask, hd ** -0.5)
    return _out_proj(out, params), new_cache


def _prefill_offset(params, q, k, v, *, cfg: ArchConfig, kind: BlockKind,
                    cache, lengths, start_pos, groups: int, rope_on: bool):
    """Offset ragged prefill: slot b's tokens are its prompt *tail*,
    occupying absolute positions ``start_pos[b] .. start_pos[b]+lengths[b]-1``
    on top of a cache whose ring already holds the prefix K/V (restored by
    ``serve/prefix_cache.py``).  Tail queries attend the concatenation of
    the prefix cache (read BEFORE the tail write, so restored bits are
    attended verbatim) and the in-flight tail keys, each under its exact
    positional mask; rows with ``start_pos == 0`` see no valid prefix
    slot, so one compiled program serves hit and miss rows alike."""
    if cache is None or lengths is None:
        raise ValueError("start_pos= prefill needs cache= and lengths=")
    B, S = q.shape[0], q.shape[1]
    hd = cfg.resolved_head_dim
    start = jnp.asarray(start_pos, jnp.int32)                  # (B,)
    L = jnp.asarray(lengths, jnp.int32)                        # (B,)
    pos_bq = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    if rope_on:
        q = apply_rope(q, pos_bq, cfg.rope_base)
        k = apply_rope(k, pos_bq, cfg.rope_base)
    kc, vc = _cache_read(cache)
    ring = cache["k"].shape[1]
    slot_ids = jnp.arange(ring, dtype=jnp.int32)[None, :]      # (1, ring)
    last = (start - 1)[:, None]                                # (B, 1)
    # the position each ring slot holds: largest value ≡ slot (mod ring)
    # that is ≤ start-1; negative ⇒ never written (start=0 rows: all)
    slot_pos = last - (last - slot_ids) % ring                 # (B, ring)
    mp = (slot_pos >= 0)[:, None, :]
    pq = pos_bq[:, :, None]                                    # (B, S, 1)
    if kind == BlockKind.ATTN_LOCAL:
        mp = mp & (pq - slot_pos[:, None, :] < cfg.window)
    elif kind == BlockKind.ATTN_CHUNKED:
        mp = mp & ((slot_pos[:, None, :] // cfg.attn_chunk)
                   == (pq // cfg.attn_chunk))
    else:
        # global: prefix positions (≤ start-1) precede every tail query,
        # so causality is automatic — the term only shapes mp to (B,S,r)
        mp = mp & (slot_pos[:, None, :] <= pq)
    # tail self-attention: ragged causal over real tail columns
    jk = jnp.arange(S, dtype=jnp.int32)[None, None, :]
    pk = pos_bq[:, None, :]                                    # (B, 1, S)
    mt = (jk < L[:, None, None]) & (pk <= pq)
    if kind == BlockKind.ATTN_LOCAL:
        mt = mt & (pq - pk < cfg.window)
    elif kind == BlockKind.ATTN_CHUNKED:
        mt = mt & ((pk // cfg.attn_chunk) == (pq // cfg.attn_chunk))
    mask = jnp.concatenate([mp, mt], axis=-1)[:, None]         # (B,1,S,r+S)
    k_all = jnp.concatenate([kc.astype(k.dtype), k], axis=1)
    v_all = jnp.concatenate([vc.astype(v.dtype), v], axis=1)
    out = _sdpa(q, _repeat_kv(k_all, groups), _repeat_kv(v_all, groups),
                mask, hd ** -0.5)
    cache = _prefill_fill_cache(cache, k, v, lengths, start=start)
    return _out_proj(out, params), cache


def _prefill_fill_cache(cache, k, v, lengths=None, start=None):
    """Copy the last ``slots`` tokens of prefill K/V into the decode cache,
    laid out so ring addressing (slot = pos % slots) stays consistent.

    ``lengths=None`` is the classic equal-length path.  With ``lengths``
    (B,), the prompts are *right-padded* to a common S and slot b's real
    tokens occupy columns 0..lengths[b]-1: each row keeps the last
    ``min(lengths[b], slots)`` real columns and every pad / evicted column
    is routed to an out-of-bounds destination and dropped by the scatter —
    pad tokens never enter the cache, so the decode-side validity mask
    (slot_pos ≤ pos) stays exact per slot.

    ``start`` (B,) shifts row b's columns to absolute positions
    ``start[b]+j`` (tail prefill over a restored prefix — see
    ``_prefill_offset``): the kept window becomes the last ≤``slots``
    positions before ``start[b]+lengths[b]``, so prefix entries still
    inside the ring are never clobbered and the final ring state is
    exactly what a full prefill of the whole prompt would have left."""
    B, S = k.shape[0], k.shape[1]
    slots = cache["k"].shape[1]
    out = dict(cache)
    if lengths is None:
        take = min(S, slots)
        ks = k[:, S - take:]
        vs = v[:, S - take:]
        # position of ks[:, j] is (S - take + j); its slot is that mod slots
        pos0 = S - take
        dest = (pos0 + jnp.arange(take)) % slots
        if "k_scale" in cache:
            kq, ksc = _quantize(ks)
            vq, vsc = _quantize(vs)
            out["k"] = cache["k"].at[:, dest].set(kq)
            out["v"] = cache["v"].at[:, dest].set(vq)
            out["k_scale"] = cache["k_scale"].at[:, dest].set(ksc)
            out["v_scale"] = cache["v_scale"].at[:, dest].set(vsc)
        else:
            out["k"] = cache["k"].at[:, dest].set(ks.astype(cache["k"].dtype))
            out["v"] = cache["v"].at[:, dest].set(vs.astype(cache["v"].dtype))
        return out

    L = jnp.asarray(lengths, jnp.int32)[:, None]               # (B, 1)
    j = jnp.arange(S, dtype=jnp.int32)[None, :]                # (1, S)
    if start is None:
        keep = (j < L) & (j >= L - slots)  # last ≤slots real columns per row
        dest = jnp.where(keep, j % slots, slots)  # ``slots`` OOB → dropped
    else:
        s0 = jnp.asarray(start, jnp.int32)[:, None]            # (B, 1)
        abspos = s0 + j
        keep = (j < L) & (abspos >= s0 + L - slots)
        dest = jnp.where(keep, abspos % slots, slots)
    bidx = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[:, None], (B, S))
    if "k_scale" in cache:
        kq, ksc = _quantize(k)
        vq, vsc = _quantize(v)
        out["k"] = cache["k"].at[bidx, dest].set(kq, mode="drop")
        out["v"] = cache["v"].at[bidx, dest].set(vq, mode="drop")
        out["k_scale"] = cache["k_scale"].at[bidx, dest].set(ksc, mode="drop")
        out["v_scale"] = cache["v_scale"].at[bidx, dest].set(vsc, mode="drop")
    else:
        out["k"] = cache["k"].at[bidx, dest].set(
            k.astype(cache["k"].dtype), mode="drop")
        out["v"] = cache["v"].at[bidx, dest].set(
            v.astype(cache["v"].dtype), mode="drop")
    return out


def _out_proj(out: jnp.ndarray, params) -> jnp.ndarray:
    return linear_or_quant(out, params["wo"], "bshk,hkd->bsd")
