"""Token embedding / LM head and positional tables."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.params import split_tree_of, table

__all__ = ["embed_init", "embed_tokens", "logits_from", "sinusoidal_positions"]


def embed_init(key: jax.Array, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 3)
    mixed = {"tok": table(ks[0], (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), dtype)}
    if not cfg.tie_embeddings:
        mixed["head"] = table(ks[1], (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dtype)
    if cfg.learned_pos:
        mixed["pos"] = table(ks[2], (cfg.max_seq_len, cfg.d_model), (None, "embed"), dtype)
    return split_tree_of(mixed)


def embed_tokens(params: Dict[str, jnp.ndarray], tokens: jnp.ndarray,
                 positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    x = jnp.take(params["tok"], tokens, axis=0)
    if "pos" in params and positions is not None:
        x = x + jnp.take(params["pos"], positions, axis=0)[None, ...] if positions.ndim == 1 \
            else x + jnp.take(params["pos"], positions, axis=0)
    return x


def logits_from(params: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, D) -> (B, S, V), fp32."""
    if "head" in params:
        return jnp.einsum("bsd,dv->bsv", x, params["head"],
                          preferred_element_type=jnp.float32)
    return jnp.einsum("bsd,vd->bsv", x, params["tok"],
                      preferred_element_type=jnp.float32)


def sinusoidal_positions(seq: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)[:, :d].astype(dtype)
