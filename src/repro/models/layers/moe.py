"""Mixture-of-Experts with capacity-factor token dispatch (llama4 16e top-1,
mixtral 8e top-2).

Tokens are processed in *groups* so the one-hot dispatch tensor stays
VMEM-friendly and GSPMD turns the dispatch/combine einsums into all-to-alls
when the expert dimension is sharded (expert parallelism):

    dispatch D: (g, s, E, C)   expert_in  = einsum('gsec,gsd->egcd', D, x)
    combine  W: (g, s, E, C)   out        = einsum('gsec,egcd->gsd', W, y)

Router load-balance auxiliary loss follows Switch/Mixtral:
``aux = E * Σ_e f_e · p_e`` (fraction routed · mean gate prob).

A shared expert (llama4) is a normal SwiGLU MLP applied to every token whose
output is summed with the routed output.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers.mlp import mlp_apply, mlp_init
from repro.models.params import linear, split_tree_of

__all__ = ["moe_init", "moe_apply", "moe_capacity"]

GROUP = 2048  # tokens per dispatch group (VMEM sizing; see DESIGN.md)


def moe_capacity(cfg: ArchConfig, group: int) -> int:
    cap = int(group * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, ((cap + 7) // 8) * 8)  # pad to 8 for TPU lanes


def moe_init(key: jax.Array, cfg: ArchConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    mixed = {
        "router": linear(ks[0], (d, e), ("embed", "experts"), fan_in=d, dtype=jnp.float32),
        "w_gate": linear(ks[1], (e, d, f), ("experts", "embed", "ffn"), fan_in=d, dtype=dtype),
        "w_up": linear(ks[2], (e, d, f), ("experts", "embed", "ffn"), fan_in=d, dtype=dtype),
        "w_down": linear(ks[3], (e, f, d), ("experts", "ffn", "embed"), fan_in=f, dtype=dtype),
    }
    params, axes = split_tree_of(mixed)
    if cfg.shared_expert:
        sp, sa = mlp_init(ks[4], cfg, dtype)
        params["shared"], axes["shared"] = sp, sa
    return params, axes


def _top_k_dispatch(gates: jnp.ndarray, k: int, capacity: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """gates: (g, s, E) softmax probs.  Returns (dispatch, combine, aux_loss).

    dispatch/combine: (g, s, E, C).  Tokens beyond an expert's capacity are
    dropped (standard capacity-factor semantics)."""
    g, s, e = gates.shape
    # top-k expert choices per token
    top_gates, top_idx = jax.lax.top_k(gates, k)            # (g, s, k)
    # renormalize the kept gates (mixtral convention)
    top_gates = top_gates / jnp.maximum(jnp.sum(top_gates, -1, keepdims=True), 1e-9)

    # expert mask per choice: (g, s, k, E)
    choice_mask = jax.nn.one_hot(top_idx, e, dtype=gates.dtype)

    # position of each (token, choice) in its expert's queue — cumulative
    # count over the flattened (s, k) order, choice-major within a token
    flat_mask = choice_mask.reshape(g, s * k, e)
    pos_in_expert = jnp.cumsum(flat_mask, axis=1) - flat_mask  # (g, s*k, E)
    pos_in_expert = jnp.sum(pos_in_expert * flat_mask, axis=-1)  # (g, s*k)
    keep = pos_in_expert < capacity
    flat_mask = flat_mask * keep[..., None]
    cap_onehot = jax.nn.one_hot(pos_in_expert.astype(jnp.int32), capacity,
                                dtype=gates.dtype)  # (g, s*k, C)
    disp_flat = flat_mask[..., None] * cap_onehot[..., None, :]  # (g, s*k, E, C)
    disp = disp_flat.reshape(g, s, k, e, capacity)

    combine = disp * top_gates[..., None, None]
    dispatch = jnp.sum(disp, axis=2)                         # (g, s, E, C)
    combine = jnp.sum(combine, axis=2)

    # Switch aux loss: fraction of tokens per expert × mean router prob
    frac = jnp.mean(jnp.sum(choice_mask, axis=2), axis=(0, 1))  # (E,) routed frac (per choice)
    prob = jnp.mean(gates, axis=(0, 1))                         # (E,)
    aux = e * jnp.sum(frac * prob) / k
    return dispatch, combine, aux


def _gather_dispatch_apply(params, xg, gates, k: int, capacity: int,
                           act_dtype) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """§Perf alternative to the one-hot dispatch: sort-based index routing.

    The einsum path materializes (g, s, E, C) dispatch/combine tensors —
    an E·C/1 blowup over the token count (2560× for llama4) that dominates
    the memory roofline term.  Here each (token, choice) gets an integer
    slot in the (E·C, d) expert buffer via a stable sort by expert id;
    traffic is O(s·k·d) scatter + gather plus an O(s·k log) sort.  Drop
    semantics match the einsum path exactly (stable sort preserves the
    flat (s, k) arrival order within an expert).

    xg: (g, s, d), gates: (g, s, E).  Returns (out (g, s, d), aux).
    """
    g, s, e = gates.shape
    d = xg.shape[-1]
    top_gates, top_idx = jax.lax.top_k(gates, k)            # (g, s, k)
    top_gates = top_gates / jnp.maximum(jnp.sum(top_gates, -1, keepdims=True), 1e-9)

    def route_one(xg1, idx1, gate1):
        # xg1 (s, d), idx1 (s, k), gate1 (s, k)
        sk = s * k
        e_f = idx1.reshape(sk)
        order = jnp.argsort(e_f, stable=True)               # (sk,)
        e_sorted = e_f[order]
        start = jnp.searchsorted(e_sorted, jnp.arange(e), side="left")
        pos = jnp.arange(sk) - start[e_sorted]
        keep = pos < capacity
        dest_sorted = jnp.where(keep, e_sorted * capacity + pos, e * capacity)
        token_of = order // k
        # scatter tokens into the (E·C, d) buffer; sentinel rows drop
        buf = jnp.zeros((e * capacity, d), act_dtype).at[dest_sorted].set(
            xg1[token_of], mode="drop")
        # per-choice destination in original (s, k) order (sentinel = dropped)
        dest_f = jnp.full((sk,), e * capacity, jnp.int32).at[order].set(
            dest_sorted.astype(jnp.int32))
        return buf.reshape(e, capacity, d), dest_f.reshape(s, k)

    expert_in, dest = jax.vmap(route_one)(xg, top_idx, top_gates)
    # expert_in: (g, E, C, d) -> run experts exactly like the einsum path
    gate_h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"],
                                    preferred_element_type=jnp.float32))
    up = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"],
                    preferred_element_type=jnp.float32)
    h = (gate_h * up).astype(act_dtype)
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"],
                            preferred_element_type=jnp.float32).astype(act_dtype)

    def combine_one(out1, dest1, gate1):
        flat = out1.reshape(e * capacity, d)
        picked = jnp.take(flat, dest1.reshape(-1), axis=0, mode="fill",
                          fill_value=0)                      # (s·k, d)
        picked = picked.reshape(s, k, d)
        return jnp.sum(picked * gate1[..., None].astype(act_dtype), axis=1)

    out = jax.vmap(combine_one)(expert_out, dest, top_gates)

    # Switch aux loss (identical to the einsum path)
    choice_mask = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)
    frac = jnp.mean(jnp.sum(choice_mask, axis=2), axis=(0, 1))
    prob = jnp.mean(gates.astype(jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(frac * prob) / k
    return out, aux


def moe_apply(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
              cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss)."""
    B, S, D = x.shape
    tokens = B * S
    group = min(GROUP, tokens)
    if tokens % group != 0:
        group = tokens  # degenerate small-shape fallback
    n_groups = tokens // group
    cap = moe_capacity(cfg, group)

    xg = x.reshape(n_groups, group, D)
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), params["router"])
    gates = jax.nn.softmax(logits, axis=-1)

    if cfg.moe_dispatch == "gather":
        out, aux = _gather_dispatch_apply(params, xg, gates, cfg.top_k, cap,
                                          x.dtype)
        out = out.reshape(B, S, D)
        if "shared" in params:
            out = out + mlp_apply(params["shared"], x)
        return out, aux.astype(jnp.float32)

    dispatch, combine, aux = _top_k_dispatch(gates, cfg.top_k, cap)

    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), xg,
                           preferred_element_type=jnp.float32).astype(x.dtype)
    gate = jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in, params["w_gate"],
                                  preferred_element_type=jnp.float32))
    up = jnp.einsum("egcd,edf->egcf", expert_in, params["w_up"],
                    preferred_element_type=jnp.float32)
    h = (gate * up).astype(x.dtype)
    expert_out = jnp.einsum("egcf,efd->egcd", h, params["w_down"],
                            preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), expert_out,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(B, S, D)
    if "shared" in params:
        out = out + mlp_apply(params["shared"], x)
    return out, aux.astype(jnp.float32)
