"""Rotary position embeddings (applied per-layer; NoPE layers skip this)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["apply_rope"]


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, base: float = 10000.0) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32.

    Rotates pairs (x[2i], x[2i+1]) by angle pos / base^(2i/d).  Odd head_dim
    rotates the even prefix only (whisper head_dim=64 is even; this guard is
    for reduced smoke variants).
    """
    d = x.shape[-1]
    half = d // 2
    freq = jnp.arange(half, dtype=jnp.float32)
    inv = base ** (-freq / half)                       # (half,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]                   # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half : 2 * half].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.concatenate([r1, r2] + ([x[..., 2 * half:].astype(jnp.float32)] if d % 2 else []),
                          axis=-1)
    return out.astype(x.dtype)
