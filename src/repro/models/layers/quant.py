"""Weight quantization for the serving fast path.

``QTensor`` packs a weight as symmetric per-output-channel int8 plus fp32
scales.  It is a registered pytree whose two children (``q``, ``scale``)
both carry any leading stacked "layers" axis, so a quantized weight rides
``jax.lax.scan`` over periods exactly like a plain array: the scan slices
period ``p`` out of both children and the layer sees a QTensor of the
original per-layer shape.

Layers dispatch on type: a plain ``jnp.ndarray`` keeps the literal einsum
(bit-identical to the fp32 path — the regression suites depend on this),
a ``QTensor`` routes through ``ops.quant_matmul`` (Pallas int8 kernel on
TPU, fp32-cast dequantized accumulation elsewhere — see that wrapper's
docstring for the exactness bound).

Weight layout convention (true for every projection in ``models/layers``):
the *contracted* axes lead and the *output-channel* axes trail, so
``n_contract`` pins the split — wq/wk/wv ``(d | h, hd)`` → n_contract 1,
wo ``(h, hd | d)`` → 2, mlp w_gate/w_up/w_down ``(d | f)`` / ``(f | d)``
→ 1.  ``scale`` has the output-channel (and any stacked) axes only.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.kernels import ops

__all__ = ["QTensor", "quantize_weight", "linear_or_quant",
           "quantize_model_params"]


@jax.tree_util.register_pytree_node_class
class QTensor:
    """int8 weight + per-output-channel fp32 scales.  ``q`` keeps the
    original weight shape; ``scale`` drops the ``n_contract`` contracted
    axes (which sit immediately after any stacked batch axes)."""

    def __init__(self, q, scale, n_contract: int):
        self.q = q
        self.scale = scale
        self.n_contract = n_contract

    def tree_flatten(self):
        return (self.q, self.scale), self.n_contract

    @classmethod
    def tree_unflatten(cls, n_contract, children):
        return cls(children[0], children[1], n_contract)

    def __repr__(self):
        return (f"QTensor(q={getattr(self.q, 'shape', None)}, "
                f"scale={getattr(self.scale, 'shape', None)}, "
                f"n_contract={self.n_contract})")


def quantize_weight(w: jnp.ndarray, n_contract: int,
                    n_batch: int = 0) -> QTensor:
    """Symmetric int8 quantization over the contracted axes (per output
    channel).  ``n_batch`` leading axes (the stacked "layers" axis) are
    kept on both ``q`` and ``scale`` so the result scans like the input."""
    axes = tuple(range(n_batch, n_batch + n_contract))
    wf = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(wf), axis=axes) / 127.0 + 1e-8
    sb = jnp.expand_dims(scale, axes)
    q = jnp.clip(jnp.round(wf / sb), -127, 127).astype(jnp.int8)
    return QTensor(q, scale, n_contract)


def _quant_contract(x: jnp.ndarray, w: QTensor) -> jnp.ndarray:
    """Contract ``x``'s trailing ``n_contract`` axes against ``w``'s leading
    ones: flatten both sides to a 2-D matmul, quantize the activation rows
    on the fly, and dequantize in the epilogue."""
    nc = w.n_contract
    K = math.prod(w.q.shape[:nc])
    out_shape = w.q.shape[nc:]
    xq, xs = ops.quantize_rows(x.reshape(-1, K))
    out = ops.quant_matmul(xq, xs, w.q.reshape(K, -1), w.scale.reshape(-1))
    return out.reshape(x.shape[:-nc] + out_shape).astype(x.dtype)


def linear_or_quant(x: jnp.ndarray, w, eq: str, **einsum_kwargs) -> jnp.ndarray:
    """The layer-side dispatch point: literal einsum for plain arrays
    (bit-identical to the pre-quantization code), quantized matmul for
    ``QTensor`` weights."""
    if isinstance(w, QTensor):
        return _quant_contract(x, w)
    return jnp.einsum(eq, x, w, **einsum_kwargs)


# weight name → n_contract, per module; everything absent stays fp32
# (biases, norms, embeddings, MoE experts, SSD/RG-LRU mixers).
_QUANT_SPECS: Dict[str, Dict[str, int]] = {
    "attn": {"wq": 1, "wk": 1, "wv": 1, "wo": 2},
    "cross": {"wq": 1, "wk": 1, "wv": 1, "wo": 2},
    "mlp": {"w_gate": 1, "w_up": 1, "w_down": 1},
}


def _quantize_blocks(blocks: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for bk, block in blocks.items():
        nb = dict(block)
        for mod, specs in _QUANT_SPECS.items():
            if mod in nb:
                m = dict(nb[mod])
                for name, nc in specs.items():
                    if name in m:
                        # stacked along the leading "layers" axis → n_batch=1
                        m[name] = quantize_weight(m[name], nc, n_batch=1)
                nb[mod] = m
        out[bk] = nb
    return out


def quantize_model_params(params: Dict[str, Any], mode: str) -> Dict[str, Any]:
    """Apply the ``ArchConfig.quantize`` knob to an initialized param tree.

    ``"none"`` returns the tree unchanged; ``"bf16"`` casts every floating
    leaf to bfloat16 (weight-only — activations keep the config dtype);
    ``"int8"`` quantizes the attention/MLP projections per
    ``_QUANT_SPECS`` and leaves everything else fp32."""
    if mode == "none":
        return params
    if mode == "bf16":
        return jax.tree.map(
            lambda a: a.astype(jnp.bfloat16)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, params)
    if mode != "int8":
        raise ValueError(f"quantize={mode!r} (want none|bf16|int8)")
    out = dict(params)
    out["blocks"] = _quantize_blocks(params["blocks"])
    if "encoder" in params:
        enc = dict(params["encoder"])
        enc["blocks"] = _quantize_blocks(enc["blocks"])
        out["encoder"] = enc
    return out
