"""Layer library for the architecture zoo."""
