"""Dense MLPs: SwiGLU (llama-family) and GELU (whisper/original transformer)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers.quant import linear_or_quant
from repro.models.params import linear, split_tree_of

__all__ = ["mlp_init", "mlp_apply"]


def mlp_init(key: jax.Array, cfg: ArchConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind == "swiglu":
        mixed = {
            "w_gate": linear(ks[0], (d, f), ("embed", "ffn"), fan_in=d, dtype=dtype),
            "w_up": linear(ks[1], (d, f), ("embed", "ffn"), fan_in=d, dtype=dtype),
            "w_down": linear(ks[2], (f, d), ("ffn", "embed"), fan_in=f, dtype=dtype),
        }
    elif cfg.mlp_kind == "gelu":
        mixed = {
            "w_up": linear(ks[1], (d, f), ("embed", "ffn"), fan_in=d, dtype=dtype),
            "w_down": linear(ks[2], (f, d), ("ffn", "embed"), fan_in=f, dtype=dtype),
        }
    else:
        raise ValueError(cfg.mlp_kind)
    return split_tree_of(mixed)


def mlp_apply(params: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    if "w_gate" in params:
        g = jax.nn.silu(linear_or_quant(x, params["w_gate"], "bsd,df->bsf",
                                        preferred_element_type=jnp.float32))
        u = linear_or_quant(x, params["w_up"], "bsd,df->bsf",
                            preferred_element_type=jnp.float32)
        h = (g * u).astype(x.dtype)
    else:
        h = jax.nn.gelu(linear_or_quant(x, params["w_up"], "bsd,df->bsf",
                                        preferred_element_type=jnp.float32)).astype(x.dtype)
    return linear_or_quant(h, params["w_down"], "bsf,fd->bsd",
                           preferred_element_type=jnp.float32).astype(x.dtype)
