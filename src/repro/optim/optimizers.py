"""Pytree optimizers for transformer training (beyond-paper substrate).

The paper treats optimization as a first-class citizen of the API (§III-C);
these extend the same contract from weight *vectors* (core.optimizer) to
parameter *pytrees*.  No optax dependency — each optimizer is an
``OptimizerDef(init, update)`` pair of pure functions.

State dtype policy: moments in fp32 regardless of param dtype (bf16 params
keep an implicit fp32 master copy via the fp32 `mu`-correction path being
applied in fp32 and cast back — adequate for the few-hundred-step example
runs; a full fp32 master-weight option is `master_weights=True`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptimizerDef", "adamw", "sgd_momentum", "lion"]


@dataclasses.dataclass(frozen=True)
class OptimizerDef:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, grad_clip: Optional[float] = 1.0,
          warmup: int = 100, master_weights: bool = False,
          schedule: str = "cosine", total_steps: int = 10000) -> OptimizerDef:
    def lr_at(step):
        step = step.astype(jnp.float32)
        # (step+1)/warmup so step 0 has a nonzero LR and warmup=0 disables
        warm = jnp.minimum((step + 1.0) / max(warmup, 1), 1.0)
        if schedule == "cosine":
            frac = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
            base = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        else:
            base = 1.0
        return lr * warm * base

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        state = {"m": zeros,
                 "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}
        if master_weights:
            state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return state

    def update(grads, state, params, step):
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip is not None:
            gn = _global_norm(g32)
            scale = jnp.minimum(1.0, grad_clip / (gn + 1e-9))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], g32)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], g32)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr_t = lr_at(step)

        ref = state.get("master", params)

        def leaf_update(p, m_, v_):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            pf = p.astype(jnp.float32)
            pf = pf - lr_t * (upd + weight_decay * pf)
            return pf

        new_ref = jax.tree.map(leaf_update, ref, m, v)
        new_state = {"m": m, "v": v}
        if master_weights:
            new_state["master"] = new_ref
            new_params = jax.tree.map(lambda nr, p: nr.astype(p.dtype), new_ref, params)
        else:
            new_params = jax.tree.map(lambda nr, p: nr.astype(p.dtype), new_ref, params)
        return new_params, new_state

    return OptimizerDef(init, update)


def sgd_momentum(lr: float = 0.1, momentum: float = 0.9,
                 grad_clip: Optional[float] = None) -> OptimizerDef:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip is not None:
            gn = _global_norm(g32)
            scale = jnp.minimum(1.0, grad_clip / (gn + 1e-9))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        m = jax.tree.map(lambda m_, g: momentum * m_ + g, state["m"], g32)
        new_params = jax.tree.map(
            lambda p, m_: (p.astype(jnp.float32) - lr * m_).astype(p.dtype), params, m)
        return new_params, {"m": m}

    return OptimizerDef(init, update)


def lion(lr: float = 1e-4, b1: float = 0.9, b2: float = 0.99,
         weight_decay: float = 0.1) -> OptimizerDef:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        def leaf(p, m_, g):
            upd = jnp.sign(b1 * m_ + (1 - b1) * g)
            pf = p.astype(jnp.float32)
            pf = pf - lr * (upd + weight_decay * pf)
            return pf.astype(p.dtype)

        new_params = jax.tree.map(leaf, params, state["m"], g32)
        m = jax.tree.map(lambda m_, g: b2 * m_ + (1 - b2) * g, state["m"], g32)
        return new_params, {"m": m}

    return OptimizerDef(init, update)
