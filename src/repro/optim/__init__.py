from repro.optim.optimizers import adamw, sgd_momentum, lion, OptimizerDef

__all__ = ["adamw", "sgd_momentum", "lion", "OptimizerDef"]
