"""MLI-algorithm launcher: streaming epochs with checkpoint/resume.

The streaming counterpart of ``repro.launch.train`` for the paper's
algorithms: data arrives as per-epoch minibatch windows from a
:class:`repro.data.pipeline.BatchIterator` (never fully resident), the
:class:`repro.core.runner.DistributedRunner` iterates them on the device
mesh, and a :class:`repro.core.runner.CheckpointPolicy` makes the run
survive being killed — relaunching with ``--resume`` continues from the
newest snapshot bit-for-bit.

Examples (CPU container; add XLA_FLAGS=--xla_force_host_platform_device_count=8
for a multi-device mesh):

    PYTHONPATH=src python -m repro.launch.fit --algorithm logreg \\
        --epochs 8 --rows-per-epoch 256 --features 16 --chunks-per-epoch 4 \\
        --schedule allreduce --ckpt-dir /tmp/mli-logreg --ckpt-every 2

    # kill it mid-run, then:
    PYTHONPATH=src python -m repro.launch.fit --algorithm logreg \\
        --epochs 8 --rows-per-epoch 256 --features 16 --chunks-per-epoch 4 \\
        --schedule allreduce --ckpt-dir /tmp/mli-logreg --resume

Multi-host (subprocess-simulated hosts; the same flags drive real pods):

    # BSP: one global 2x4-device mesh, gloo collectives, lock-step rounds
    PYTHONPATH=src python -m repro.launch.fit --algorithm logreg \\
        --epochs 4 --hosts 2 --devices-per-host 4

    # SSP: independent hosts exchanging weights with staleness bound 2;
    # --elastic also restarts the world (resized) if a host dies
    PYTHONPATH=src python -m repro.launch.fit --algorithm logreg \\
        --epochs 4 --hosts 3 --staleness 2 --elastic \\
        --ckpt-dir /tmp/mli-ssp --ckpt-every 1
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.core import hostmesh

# the multi-host BSP lane must join the mesh BEFORE anything touches the
# jax backend; a no-op without the REPRO_* launcher contract in place
_HOST_INFO = hostmesh.initialize_from_env()

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step
from repro.core.algorithms.kmeans import KMeans, KMeansParameters
from repro.core.algorithms.logistic_regression import (
    LogisticRegressionAlgorithm,
    LogisticRegressionParameters,
)
from repro.core.compat import make_mesh
from repro.core.optimizer import MinibatchSGD, MinibatchSGDParameters
from repro.core.runner import CheckpointPolicy
from repro.data import BatchIterator

ALGORITHMS = ("logreg", "linreg", "kmeans", "pipeline")


def make_source(algorithm: str, rows: int, features: int, seed: int):
    """Deterministic per-step window generator — a pure function of the
    step, which is what makes ``--resume`` exact."""
    if algorithm == "logreg":
        def source(step: int):
            rng = np.random.default_rng(seed * 100_003 + step)
            w = np.linspace(-1, 1, features).astype(np.float32)
            X = rng.normal(size=(rows, features)).astype(np.float32)
            y = (X @ w > 0).astype(np.float32)
            return {"data": np.concatenate([y[:, None], X], 1)}
    elif algorithm == "linreg":
        def source(step: int):
            rng = np.random.default_rng(seed * 100_003 + step)
            w = np.arange(1, features + 1, dtype=np.float32) / features
            X = rng.normal(size=(rows, features)).astype(np.float32)
            y = X @ w + 0.01 * rng.normal(size=rows).astype(np.float32)
            return {"data": np.concatenate([y[:, None], X], 1)}
    else:
        def source(step: int):
            rng = np.random.default_rng(seed * 100_003 + step)
            k = 4
            centers = np.stack([np.full(features, 2.0 * (i - (k - 1) / 2))
                                for i in range(k)]).astype(np.float32)
            idx = rng.integers(0, k, size=rows)
            X = centers[idx] + 0.3 * rng.normal(size=(rows, features))
            return {"data": X.astype(np.float32)}
    return source


def run_pipeline(args, mesh, ckpt, resume) -> None:
    """The Fig. A2 flagship scenario as ONE object: raw labeled text →
    NGrams → TfIdf → Standardizer → logistic regression, trained from
    streamed windows with the whole artifact (featurizer statistics +
    model + stream position) in every atomic checkpoint."""
    from repro.core.mltable import MLTable
    from repro.data import synth_labeled_text
    from repro.features import NGrams, Standardizer, TfIdf
    from repro.pipeline import Pipeline
    from repro.serve import ModelPredictor, PredictRequest

    rows = synth_labeled_text(n_docs=args.rows_per_epoch, seed=args.seed)
    raw = MLTable.from_rows(rows, names=["label", "text"], num_partitions=4)
    pipe = Pipeline([
        NGrams(n=1, top=args.features, column="text"),
        TfIdf(),
        Standardizer(),
        LogisticRegressionAlgorithm(
            learning_rate=args.lr, local_batch_size=args.local_batch_size,
            schedule=args.schedule),
    ], mesh=mesh, num_shards=None if mesh is not None else args.num_shards)
    fitted = pipe.fit_stream(raw, num_epochs=args.epochs,
                             chunks_per_epoch=args.chunks_per_epoch,
                             checkpoint=ckpt, resume=resume)
    table = fitted.transform(raw)
    X = jnp.asarray(table.data)
    acc = float(jnp.mean(fitted.model.predict(X[:, 1:]) == X[:, 0]))
    print(f"done: pipeline train acc {acc:.3f} "
          f"({table.num_rows} rows x {table.num_cols - 1} features)")
    served = ModelPredictor(fitted, max_batch=8)
    req = served.submit(PredictRequest(features=rows[0][1]))
    served.flush()
    print(f"served raw text -> class {float(req.result[0]):.0f} "
          f"(label {rows[0][0]:.0f})")


def _drive_hosts(args, argv) -> None:
    """Driver mode: re-exec this module once per host under the elastic
    controller.  Children carry ``REPRO_HOST_ID`` and skip this branch."""
    import tempfile

    from repro.launch.elastic import ElasticController

    child = [sys.executable, "-m", "repro.launch.fit"] + \
        list(argv if argv is not None else sys.argv[1:])
    if args.staleness is not None and not args.exchange_dir:
        exchange = (os.path.join(args.ckpt_dir, "exchange") if args.ckpt_dir
                    else tempfile.mkdtemp(prefix="mli-exchange-"))
        child += ["--exchange-dir", exchange]
    ctl = ElasticController(
        child, args.hosts, devices_per_host=args.devices_per_host,
        max_restarts=2 if args.elastic else 0,
        min_hosts=1, timeout=600.0,
        global_mesh=args.staleness is None)
    report = ctl.run()
    for gen in report.generations:
        tag = f"generation {gen.index} ({gen.num_hosts} hosts)"
        for e in sorted(gen.exits, key=lambda x: x.host_id):
            for line in e.stdout.strip().splitlines():
                print(f"[{tag} h{e.host_id}] {line}")
    if report.resized:
        print(f"elastic: {len(report.generations)} generations, restart "
              f"latency {[f'{s:.2f}s' for s in report.restart_seconds]}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--algorithm", required=True, choices=ALGORITHMS)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--rows-per-epoch", type=int, default=256)
    ap.add_argument("--features", type=int, default=16)
    ap.add_argument("--chunks-per-epoch", type=int, default=4)
    ap.add_argument("--schedule", default="allreduce",
                    choices=("allreduce", "gather_broadcast", "reduce_scatter"))
    ap.add_argument("--num-shards", type=int, default=4,
                    help="emulated partitions when only one device is visible")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=1)
    ap.add_argument("--keep", type=int, default=None,
                    help="retain only the newest N checkpoints")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the newest checkpoint in --ckpt-dir")
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--local-batch-size", type=int, default=8)
    ap.add_argument("--k", type=int, default=4, help="k-means cluster count")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hosts", type=int, default=1,
                    help="spawn N subprocess-simulated hosts (multi-host "
                         "mesh; BSP unless --staleness is given)")
    ap.add_argument("--devices-per-host", type=int, default=2)
    ap.add_argument("--staleness", type=int, default=None,
                    help="stale-synchronous lane with this bound (0 = "
                         "lock-step BSP over the exchange store)")
    ap.add_argument("--elastic", action="store_true",
                    help="restart the world (resized) when a host dies; "
                         "survivors resume from --ckpt-dir")
    ap.add_argument("--exchange-dir", default=None,
                    help="shared SSP exchange directory (defaults under "
                         "--ckpt-dir or a fresh temp dir)")
    args = ap.parse_args(argv)

    if args.hosts > 1 and "REPRO_HOST_ID" not in os.environ:
        _drive_hosts(args, argv)
        return

    devices = jax.devices()
    mesh = make_mesh((len(devices),), ("data",)) if len(devices) > 1 else None
    where = (f"{len(devices)}-device mesh" if mesh is not None
             else f"{args.num_shards} emulated partitions")
    if _HOST_INFO.multihost:
        where += (f" ({hostmesh.num_hosts()} hosts x "
                  f"{len(jax.local_devices())} local devices)")
    print(f"fit: {args.algorithm} | {where} | schedule={args.schedule} | "
          f"{args.epochs} epochs x {args.rows_per_epoch} rows x "
          f"{args.chunks_per_epoch} chunks")

    ssp = args.staleness is not None and int(os.environ.get(
        "REPRO_NUM_HOSTS", "1")) > 1
    host = int(os.environ.get("REPRO_HOST_ID", "0"))
    elastic_resume = args.elastic and os.environ.get("REPRO_RESUME") == "1"
    store = None
    ckpt_dir = args.ckpt_dir
    seed = args.seed
    if ssp:
        # SSP hosts are independent programs: each streams its own data
        # (seed offset by rank), checkpoints into its own subdirectory, and
        # exchanges through the shared ParamStore
        from repro.core.exchange import ParamStore
        from repro.testing.chaos import ChaosInjector

        if not args.exchange_dir:
            ap.error("--staleness with --hosts needs --exchange-dir "
                     "(the driver injects one automatically)")
        n = int(os.environ["REPRO_NUM_HOSTS"])
        store = ParamStore(args.exchange_dir, host, n,
                           keep=args.staleness + 2)
        if ckpt_dir:
            ckpt_dir = os.path.join(ckpt_dir, f"h{host}")
        seed = args.seed + 7919 * host

    ckpt = None
    if ckpt_dir:
        ckpt = CheckpointPolicy(ckpt_dir, every_epochs=args.ckpt_every,
                                keep=args.keep)
    resume = bool((args.resume or elastic_resume) and ckpt_dir
                  and latest_step(ckpt_dir) is not None)
    if args.resume and not resume:
        print("no checkpoint found; starting fresh")
    if resume:
        print(f"resuming from step {latest_step(ckpt_dir)} "
              f"in {ckpt_dir}")

    if args.algorithm == "pipeline":
        if ssp or _HOST_INFO.multihost:
            ap.error("--hosts supports logreg | linreg | kmeans")
        run_pipeline(args, mesh, ckpt, resume)
        return

    source = make_source(args.algorithm, args.rows_per_epoch, args.features,
                         seed)
    stream = BatchIterator(source, mesh=mesh)
    common = dict(num_epochs=args.epochs, num_shards=args.num_shards,
                  chunks_per_epoch=args.chunks_per_epoch, checkpoint=ckpt,
                  resume=resume)
    if ssp:
        injector = ChaosInjector.from_env(host_id=host, store=store)
        stream = injector.wrap_stream(stream)
        common.update(store=store, staleness=args.staleness)
        if args.algorithm == "kmeans":
            common["chunks_per_epoch"] = 1
    elif args.elastic:
        common["allow_resize"] = True
    holdout = source(10**9)["data"]  # never reached by training steps

    if args.algorithm == "logreg":
        p = LogisticRegressionParameters(
            learning_rate=args.lr, local_batch_size=args.local_batch_size,
            schedule=args.schedule)
        model = LogisticRegressionAlgorithm(p).fit_stream(stream, **common)
        X, y = jnp.asarray(holdout[:, 1:]), jnp.asarray(holdout[:, 0])
        acc = float(jnp.mean(model.predict(X) == y))
        print(f"done: holdout loss {float(model.loss(X, y)):.4f} "
              f"acc {acc:.3f}")
    elif args.algorithm == "linreg":
        def grad(vec, w):
            x = vec[1:]
            return x * (jnp.dot(x, w) - vec[0])

        p = MinibatchSGDParameters(
            w_init=jnp.zeros(args.features, jnp.float32), grad=grad,
            learning_rate=args.lr * 0.1, schedule=args.schedule)
        w = MinibatchSGD(p).apply_stream(stream, **common)
        X, y = jnp.asarray(holdout[:, 1:]), jnp.asarray(holdout[:, 0])
        mse = float(jnp.mean((X @ w - y) ** 2))
        print(f"done: holdout mse {mse:.5f}")
    else:
        p = KMeansParameters(k=args.k, seed=args.seed, schedule=args.schedule)
        model = KMeans(p).fit_stream(stream, **common)
        inertia = float(model.inertia(jnp.asarray(holdout)))
        print(f"done: holdout inertia {inertia:.2f}")
    print(f"stream position: step {stream.step}")


if __name__ == "__main__":
    main()
