"""Multi-pod dry-run: prove every (arch × input-shape × mesh) lowers,
partitions, and compiles — and extract the roofline terms from the compiled
artifact.  No real data is allocated: inputs are ShapeDtypeStructs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]

Results (memory analysis, FLOPs/bytes from cost_analysis, per-collective
bytes parsed from the post-SPMD HLO) are printed and optionally written as
JSON for EXPERIMENTS.md §Dry-run / §Roofline.
"""
# The VERY FIRST lines, before ANY other import: jax locks the device count
# on first init, and the production mesh needs 512 placeholder host devices.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import re
import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.store import atomic_write_json
from repro.configs import ARCH_IDS, SHAPES, InputShape, get_config
from repro.launch.mesh import make_production_mesh
from repro.models.config import ArchConfig
from repro.models.transformer import TransformerLM, init_model
from repro.optim.optimizers import adamw
from repro.serve.step import cache_axes, make_decode_step, make_prefill_step
from repro.sharding.rules import (DEFAULT_RULES, ShardingRules, logical_to_spec,
                                  shardings_for)
from repro.train.step import (BATCH_AXES, TrainState, init_train_state,
                              make_train_step, state_shardings)

__all__ = ["run_pair", "planned_pairs", "input_specs", "collective_bytes",
           "HW", "main"]

# TPU v5e hardware constants (roofline denominators)
HW = {
    "peak_flops": 197e12,      # bf16 FLOP/s per chip
    "hbm_bw": 819e9,           # bytes/s per chip
    "ici_bw": 50e9,            # bytes/s per link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")


def _dry_cfg(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Shape-dependent config fixups for the dry-run (position-table sizing)."""
    updates: Dict[str, Any] = {}
    if cfg.max_seq_len < shape.seq_len:
        updates["max_seq_len"] = shape.seq_len
    return dataclasses.replace(cfg, **updates) if updates else cfg


# --------------------------------------------------------------------------- #
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# --------------------------------------------------------------------------- #
def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model-input stand-ins for one (arch, shape) pair."""
    B, S = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if cfg.vision_tokens:
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.d_model), act)
        if cfg.encoder_layers:
            specs["encoder_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), act)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.vision_tokens:
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.d_model), act)
        if cfg.encoder_layers:
            specs["encoder_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), act)
        return specs
    # decode: ONE new token against a seq_len-deep cache
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def _abstract(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def _batch_shardings(specs: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh,
                     rules: ShardingRules) -> Dict[str, NamedSharding]:
    return {
        k: NamedSharding(mesh, logical_to_spec(
            BATCH_AXES.get(k, ("batch",) + (None,) * (len(v.shape) - 1)),
            tuple(v.shape), mesh, rules))
        for k, v in specs.items()
    }


# --------------------------------------------------------------------------- #
# lower+compile per shape kind
# --------------------------------------------------------------------------- #
def abstract_train_state(cfg: ArchConfig, optimizer=None):
    """(abstract TrainState, logical axes) with no array allocation.  The
    axes tree is static metadata, captured via a side channel so eval_shape
    only sees array outputs."""
    optimizer = optimizer or adamw()
    box: Dict[str, Any] = {}

    def build(key):
        state, axes = init_train_state(key, cfg, optimizer)
        box["axes"] = axes
        return state

    state_s = jax.eval_shape(build, jax.random.PRNGKey(0))
    return state_s, box["axes"]


def abstract_params(cfg: ArchConfig):
    """(abstract params, logical axes) with no array allocation."""
    box: Dict[str, Any] = {}

    def build(key):
        params, axes = init_model(key, cfg)
        box["axes"] = axes
        return params

    params_s = jax.eval_shape(build, jax.random.PRNGKey(0))
    return params_s, box["axes"]


def _lower_train(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                 rules: ShardingRules):
    optimizer = adamw()
    state_s, axes = abstract_train_state(cfg, optimizer)
    state_sh = state_shardings(state_s, axes, mesh, rules)
    batch = input_specs(cfg, shape)
    batch_sh = _batch_shardings(batch, mesh, rules)
    step = make_train_step(cfg, optimizer, mesh=mesh, rules=rules)
    jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None))
    return jitted.lower(state_s, batch)


def _serve_state(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                 rules: ShardingRules):
    """Abstract params + cache and their shardings for serving."""
    params_s, axes = abstract_params(cfg)
    p_sh = shardings_for(axes, params_s, mesh, rules)
    model = TransformerLM(cfg)
    cache_s = _abstract(partial(model.init_cache, shape.global_batch,
                                shape.seq_len))
    c_axes = cache_axes(cfg)
    c_sh = shardings_for(c_axes, cache_s, mesh, rules)
    return params_s, p_sh, cache_s, c_sh


def _lower_prefill(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                   rules: ShardingRules):
    params_s, p_sh, cache_s, c_sh = _serve_state(cfg, shape, mesh, rules)
    specs = input_specs(cfg, shape)
    in_sh = _batch_shardings(specs, mesh, rules)
    prefill = make_prefill_step(cfg)

    def step(params, cache, batch):
        return prefill(params, batch["tokens"], cache,
                       vision_embeds=batch.get("vision_embeds"),
                       encoder_frames=batch.get("encoder_frames"))

    jitted = jax.jit(step, in_shardings=(p_sh, c_sh, in_sh),
                     out_shardings=(None, c_sh))
    return jitted.lower(params_s, cache_s, specs)


def _lower_decode(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                  rules: ShardingRules):
    params_s, p_sh, cache_s, c_sh = _serve_state(cfg, shape, mesh, rules)
    specs = input_specs(cfg, shape)
    tok_sh = NamedSharding(mesh, logical_to_spec(
        ("batch", None), tuple(specs["token"].shape), mesh, rules))
    decode = make_decode_step(cfg)
    jitted = jax.jit(decode,
                     in_shardings=(p_sh, tok_sh, NamedSharding(mesh, P()), c_sh),
                     out_shardings=(None, c_sh))
    return jitted.lower(params_s, specs["token"], specs["pos"], cache_s)


# --------------------------------------------------------------------------- #
# artifact analysis
# --------------------------------------------------------------------------- #
def collective_bytes(hlo: str) -> Dict[str, Any]:
    """Sum result-shape bytes of every collective op in the post-SPMD HLO
    (shapes are per-device after partitioning)."""
    per_op: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        for coll in _COLLECTIVES:
            # match the op use (" all-reduce(") not names like %all-reduce.5
            if f" {coll}(" in stripped or f" {coll}-start(" in stripped:
                lhs = stripped.split("=", 1)[1]
                lhs = lhs.split(coll, 1)[0]
                nbytes = 0
                for dt, dims in _SHAPE_RE.findall(lhs):
                    if dt not in _DTYPE_BYTES:
                        continue
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    nbytes += n * _DTYPE_BYTES[dt]
                per_op[coll] += nbytes
                counts[coll] += 1
                break
    total = sum(per_op.values())
    return {"total_bytes": total, "bytes_by_op": per_op, "count_by_op": counts}


def model_flops_estimate(cfg: ArchConfig, shape: InputShape) -> float:
    """MODEL_FLOPS = 6·N_active·D (training) or 2·N_active·D (decode/prefill
    forward-only), N_active = params touched per token."""
    d, L = cfg.d_model, cfg.num_layers
    hd = cfg.resolved_head_dim if cfg.num_heads else 0
    n_per_layer = 0.0
    att_layers = sum(1 for k in cfg.pattern if "attn" in k.value) * cfg.num_periods
    rec_layers = sum(1 for k in cfg.pattern if k.value == "rglru") * cfg.num_periods
    ssd_layers = sum(1 for k in cfg.pattern if k.value == "ssd") * cfg.num_periods
    n = 0.0
    if att_layers:
        n += att_layers * (d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd
                           + cfg.num_heads * hd * d)
        mlp_per = 3 * d * cfg.d_ff if cfg.mlp_kind == "swiglu" else 2 * d * cfg.d_ff
        if cfg.num_experts:
            active = cfg.top_k + (1 if cfg.shared_expert else 0)
            n += att_layers * active * mlp_per
        else:
            n += att_layers * mlp_per
    if rec_layers:
        r = cfg.rnn_width or d
        n += rec_layers * (3 * d * r + 2 * r * r + r * d + 3 * d * cfg.d_ff)
    if ssd_layers:
        di = cfg.d_inner_ssd
        n += ssd_layers * (2 * d * di + 2 * d * cfg.ssm_state
                           + d * cfg.ssd_heads + di * d)
    n += cfg.vocab_size * d  # logits matmul (embeddings tied)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def analyze(lowered, compiled) -> Dict[str, Any]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # some backends return [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    mem: Dict[str, Any] = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = int(v)
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)
    coll = collective_bytes(compiled.as_text())
    return {"flops_per_device": flops, "bytes_per_device": bytes_acc,
            "memory": mem, "collectives": coll}


# --------------------------------------------------------------------------- #
# the pair matrix
# --------------------------------------------------------------------------- #
def planned_pairs() -> Tuple[Tuple[str, str], ...]:
    """All (arch, shape) baseline pairs.  long_500k only for sub-quadratic
    archs (skips recorded in DESIGN.md)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if sname == "long_500k" and not cfg.sub_quadratic:
                continue
            if sname == "long_500k" and cfg.encoder_layers > 0:
                continue  # whisper: bounded-source enc-dec
            out.append((arch, sname))
    return tuple(out)


def _lower_kind(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                rules: ShardingRules):
    if shape.kind == "train":
        return _lower_train(cfg, shape, mesh, rules)
    if shape.kind == "prefill":
        return _lower_prefill(cfg, shape, mesh, rules)
    return _lower_decode(cfg, shape, mesh, rules)


def _probe_cfg(cfg: ArchConfig, periods: int) -> ArchConfig:
    """Shallow UNROLLED variant for cost extrapolation: XLA's cost_analysis
    counts a lax.scan body once regardless of trip count, so the scanned
    full-depth program under-reports.  Two unrolled probes (1 and 2 periods)
    give base + per-period body costs; total = base + P·body.  The encoder
    (whisper) stays full-depth in both probes, landing in `base` exactly
    once.  Archs with long periods (recurrentgemma 19, gemma3 13) probe
    with a reduced same-mix ``probe_pattern`` and a fractional period scale.
    Residual known under-count: the q-chunk scan inside one attention
    layer (documented in EXPERIMENTS.md)."""
    pattern = cfg.probe_pattern or cfg.pattern
    return dataclasses.replace(cfg, pattern=pattern, probe_pattern=None,
                               num_layers=periods * len(pattern),
                               unroll_periods=True)


def _extrapolated_costs(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                        rules: ShardingRules) -> Dict[str, float]:
    probes = []
    for k in (1, 2):
        lowered = _lower_kind(_probe_cfg(cfg, k), shape, mesh, rules)
        probes.append(analyze(lowered, lowered.compile()))
    # effective periods: layers of the full model per probe-pattern length
    P = cfg.num_layers / len(cfg.probe_pattern or cfg.pattern)

    def extrap(c1: float, c2: float) -> float:
        body = max(c2 - c1, 0.0)
        return c1 + (P - 1) * body

    return {
        "flops_per_device": extrap(probes[0]["flops_per_device"],
                                   probes[1]["flops_per_device"]),
        "bytes_per_device": extrap(probes[0]["bytes_per_device"],
                                   probes[1]["bytes_per_device"]),
        "collective_bytes": extrap(probes[0]["collectives"]["total_bytes"],
                                   probes[1]["collectives"]["total_bytes"]),
    }


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             rules: ShardingRules = DEFAULT_RULES,
             mesh: Optional[Mesh] = None,
             cfg_override: Optional[ArchConfig] = None) -> Dict[str, Any]:
    cfg = _dry_cfg(cfg_override or get_config(arch), SHAPES[shape_name])
    shape = SHAPES[shape_name]
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    lowered = _lower_kind(cfg, shape, mesh, rules)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    res = analyze(lowered, compiled)
    res["raw_scan_counted"] = {
        "flops_per_device": res["flops_per_device"],
        "bytes_per_device": res["bytes_per_device"],
        "collective_bytes": res["collectives"]["total_bytes"],
    }
    # repair the scan-counted-once under-count via two unrolled probes
    corrected = _extrapolated_costs(cfg, shape, mesh, rules)
    res["flops_per_device"] = corrected["flops_per_device"]
    res["bytes_per_device"] = corrected["bytes_per_device"]
    res["collective_bytes"] = corrected["collective_bytes"]

    res.update({
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    })
    # roofline terms (seconds); flops/bytes are per-device post-SPMD
    res["compute_s"] = res["flops_per_device"] / HW["peak_flops"]
    res["memory_s"] = res["bytes_per_device"] / HW["hbm_bw"]
    res["collective_s"] = res["collective_bytes"] / HW["ici_bw"]
    terms = {k: res[k] for k in ("compute_s", "memory_s", "collective_s")}
    res["bottleneck"] = max(terms, key=terms.get)
    mf = model_flops_estimate(cfg, shape)
    res["model_flops"] = mf
    total_hlo = res["flops_per_device"] * n_chips
    res["model_flops_ratio"] = mf / total_hlo if total_hlo else 0.0
    return res


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--decode-only", action="store_true",
                    help="restrict --all to decode shapes")
    ap.add_argument("--mesh-shape", default=None,
                    help="override mesh, e.g. 32x8 (same 256 chips refactored)")
    ap.add_argument("--serve-rules", action="store_true",
                    help="use SERVE_RULES instead of the training rules")
    ap.add_argument("--out", default=None, help="directory for JSON results")
    args = ap.parse_args()

    pairs = planned_pairs() if args.all else [(args.arch, args.shape)]
    if args.decode_only:
        pairs = [(a, s) for a, s in pairs if SHAPES[s].kind == "decode"]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    override_mesh = None
    if args.mesh_shape:
        shp = tuple(int(x) for x in args.mesh_shape.split("x"))
        names = ("pod", "data", "model")[-len(shp):]
        from repro.core.compat import make_mesh
        override_mesh = make_mesh(shp, names)
    from repro.sharding.rules import SERVE_RULES
    rules = SERVE_RULES if args.serve_rules else DEFAULT_RULES
    for arch, shape in pairs:
        for mp in meshes:
            mesh_tag = args.mesh_shape or ('2x16x16' if mp else '16x16')
            tag = f"{arch}__{shape}__{mesh_tag}"
            try:
                res = run_pair(arch, shape, multi_pod=mp, mesh=override_mesh,
                               rules=rules)
            except Exception as e:  # noqa: BLE001 — report, keep sweeping
                print(f"FAIL {tag}: {type(e).__name__}: {e}")
                if not args.all:
                    raise
                continue
            print(f"OK   {tag}: flops/dev={res['flops_per_device']:.3e} "
                  f"bytes/dev={res['bytes_per_device']:.3e} "
                  f"coll={res['collectives']['total_bytes']:.3e}B "
                  f"bottleneck={res['bottleneck']} "
                  f"(lower {res['lower_s']}s compile {res['compile_s']}s)")
            if args.out:
                # atomic publish: a sweep killed mid-write must not leave a
                # torn result file for the comparison tooling to parse
                atomic_write_json(os.path.join(args.out, tag + ".json"),
                                  res, indent=1)


if __name__ == "__main__":
    main()
