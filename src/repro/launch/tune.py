"""Model-search launcher: grid/random search with CV and checkpoint/resume.

The search counterpart of ``repro.launch.fit``: enumerate candidate
configurations of an MLI algorithm (``--grid`` or ``--samples`` over
``--space``), train them as device-stacked trials through
:class:`repro.tune.ModelSearch` (streaming epochs, k-fold or holdout
validation, optional median early stopping), and report every trial plus
the winner.  With ``--ckpt-dir`` the search snapshots after every
completed unit; ``--resume`` continues a killed search trial-for-trial.

Examples (CPU container; add
XLA_FLAGS=--xla_force_host_platform_device_count=8 for a multi-device
mesh):

    PYTHONPATH=src python -m repro.launch.tune --algorithm logreg \\
        --grid "learning_rate=0.05,0.1,0.3;l2=0.0,0.01" \\
        --rows 128 --features 8 --epochs 4 --chunks-per-epoch 2 \\
        --folds 3 --schedule allreduce --exec stacked

    PYTHONPATH=src python -m repro.launch.tune --algorithm logreg \\
        --samples 6 --space "learning_rate=loguniform:0.01:1.0;l2=0.0,0.01" \\
        --ckpt-dir /tmp/mli-search
    # kill it mid-search, then add --resume to the same command line

    PYTHONPATH=src python -m repro.launch.tune --algorithm logreg \\
        --samples 32 --space "learning_rate=loguniform:0.01:1.0;l2=0.0,0.01" \\
        --epochs 9 --asha --reduction-factor 3 --min-rounds 1 --slots 4 \\
        --record-eval
    # ASHA: slot-table execution, per-report promotion, per-rung history
"""
from __future__ import annotations

import argparse
import ast
import json
import os
import signal
import sys
from typing import Any, Dict, List

import jax
import numpy as np

from repro.core.collectives import CollectiveSchedule
from repro.core.compat import make_mesh
from repro.core.numeric_table import MLNumericTable
from repro.tune import (AsyncSuccessiveHalving, MedianStoppingRule,
                        ModelSearch, grid, record_evaluation, sample)

ALGORITHMS = ("logreg", "kmeans", "pipeline")


def _literal(text: str) -> Any:
    """Parse one grid/space value: python literal when it is one, else the
    raw string (schedule names etc.)."""
    try:
        return ast.literal_eval(text)
    except (ValueError, SyntaxError):
        return text


def parse_space(spec: str) -> Dict[str, Any]:
    """Parse ``"lr=0.1,0.3;l2=0.0,0.01"`` into a search space dict.

    Each ``;``-separated entry is ``name=v1,v2,…`` (a value list) or
    ``name=uniform:lo:hi`` / ``name=loguniform:lo:hi`` (a continuous
    range for ``--samples``).
    """
    space: Dict[str, Any] = {}
    for entry in filter(None, (e.strip() for e in spec.split(";"))):
        name, _, values = entry.partition("=")
        if not _ or not values:
            raise ValueError(f"malformed space entry {entry!r} (want name=…)")
        if values.startswith(("uniform:", "loguniform:")):
            kind, lo, hi = values.split(":")
            space[name.strip()] = (kind, float(lo), float(hi))
        else:
            space[name.strip()] = [_literal(v) for v in values.split(",")]
    return space


def make_pipeline(features: int, mesh):
    """The Fig. A2 text pipeline with nested-stage search keys
    (``ngrams.top``, ``tfidf.*``, ``logreg.*``)."""
    from repro.core.algorithms.logistic_regression import \
        LogisticRegressionAlgorithm
    from repro.features import NGrams, Standardizer, TfIdf
    from repro.pipeline import Pipeline

    return Pipeline([
        NGrams(n=1, top=features, column="text"),
        TfIdf(),
        Standardizer(),
        LogisticRegressionAlgorithm(),
    ], mesh=mesh, num_shards=None if mesh is not None else 4)


def make_table(algorithm: str, rows: int, features: int, seed: int):
    """Deterministic synthetic dataset (pure function of the arguments, so
    a --resume relaunch sees the identical table).  The ``pipeline``
    algorithm gets a *raw* labeled-text MLTable — featurization happens
    inside the search, fit on each train fold only."""
    rng = np.random.default_rng(seed * 100_003 + 17)
    mesh = (make_mesh((len(jax.devices()),), ("data",))
            if len(jax.devices()) > 1 else None)
    if algorithm == "pipeline":
        from repro.core.mltable import MLTable
        from repro.data import synth_labeled_text

        return MLTable.from_rows(synth_labeled_text(n_docs=rows, seed=seed),
                                 names=["label", "text"], num_partitions=4)
    if algorithm == "logreg":
        w = np.linspace(-1, 1, features).astype(np.float32)
        X = rng.normal(size=(rows, features)).astype(np.float32)
        y = (X @ w > 0).astype(np.float32)
        data = np.concatenate([y[:, None], X], 1)
    else:
        k = 4
        centers = np.stack([np.full(features, 2.5 * (i - (k - 1) / 2))
                            for i in range(k)]).astype(np.float32)
        idx = rng.integers(0, k, size=rows)
        data = (centers[idx]
                + 0.3 * rng.normal(size=(rows, features))).astype(np.float32)
    num_shards = None if mesh is not None else 4
    return MLNumericTable.from_numpy(data, num_shards=num_shards, mesh=mesh)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--algorithm", required=True, choices=ALGORITHMS)
    ap.add_argument("--grid", default=None,
                    help="grid space, e.g. 'learning_rate=0.1,0.3;l2=0,0.01'")
    ap.add_argument("--samples", type=int, default=None,
                    help="random-search draw count (over --space)")
    ap.add_argument("--space", default=None,
                    help="random-search space; supports uniform:lo:hi and "
                         "loguniform:lo:hi ranges")
    ap.add_argument("--rows", type=int, default=128)
    ap.add_argument("--features", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--chunks-per-epoch", type=int, default=2)
    ap.add_argument("--folds", type=int, default=None,
                    help="k-fold CV; omit for a single holdout split")
    ap.add_argument("--holdout", type=float, default=0.25,
                    help="holdout validation fraction (when --folds is unset)")
    ap.add_argument("--metric", default=None,
                    help="accuracy | log_loss (logreg), silhouette (kmeans)")
    ap.add_argument("--schedule", default="allreduce",
                    choices=[s.value for s in CollectiveSchedule])
    ap.add_argument("--exec", dest="execution", default="auto",
                    choices=("auto", "stacked", "sequential"))
    ap.add_argument("--early-stop", action="store_true",
                    help="median-rule early stopping, one rung per "
                         "--rung-epochs")
    ap.add_argument("--rung-epochs", type=int, default=None)
    ap.add_argument("--asha", action="store_true",
                    help="asynchronous successive halving: slot-table "
                         "execution with per-report promotion (overrides "
                         "--early-stop)")
    ap.add_argument("--reduction-factor", type=int, default=3,
                    help="ASHA: promote the top 1/rf of each rung")
    ap.add_argument("--min-rounds", type=int, default=1,
                    help="ASHA: trial-local epochs before the first rung")
    ap.add_argument("--slots", type=int, default=None,
                    help="ASHA: concurrent trial slots (default min(8, "
                         "trials))")
    ap.add_argument("--epoch-budget", type=int, default=None,
                    help="ASHA: total slot-epochs; admission stops once "
                         "spent")
    ap.add_argument("--record-eval", action="store_true",
                    help="record per-rung metric snapshots (printed, and "
                         "in the --json payload as 'history')")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="continue from the newest snapshot in --ckpt-dir")
    ap.add_argument("--json", action="store_true",
                    help="print a RESULT::{json} line with every trial")
    ap.add_argument("--kill-after-trial", type=int, default=None,
                    help="fault injection (tests): SIGKILL this process "
                         "after N trials are completed and checkpointed")
    args = ap.parse_args(argv)

    if args.grid:
        configs = grid(parse_space(args.grid))
    elif args.samples:
        if not args.space:
            ap.error("--samples requires --space")
        configs = sample(parse_space(args.space), args.samples, args.seed)
    else:
        ap.error("pass --grid or --samples/--space")

    table = make_table(args.algorithm, args.rows, args.features, args.seed)
    algorithm = args.algorithm
    if algorithm == "pipeline":
        mesh = (make_mesh((len(jax.devices()),), ("data",))
                if len(jax.devices()) > 1 else None)
        algorithm = make_pipeline(args.features, mesh)
    where = (f"{len(jax.devices())}-device mesh"
             if getattr(table, "mesh", None) is not None
             else "host table (featurized per fold)"
             if args.algorithm == "pipeline"
             else f"{table.num_shards} emulated partitions")
    print(f"tune: {args.algorithm} | {len(configs)} trials | "
          f"{'%d-fold CV' % args.folds if args.folds else 'holdout'} | "
          f"exec={args.execution} | schedule={args.schedule} | {where}")

    killer = None
    if args.kill_after_trial is not None:
        completed = {"trials": 0}

        def killer(units_done: int, trial_indices: List[int]) -> None:
            completed["trials"] += len(trial_indices)
            if completed["trials"] >= args.kill_after_trial:
                os.kill(os.getpid(), signal.SIGKILL)

    if args.asha:
        early = AsyncSuccessiveHalving(
            reduction_factor=args.reduction_factor,
            min_rounds=args.min_rounds, slots=args.slots,
            epoch_budget=args.epoch_budget)
    else:
        early = MedianStoppingRule() if args.early_stop else None

    history = None
    callbacks = ()
    if args.record_eval:
        from repro.eval.metrics import MetricHistory

        history = MetricHistory()
        callbacks = (record_evaluation(history),)

    search = ModelSearch(
        algorithm=algorithm, configs=configs, num_epochs=args.epochs,
        chunks_per_epoch=args.chunks_per_epoch, folds=args.folds,
        val_fraction=args.holdout, metric=args.metric,
        schedule=args.schedule, execution=args.execution, seed=args.seed,
        early_stop=early, rung_epochs=args.rung_epochs,
        callbacks=callbacks, ckpt_dir=args.ckpt_dir,
        unit_callback=killer)

    resume = bool(args.resume and args.ckpt_dir)
    if resume:
        from repro.checkpoint import latest_step
        step = latest_step(args.ckpt_dir)
        if step is None:
            print("no checkpoint found; starting fresh")
            resume = False
        else:
            print(f"resuming from unit {step} in {args.ckpt_dir}")

    result = search.run(table, resume=resume)

    for t in result.trials:
        flag = " (stopped early)" if t.stopped else ""
        print(f"TRIAL {t.index} score={t.score:.6f} "
              f"config={json.dumps(t.config, sort_keys=True)}{flag}")
    best = result.best
    print(f"BEST trial={best.index} score={best.score:.6f} "
          f"config={json.dumps(best.config, sort_keys=True)}")
    if history is not None:
        for t in history.trials():
            for m in history.metrics(t):
                points = " ".join(f"{e}:{v:.4f}"
                                  for e, v in history.series(t, m))
                print(f"EVAL trial={t} metric={m} {points}")

    if args.json:
        payload = {
            "trials": [
                {"index": t.index, "config": t.config,
                 "score": t.score, "rung_scores": t.rung_scores,
                 "stopped": t.stopped,
                 "state": np.asarray(t.state).tolist()}
                for t in result.trials
            ],
            "best": {"index": best.index, "config": best.config,
                     "score": best.score},
        }
        if history is not None:
            payload["history"] = history.to_dict()
        print("RESULT::" + json.dumps(payload))


if __name__ == "__main__":
    main()
