"""Elastic multi-host controller: spawn, watch, resize, resume.

The restart-the-world elasticity model (the one torchelastic made
standard): host processes of one *generation* run as a gang; when a
member dies, the controller SIGKILLs the survivors, shrinks the world,
and spawns the next generation with a fresh coordinator — every survivor
resumes from the newest atomic checkpoint with
``DistributedRunner.resume(..., allow_resize=True)``, which revalidates
the row partitioning on the new world size through
:func:`repro.core.partition.plan_resize`.  Checkpoint + deterministic
seekable streams make this "live migration as checkpoint-and-restart":
the resumed run is bit-identical to a run that had started on the small
mesh from that same snapshot (proven in ``tests/chaos/``).

Host programs are ordinary argv commands following the ``REPRO_*``
environment contract of :mod:`repro.core.hostmesh` plus::

    REPRO_GENERATION    generation index (0 = first launch)
    REPRO_RESUME        "1" when a checkpoint should be picked up

Chaos fault specs (:mod:`repro.testing.chaos`) are forwarded to
generation 0 only — a kill fault is keyed to a deterministic stream step,
and the resumed generation replays through that step, so re-arming it
would kill the run forever.

Exit-code protocol: ``0`` success, :data:`repro.testing.chaos.
DROP_EXIT_CODE` graceful departure (the remaining gang keeps running —
the SSP lane absorbs it in place, no restart), anything else a death that
triggers a generation restart.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import subprocess
import sys
import tempfile
import time
from typing import Dict, List, Optional, Sequence

from repro.core.hostmesh import free_port
from repro.testing.chaos import DROP_EXIT_CODE, ENV_VAR as CHAOS_ENV

__all__ = ["HostExit", "Generation", "ElasticReport", "ElasticController"]


@dataclasses.dataclass
class HostExit:
    """One host process's final word."""

    host_id: int
    returncode: int
    stdout: str
    stderr: str
    #: True when the controller itself SIGKILLed this (healthy) host to
    #: break up a generation after a peer's death — not an organic death,
    #: so it must not shrink the world a second time
    evicted: bool = False

    @property
    def died(self) -> bool:
        return not self.evicted and self.returncode not in (0, DROP_EXIT_CODE)

    @property
    def dropped(self) -> bool:
        return self.returncode == DROP_EXIT_CODE


@dataclasses.dataclass
class Generation:
    """One gang launch: its world size, coordinator, and every exit."""

    index: int
    num_hosts: int
    coordinator: str
    exits: List[HostExit] = dataclasses.field(default_factory=list)
    started: float = 0.0
    ended: float = 0.0

    @property
    def deaths(self) -> List[HostExit]:
        return [e for e in self.exits if e.died]

    @property
    def duration(self) -> float:
        return self.ended - self.started


@dataclasses.dataclass
class ElasticReport:
    """What an elastic run did: every generation plus recovery timing."""

    generations: List[Generation]
    #: seconds from each death detection to the next generation's spawn
    restart_seconds: List[float] = dataclasses.field(default_factory=list)

    @property
    def final(self) -> Generation:
        return self.generations[-1]

    @property
    def resized(self) -> bool:
        return len(self.generations) > 1

    def host_output(self, host_id: int, generation: int = -1) -> str:
        gen = self.generations[generation]
        for e in gen.exits:
            if e.host_id == host_id:
                return e.stdout
        raise KeyError(f"no host {host_id} in generation {gen.index}")


class ElasticController:
    """Gang-spawns host subprocesses and restarts the world on a death.

    Parameters
    ----------
    argv:
        Host program command line (every host runs the same SPMD program;
        rank arrives via ``REPRO_HOST_ID``).
    num_hosts:
        Generation-0 world size.
    devices_per_host:
        Forced CPU device count per host (appended to ``XLA_FLAGS``).
    env:
        Extra environment for every host of every generation.
    faults:
        :class:`repro.testing.chaos.Fault` list — forwarded to
        generation 0 only (see module docstring).
    max_restarts:
        Generation restarts allowed before giving up.
    min_hosts:
        Smallest world size worth restarting with; below it the
        controller raises instead of respawning.
    timeout:
        Per-generation wall-clock limit (seconds).
    poll:
        Seconds between liveness scans.
    global_mesh:
        ``True`` (BSP): hand every host a shared coordinator so they join
        one ``jax.distributed`` mesh.  ``False`` (SSP exchange lane): no
        coordinator — hosts stay independent single-process programs.
    """

    def __init__(self, argv: Sequence[str], num_hosts: int, *,
                 devices_per_host: int = 1,
                 env: Optional[Dict[str, str]] = None,
                 faults: Sequence = (),
                 max_restarts: int = 2, min_hosts: int = 1,
                 timeout: float = 300.0, poll: float = 0.05,
                 global_mesh: bool = True):
        if num_hosts < 1:
            raise ValueError(f"num_hosts must be >= 1, got {num_hosts}")
        self.argv = list(argv)
        self.num_hosts = int(num_hosts)
        self.devices_per_host = int(devices_per_host)
        self.env = dict(env or {})
        self.faults = list(faults)
        self.max_restarts = int(max_restarts)
        self.min_hosts = int(min_hosts)
        self.timeout = float(timeout)
        self.poll = float(poll)
        self.global_mesh = bool(global_mesh)

    # ------------------------------------------------------------------ #
    def _host_env(self, generation: int, num_hosts: int, host_id: int,
                  coordinator: str) -> Dict[str, str]:
        env = dict(os.environ)
        env.update(self.env)
        base_flags = env.get("XLA_FLAGS", "")
        env["XLA_FLAGS"] = (
            f"{base_flags} --xla_force_host_platform_device_count="
            f"{self.devices_per_host}").strip()
        env.update({
            "REPRO_NUM_HOSTS": str(num_hosts),
            "REPRO_HOST_ID": str(host_id),
            "REPRO_GENERATION": str(generation),
            "REPRO_RESUME": "1" if generation > 0 else "0",
        })
        if self.global_mesh:
            env["REPRO_COORDINATOR"] = coordinator
        else:
            env.pop("REPRO_COORDINATOR", None)
        if generation == 0 and self.faults:
            from repro.testing.chaos import faults_to_env

            env.update(faults_to_env(self.faults))
        else:
            env.pop(CHAOS_ENV, None)
        return env

    def _spawn(self, generation: int, num_hosts: int) -> tuple:
        port = free_port()
        coordinator = f"127.0.0.1:{port}"
        procs = []
        for h in range(num_hosts):
            out = tempfile.TemporaryFile(mode="w+")
            err = tempfile.TemporaryFile(mode="w+")
            p = subprocess.Popen(
                self.argv, env=self._host_env(generation, num_hosts, h,
                                              coordinator),
                stdout=out, stderr=err, text=True)
            procs.append((h, p, out, err))
        return coordinator, procs

    @staticmethod
    def _collect(h: int, p: subprocess.Popen, out, err,
                 evicted: bool = False) -> HostExit:
        out.seek(0)
        err.seek(0)
        exit_ = HostExit(host_id=h, returncode=p.returncode,
                         stdout=out.read(), stderr=err.read(),
                         evicted=evicted)
        out.close()
        err.close()
        return exit_

    @staticmethod
    def _kill_survivors(procs) -> None:
        for _, p, _, _ in procs:
            if p.poll() is None:
                try:
                    p.send_signal(signal.SIGKILL)
                except ProcessLookupError:  # pragma: no cover - exit race
                    pass
        for _, p, _, _ in procs:
            try:
                p.wait(timeout=30)
            except subprocess.TimeoutExpired:  # pragma: no cover
                pass

    # ------------------------------------------------------------------ #
    def run(self) -> ElasticReport:
        """Run generations until one finishes cleanly (or restarts are
        exhausted / the world shrinks below ``min_hosts``)."""
        report = ElasticReport(generations=[])
        world = self.num_hosts
        for generation in range(self.max_restarts + 1):
            coordinator, procs = self._spawn(generation, world)
            gen = Generation(index=generation, num_hosts=world,
                             coordinator=coordinator)
            gen.started = time.monotonic()
            report.generations.append(gen)
            deadline = gen.started + self.timeout

            death_at = None
            pending = list(procs)
            while pending and death_at is None:
                still = []
                for h, p, out, err in pending:
                    rc = p.poll()
                    if rc is None:
                        still.append((h, p, out, err))
                        continue
                    exit_ = self._collect(h, p, out, err)
                    gen.exits.append(exit_)
                    if exit_.died:
                        death_at = time.monotonic()
                pending = still
                if death_at is None and pending:
                    if time.monotonic() > deadline:
                        self._kill_survivors(pending)
                        for h, p, out, err in pending:
                            gen.exits.append(self._collect(h, p, out, err,
                                                           evicted=True))
                        gen.ended = time.monotonic()
                        raise TimeoutError(
                            f"generation {generation} exceeded "
                            f"{self.timeout:.0f}s; killed "
                            f"{len(pending)} hosts")
                    time.sleep(self.poll)

            if death_at is not None:
                # a member died: the gang is broken (a BSP collective would
                # hang on it forever) — kill survivors, shrink, respawn.
                # The survivors' SIGKILLs are evictions, not deaths: only
                # the organic deaths shrink the world.
                self._kill_survivors(pending)
                for h, p, out, err in pending:
                    gen.exits.append(self._collect(h, p, out, err,
                                                   evicted=True))
                gen.ended = time.monotonic()
                world = world - len(gen.deaths)
                if generation == self.max_restarts:
                    raise RuntimeError(
                        f"host(s) {[e.host_id for e in gen.deaths]} died in "
                        f"generation {generation} and no restarts remain; "
                        f"stderr of first death:\n"
                        f"{gen.deaths[0].stderr[-2000:]}")
                if world < self.min_hosts:
                    raise RuntimeError(
                        f"world shrank to {world} host(s), below "
                        f"min_hosts={self.min_hosts}")
                report.restart_seconds.append(time.monotonic() - death_at)
                continue

            gen.ended = time.monotonic()
            bad = [e for e in gen.exits if e.died]
            assert not bad  # deaths are handled above
            return report
        raise AssertionError("unreachable")  # pragma: no cover
