"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Initializes a model (smoke-sized on CPU), then serves a batch of synthetic
requests through the ServeEngine: per-request prefill + shared decode loop.

Example (CPU):
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
        --requests 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models.transformer import init_model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_layers or cfg.vision_tokens:
        print(f"note: {cfg.name} frontend is stubbed; serving text-only path")
    params, _ = init_model(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(cfg, params, batch_size=args.requests,
                         max_seq=args.max_seq)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total_new} new tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s)")
    for i, r in enumerate(done[:4]):
        print(f"  req{i}: {r.out_tokens[:8]}...")
    assert all(r.done for r in done)


if __name__ == "__main__":
    main()
