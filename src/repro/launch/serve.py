"""Serving launcher: ``python -m repro.launch.serve --engine lm|model [...]``.

One CLI fronts the whole serving stack:

  * ``--engine lm`` — the continuous-batching LM engine
    (``serve/engine.py`` + ``serve/scheduler.py``): synthetic mixed-length
    prompts arrive on a Poisson/uniform/burst trace, are queued, admitted
    into decode slots (backfilled mid-decode), and greedy-decoded through
    one fused per-slot-position step.  Runs under the serving mesh/rules
    selection (``launch/mesh.host_serving_setup`` — slot sharding over the
    host devices; the production factorization is ``serving_setup``).
  * ``--engine model`` — the classic-ML prediction service
    (``serve/predictor.py``): train a small logreg/k-means on synthetic
    data via the paper's ``Algorithm.train``, then serve feature-block
    requests through the shard-aware microbatcher.

The jitted prefill/decode (or the compiled predict) is **warmed up before
the timed run**, so the perf report measures serving, not compilation.
Both engines end with a queue-depth/latency report; ``--json`` emits it as
a ``RESULT::{json}`` line like the other launchers.

Examples (CPU):
    PYTHONPATH=src python -m repro.launch.serve --engine lm --arch qwen2-1.5b \
        --smoke --requests 8 --slots 4 --prompt-lens 8,12,16,20 --max-new 8
    PYTHONPATH=src python -m repro.launch.serve --engine lm --arch qwen2-1.5b \
        --smoke --requests 8 --arrival poisson --rate 4 --json
    PYTHONPATH=src python -m repro.launch.serve --engine lm --arch qwen2-1.5b \
        --smoke --requests 16 --prefix-cache on --prefix-share 0.8 \
        --prefix-len 32 --prefix-block 16 --json
    PYTHONPATH=src python -m repro.launch.serve --engine model \
        --algorithm kmeans --rows 512 --features 16 --batch 64 --json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke


# --------------------------------------------------------------------------- #
# arrival traces
# --------------------------------------------------------------------------- #
def arrival_trace(kind: str, n: int, rate: float, seed: int) -> np.ndarray:
    """Request release times (seconds from serve start).

    ``all-at-once`` (rate<=0 or kind 'none') releases everything at t=0;
    ``poisson`` draws exponential inter-arrivals at ``rate`` req/s;
    ``uniform`` spaces arrivals evenly at the same mean rate; ``burst``
    releases half at t=0 and half at a *fixed* ``1/rate`` seconds — one
    mean inter-arrival gap, independent of ``n``.  (The old offset was
    ``0.5/rate * n``: it grew with the trace length, so large traces
    degenerated into two disjoint static batches that never overlapped in
    the slot table and inflated the continuous-batching backfill win.
    Pinned by ``tests/test_arrival_traces.py``.)
    """
    if kind == "none" or rate <= 0:
        return np.zeros(n)
    rng = np.random.default_rng(seed + 1)
    if kind == "poisson":
        return np.cumsum(rng.exponential(1.0 / rate, size=n))
    if kind == "uniform":
        return np.arange(n) / rate
    if kind == "burst":
        half = (n + 1) // 2
        return np.concatenate([np.zeros(half), np.full(n - half, 1.0 / rate)])
    raise ValueError(f"unknown arrival kind {kind!r}")


# --------------------------------------------------------------------------- #
# --engine lm
# --------------------------------------------------------------------------- #
def run_lm(args) -> dict:
    import dataclasses as _dc

    from repro.launch.mesh import host_serving_setup
    from repro.models.transformer import init_model
    from repro.serve import (QueueAutoscaler, RadixPrefixCache, ReplicaRouter,
                             Request, ServeEngine, SlotScheduler)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_layers or cfg.vision_tokens:
        print(f"note: {cfg.name} frontend is stubbed; serving text-only path")
    if args.quantize != "none":
        cfg = _dc.replace(cfg, quantize=args.quantize)
    params, axes = init_model(jax.random.PRNGKey(args.seed), cfg)
    fleet = args.replicas > 0
    mesh = rules = param_axes = None
    if args.mesh:
        if fleet:
            raise SystemExit("--mesh and --replicas are mutually exclusive "
                             "(the fleet shards lanes, not params)")
        mesh, rules = host_serving_setup(cfg)
        param_axes = axes

    lens = [int(x) for x in args.prompt_lens.split(",") if x]
    rng = np.random.default_rng(args.seed)
    arrivals = arrival_trace(args.arrival, args.requests, args.rate, args.seed)
    tenants = [f"t{i}" for i in range(max(1, args.tenants))]
    # with --prefix-share p, fraction p of the requests open with ONE shared
    # --prefix-len token prefix (a synthetic system prompt); the rest are
    # fully random at the SAME total length, so cache-on vs cache-off runs
    # and shared vs unshared requests all prefill identical token counts
    shared_prefix = rng.integers(0, cfg.vocab_size,
                                 size=args.prefix_len).astype(np.int32)

    def _prompt(i: int) -> np.ndarray:
        n = args.prefix_len + lens[i % len(lens)]
        if args.prefix_share > 0 and rng.random() < args.prefix_share:
            tail = rng.integers(0, cfg.vocab_size,
                                size=n - args.prefix_len).astype(np.int32)
            return np.concatenate([shared_prefix, tail])
        return rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)

    reqs = [Request(prompt=_prompt(i) if args.prefix_share > 0
                    else rng.integers(0, cfg.vocab_size,
                                      size=lens[i % len(lens)]
                                      ).astype(np.int32),
                    max_new_tokens=args.max_new, arrival=float(arrivals[i]),
                    tenant=tenants[i % len(tenants)],
                    slo_ms=args.slo_ms if args.slo_ms > 0 else None)
            for i in range(args.requests)]
    prefix_cache = (RadixPrefixCache(block_size=args.prefix_block,
                                     capacity_blocks=args.prefix_capacity)
                    if args.prefix_cache == "on" else None)

    def _prefix_fields(rep: dict) -> None:
        s = prefix_cache.stats() if prefix_cache is not None else None
        rep["prefix_cache"] = s
        rep["prefill_tokens"] = (s["prompt_tokens"] if s
                                 else sum(len(r.prompt) for r in reqs))
        rep["cached_prefill_tokens"] = s["cached_tokens"] if s else 0
        rep["prefix_hit_rate"] = s["hit_rate"] if s else 0.0
        if s:
            print(f"  prefix cache: {s['cached_tokens']}/{s['prompt_tokens']} "
                  f"prefill tokens served cached (hit rate "
                  f"{s['hit_rate']:.2f}), {s['evictions']} evictions")

    if fleet:
        autoscaler = None
        if args.autoscale_min > 0:
            autoscaler = QueueAutoscaler(
                slots_per_replica=args.slots,
                min_replicas=args.autoscale_min,
                max_replicas=args.replicas)
        router = ReplicaRouter(
            cfg, params, slots_per_replica=args.slots,
            max_replicas=args.replicas, max_seq=args.max_seq,
            admission=args.admission, autoscaler=autoscaler,
            min_replicas=args.autoscale_min or args.replicas,
            prefix_cache=prefix_cache)
        if not args.no_warmup:
            t0 = time.perf_counter()
            spans = (range(args.autoscale_min, args.replicas + 1)
                     if autoscaler else [args.replicas])
            router.warmup(prompt_lens=lens, spans=spans)
            print(f"warmup (compile) {time.perf_counter() - t0:.2f}s — "
                  "excluded from the perf report")
        start = time.perf_counter()
        done = router.run(reqs, now_fn=lambda: time.perf_counter() - start)
        dt = time.perf_counter() - start
        served = [r for r in done if r.done]
        total_new = sum(len(r.out_tokens) for r in served)
        rep = router.report()
        rep.pop("per_replica")
        rep.update({
            "engine": "lm", "arch": args.arch, "slots": args.slots,
            "requests": len(served), "new_tokens": total_new,
            "seconds": round(dt, 4),
            "requests_per_sec": round(len(served) / dt, 2),
            "tokens_per_sec": round(total_new / dt, 1),
            "arrival": args.arrival, "rate": args.rate,
            "quantize": args.quantize, "admission": args.admission,
            "tenants_n": len(tenants), "mesh": "none",
        })
        print(f"fleet served {len(served)}/{len(done)} requests / "
              f"{total_new} tokens in {dt:.2f}s "
              f"({rep['requests_per_sec']} req/s, "
              f"{rep['tokens_per_sec']} tok/s) | "
              f"{args.replicas}x{args.slots} lanes, quantize={args.quantize}")
        print(f"latency p50={rep['latency_p50']*1e3:.1f}ms "
              f"p95={rep['latency_p95']*1e3:.1f}ms "
              f"p99={rep['latency_p99']*1e3:.1f}ms | "
              f"rejected={rep['rejected']} degraded={rep['degraded']} | "
              f"backfills={rep['backfills']}")
        for t, tr in sorted(rep["tenants"].items()):
            print(f"  {t}: finished={tr['finished']} rejected={tr['rejected']}"
                  f" slo_attainment={tr['slo_attainment']:.2f}")
        if rep["autoscaler_events"]:
            print(f"  autoscaler: {rep['autoscaler_events']}")
        _prefix_fields(rep)
        assert all(r.done or r.rejected for r in done)
        return rep

    engine = ServeEngine(cfg, params, batch_size=args.slots,
                         max_seq=args.max_seq, mesh=mesh, rules=rules,
                         param_axes=param_axes, prefix_cache=prefix_cache)
    if not args.no_warmup:
        t0 = time.perf_counter()
        engine.warmup(prompt_lens=lens)
        print(f"warmup (compile) {time.perf_counter() - t0:.2f}s — "
              "excluded from the perf report")

    sched = SlotScheduler(args.slots)
    start = time.perf_counter()
    now_fn = (lambda: time.perf_counter() - start)
    done = engine.run(reqs, scheduler=sched, now_fn=now_fn)
    dt = time.perf_counter() - start

    total_new = sum(len(r.out_tokens) for r in done)
    rep = sched.report()
    rep.update({
        "engine": "lm", "arch": args.arch, "slots": args.slots,
        "requests": len(done), "new_tokens": total_new,
        "seconds": round(dt, 4),
        "requests_per_sec": round(len(done) / dt, 2),
        "tokens_per_sec": round(total_new / dt, 1),
        "arrival": args.arrival, "rate": args.rate,
        "quantize": args.quantize,
        "mesh": (f"{tuple(mesh.devices.shape)}" if mesh is not None
                 else "none"),
        "ragged_prefill": engine.ragged_ok,
    })
    _prefix_fields(rep)
    print(f"served {len(done)} requests / {total_new} tokens in {dt:.2f}s "
          f"({rep['requests_per_sec']} req/s, {rep['tokens_per_sec']} tok/s)")
    print(f"queue depth max={rep['queue_depth_max']} "
          f"mean={rep['queue_depth_mean']:.2f} | backfills={rep['backfills']} "
          f"| wait p50={rep['wait_p50']*1e3:.1f}ms p95={rep['wait_p95']*1e3:.1f}ms "
          f"| latency p50={rep['latency_p50']*1e3:.1f}ms "
          f"p95={rep['latency_p95']*1e3:.1f}ms "
          f"p99={rep['latency_p99']*1e3:.1f}ms")
    for i, r in enumerate(done[:4]):
        print(f"  req{i}: {r.out_tokens[:8]}...")
    assert all(r.done for r in done)
    return rep


# --------------------------------------------------------------------------- #
# --engine model
# --------------------------------------------------------------------------- #
def run_model(args) -> dict:
    from repro.core.numeric_table import MLNumericTable
    from repro.serve import ModelPredictor, PredictRequest

    rng = np.random.default_rng(args.seed)
    if args.algorithm == "logreg":
        from repro.core.algorithms.logistic_regression import (
            LogisticRegressionAlgorithm, LogisticRegressionParameters)
        w = np.linspace(-1, 1, args.features).astype(np.float32)
        X = rng.normal(size=(args.rows, args.features)).astype(np.float32)
        y = (X @ w > 0).astype(np.float32)
        table = MLNumericTable.from_numpy(
            np.concatenate([y[:, None], X], 1), num_shards=args.shards)
        model = LogisticRegressionAlgorithm(max_iter=5).fit(table)
    else:
        from repro.core.algorithms.kmeans import KMeans, KMeansParameters
        k = 4
        centers = np.stack([np.full(args.features, 2.5 * (i - (k - 1) / 2))
                            for i in range(k)]).astype(np.float32)
        X = (centers[rng.integers(0, k, size=args.rows)]
             + 0.3 * rng.normal(size=(args.rows, args.features))
             ).astype(np.float32)
        table = MLNumericTable.from_numpy(X, num_shards=args.shards)
        model = KMeans(KMeansParameters(
            k=k, max_iter=5, use_kernel=args.kernel)).fit(table)

    service = ModelPredictor(model, max_batch=args.batch,
                             num_shards=args.shards)
    # request stream: feature blocks of mixed sizes
    sizes = rng.integers(1, max(2, args.batch // 2), size=args.requests)
    blocks = [rng.normal(size=(int(s), args.features)).astype(np.float32)
              for s in sizes]

    # warm the compiled predict before timing
    if not args.no_warmup:
        service.predict_many([blocks[0]])
        service.batches = service.rows_served = service.rows_padded = 0

    arrivals = arrival_trace(args.arrival, args.requests, args.rate, args.seed)
    start = time.perf_counter()
    for b, a in zip(blocks, arrivals):
        wait = a - (time.perf_counter() - start)
        if wait > 0:
            time.sleep(wait)
        service.submit(PredictRequest(features=b, arrival=float(a)))
    done = service.flush(now=time.perf_counter() - start)
    dt = time.perf_counter() - start

    rows = sum(b.shape[0] for b in blocks)
    rep = service.report()
    rep.update({
        "engine": "model", "algorithm": args.algorithm,
        "requests": len(done), "rows": rows, "seconds": round(dt, 4),
        "rows_per_sec": round(rows / dt, 1),
        "requests_per_sec": round(len(done) / dt, 2),
    })
    print(f"served {len(done)} predict requests / {rows} rows in {dt:.3f}s "
          f"({rep['rows_per_sec']} rows/s, {rep['batches']} microbatches, "
          f"pad fraction {rep['pad_fraction']:.2f})")
    assert all(r.done and r.result is not None for r in done)
    return rep


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", default="lm", choices=("lm", "model"))
    # shared
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arrival", default="none",
                    choices=("none", "poisson", "uniform", "burst"))
    ap.add_argument("--rate", type=float, default=0.0,
                    help="mean arrival rate (requests/sec; 0 = all at once)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip compile warmup (the report then includes "
                         "compile time)")
    ap.add_argument("--json", action="store_true",
                    help="print a RESULT::{json} line with the perf report")
    # lm engine
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (continuous-batching batch size; "
                         "slots PER REPLICA with --replicas)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="serve through a ReplicaRouter fleet of this many "
                         "replicas (0 = single engine)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="synthetic tenants (requests round-robin t0..tN-1)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="per-request arrival→finish SLO in ms (0 = none)")
    ap.add_argument("--admission", default="none",
                    choices=("none", "reject", "degrade"),
                    help="fleet admission control when the predicted "
                         "completion misses the SLO")
    ap.add_argument("--quantize", default="none",
                    choices=("none", "bf16", "int8"),
                    help="weight quantization for the decode/prefill path")
    ap.add_argument("--prefix-cache", default="off", choices=("on", "off"),
                    help="radix prefix KV cache: reuse repeated prompt "
                         "prefixes across requests (and replicas)")
    ap.add_argument("--prefix-block", type=int, default=16,
                    help="prefix cache block size in tokens")
    ap.add_argument("--prefix-capacity", type=int, default=256,
                    help="prefix cache capacity in blocks (LRU beyond)")
    ap.add_argument("--prefix-share", type=float, default=0.0,
                    help="fraction of requests opening with one shared "
                         "--prefix-len token prefix (synthetic system "
                         "prompt); the rest are random at the same length")
    ap.add_argument("--prefix-len", type=int, default=32,
                    help="shared prefix length for --prefix-share traffic")
    ap.add_argument("--autoscale-min", type=int, default=0,
                    help="enable queue-driven autoscale with this minimum "
                         "replica count (0 = fixed fleet)")
    ap.add_argument("--prompt-lens", default="8,12,16,20",
                    help="comma list; request i uses length i mod list")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--mesh", action="store_true",
                    help="run under host_serving_setup (slot sharding over "
                         "host devices)")
    # model engine
    ap.add_argument("--algorithm", default="logreg",
                    choices=("logreg", "kmeans"))
    ap.add_argument("--rows", type=int, default=256)
    ap.add_argument("--features", type=int, default=16)
    ap.add_argument("--batch", type=int, default=64,
                    help="microbatch rows (compiled predict shape)")
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--kernel", action="store_true",
                    help="kmeans: route assignment through the Pallas kernel")
    args = ap.parse_args()

    rep = run_lm(args) if args.engine == "lm" else run_model(args)
    if args.json:
        print("RESULT::" + json.dumps(rep))


if __name__ == "__main__":
    main()
