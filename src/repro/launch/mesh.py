"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init;
tests and benches see the plain 1-device CPU).

Topology (TPU v5e target):
    single pod   (16, 16)    axes ("data", "model")   — 256 chips
    multi-pod    (2, 16, 16) axes ("pod", "data", "model") — 512 chips

The "data" axis carries the paper's partition dimension (MLI partitions ==
data-parallel shards); "model" adds tensor/expert parallelism; "pod" is the
cross-pod data-parallel axis whose collectives ride DCI, not ICI.
"""
from __future__ import annotations

import jax

from repro.core.compat import make_mesh

__all__ = ["make_production_mesh", "make_host_mesh", "serving_setup",
           "host_serving_setup"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_serving_mesh(*, multi_pod: bool = False,
                      model: int = 8) -> jax.sharding.Mesh:
    """Serving-tuned factorization of the same chips (§Perf H1d): decode
    wants the model axis to DIVIDE the kv-head count so the cache IO layout
    matches GSPMD's head-parallel attention — (32, 8) removed granite's
    per-step 86 GB cache all-gather entirely (collective term 1.72 s →
    2.4 ms).  Default model=8 fits every GQA arch in the pool (kv ∈
    {1, 2, 8, 12, 40 → replicated})."""
    data = (512 if multi_pod else 256) // model
    if multi_pod:
        return make_mesh((2, data // 2, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def serving_setup(cfg, *, multi_pod: bool = False):
    """Per-arch serving profile (EXPERIMENTS.md §Perf, optimized-serving
    table): attention-cache-dominated archs win 3–14× on the (32,8) mesh +
    SERVE_RULES; recurrent/SSM archs (tiny per-step state, weight-read
    bound) keep the training mesh + DEFAULT_RULES, where FSDP storage beats
    replicated weight reads.  Returns (mesh, rules)."""
    from repro.models.config import BlockKind
    from repro.sharding.rules import DEFAULT_RULES, SERVE_RULES

    recurrent = any(k in (BlockKind.RGLRU, BlockKind.SSD)
                    for k in cfg.pattern)
    if recurrent:
        return make_production_mesh(multi_pod=multi_pod), DEFAULT_RULES
    return make_serving_mesh(multi_pod=multi_pod), SERVE_RULES


def host_serving_setup(cfg):
    """:func:`serving_setup` sized to whatever host devices exist: the same
    per-arch rules selection, but the mesh is (devices, 1) — the "data"
    axis carries the serve engine's decode-slot sharding (cache batch dim),
    "model" collapses to 1.  This is what ``launch/serve.py`` and the
    serving tests run under on CPU; on a real pod use
    :func:`serving_setup`.  Returns (mesh, rules)."""
    from repro.models.config import BlockKind
    from repro.sharding.rules import DEFAULT_RULES, SERVE_RULES

    recurrent = any(k in (BlockKind.RGLRU, BlockKind.SSD)
                    for k in cfg.pattern)
    mesh = make_host_mesh(data=len(jax.devices()), model=1)
    return mesh, (DEFAULT_RULES if recurrent else SERVE_RULES)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — used by tests and
    the CPU examples; same axis names as production so all sharding rules
    apply unchanged."""
    return make_mesh((data, model), ("data", "model"))
