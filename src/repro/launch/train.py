"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs REAL steps (allocates params, iterates data) — on this CPU container
use ``--smoke`` (reduced same-family config) or a custom ``--d-model`` etc.;
on a pod the same entry point takes the full config and the production mesh.

Example (CPU):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.data import BatchIterator, SyntheticLMDataset
from repro.launch.mesh import make_host_mesh
from repro.optim.optimizers import adamw
from repro.train.step import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    print(f"arch={cfg.name} layers={cfg.num_layers} d_model={cfg.d_model} "
          f"params≈{param_count_estimate(cfg)/1e6:.1f}M")

    optimizer = adamw(lr=args.lr, total_steps=args.steps)
    state, axes = init_train_state(jax.random.PRNGKey(args.seed), cfg, optimizer)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state.params))
    print(f"params={n_params/1e6:.2f}M")

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, start = restore_checkpoint(args.ckpt_dir, state)
        print(f"restored step {start}")

    step_fn = make_train_step(cfg, optimizer)
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq,
                            batch_size=args.batch, seed=args.seed)

    def with_extras(step: int) -> dict:
        b = ds.batch(step)
        if cfg.vision_tokens:
            b["vision_embeds"] = np.zeros(
                (args.batch, cfg.vision_tokens, cfg.d_model), np.float32)
        if cfg.encoder_layers:
            b["encoder_frames"] = np.random.default_rng(step).normal(
                size=(args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        return b

    it = BatchIterator(with_extras, start_step=start)
    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        batch = next(it)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            toks = args.batch * args.seq * (step - start + 1)
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f} "
                  f"tok/s {toks/dt:,.0f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, state)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state)
    first, last = losses[0], losses[-1]
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


def param_count_estimate(cfg) -> float:
    """Rough non-embedding parameter count for the banner."""
    d, L, f = cfg.d_model, cfg.num_layers, cfg.d_ff
    per = 4 * d * d + (3 if cfg.mlp_kind == "swiglu" else 2) * d * f * max(cfg.num_experts, 1)
    return L * per + cfg.vocab_size * d


if __name__ == "__main__":
    main()
