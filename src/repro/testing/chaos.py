"""Deterministic fault injection for multi-host chaos tests.

A *fault* is (host, round, action): at the moment the targeted host asks for
the window of the targeted round, the injector

  * ``kill``  — delivers an uncatchable ``SIGKILL`` to the host's own
    process (a preemption: no cleanup, no flush — only what is already
    atomically on disk survives);
  * ``delay`` — sleeps ``seconds`` first (a straggler: the round completes,
    late);
  * ``drop``  — marks the host departed on its exchange store (graceful
    leave) and exits the process with :data:`DROP_EXIT_CODE`.

Faults are injected *inside* the victim, at a deterministic stream step —
not by an outside killer racing the training loop — so every chaos scenario
is exactly reproducible.  The spec travels to host subprocesses through one
environment variable (:data:`ENV_VAR`), encoded as JSON by
:func:`faults_to_env`; the ``chaos_hosts`` fixture in ``tests/conftest.py``
owns the process spawning, and ``tests/test_streaming_resume.py``'s former
ad-hoc ``PreemptedIterator`` is this module's ``kill`` action now.

The injector hooks a :class:`repro.data.pipeline.BatchIterator` (or any
step-positioned stream) via :meth:`ChaosInjector.wrap_stream`: the stream's
``step`` counter is the round clock, so one mechanism serves the BSP global
mesh, the SSP exchange lane, and plain single-host streaming alike.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Dict, List, Optional, Sequence

__all__ = ["Fault", "ChaosInjector", "faults_to_env", "ENV_VAR",
           "DROP_EXIT_CODE"]

#: environment variable carrying the JSON fault spec into host subprocesses
ENV_VAR = "REPRO_CHAOS"

#: exit code of a host that executed a ``drop`` fault (graceful departure)
DROP_EXIT_CODE = 76

_ACTIONS = ("kill", "delay", "drop")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected fault: ``action`` on ``host`` at stream ``round``."""

    host: int
    round: int
    action: str
    seconds: float = 0.0  # delay only

    def __post_init__(self) -> None:
        if self.action not in _ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r} "
                             f"(one of {_ACTIONS})")
        if self.action == "delay" and self.seconds <= 0:
            raise ValueError("delay faults need seconds > 0")


def faults_to_env(faults: Sequence[Fault]) -> Dict[str, str]:
    """Encode a fault list as the environment entry host processes read."""
    payload = [dataclasses.asdict(f) for f in faults]
    return {ENV_VAR: json.dumps(payload)}


class ChaosInjector:
    """Executes the faults targeting one host, keyed by round index.

    Build with :meth:`from_env` inside a host process (returns an inert
    injector when no spec is present, so programs can install it
    unconditionally), or directly with a fault list for in-process use
    (the straggler benchmark).
    """

    def __init__(self, faults: Sequence[Fault] = (), host_id: int = 0,
                 store: Optional[object] = None):
        self.host_id = int(host_id)
        self.store = store  # ParamStore for drop faults (optional)
        self._by_round: Dict[int, Fault] = {}
        for f in faults:
            if f.host != self.host_id:
                continue
            if f.round in self._by_round:
                raise ValueError(
                    f"two faults target host {f.host} round {f.round}")
            self._by_round[f.round] = f
        self.injected: List[Fault] = []

    @classmethod
    def from_env(cls, host_id: Optional[int] = None,
                 store: Optional[object] = None) -> "ChaosInjector":
        """Injector for this process from :data:`ENV_VAR` (inert when
        unset).  ``host_id`` defaults to ``REPRO_HOST_ID``/0."""
        if host_id is None:
            host_id = int(os.environ.get("REPRO_HOST_ID", "0"))
        raw = os.environ.get(ENV_VAR)
        faults = [Fault(**d) for d in json.loads(raw)] if raw else []
        return cls(faults, host_id=host_id, store=store)

    def __bool__(self) -> bool:
        return bool(self._by_round)

    # ------------------------------------------------------------------ #
    # injection
    # ------------------------------------------------------------------ #
    def step(self, round_index: int) -> None:
        """Inject the fault registered for ``round_index``, if any.  Called
        by the wrapped stream right before it yields that round's window."""
        fault = self._by_round.get(round_index)
        if fault is None:
            return
        if fault.action == "kill":
            # uncatchable, like a pod preemption: no cleanup runs
            os.kill(os.getpid(), signal.SIGKILL)
        elif fault.action == "delay":
            self.injected.append(fault)
            time.sleep(fault.seconds)
        elif fault.action == "drop":
            if self.store is not None:
                self.store.mark_left()
            # graceful leave: flush stdio, exit with the marker code the
            # harness recognizes as departure (not failure)
            raise SystemExit(DROP_EXIT_CODE)

    def wrap_stream(self, stream):
        """Return a stream that injects this host's faults keyed by the
        underlying stream's ``step`` counter — the one mechanism every
        execution lane shares (the stream position IS the round clock)."""
        return _ChaosStream(stream, self)


class _ChaosStream:
    """Iterator proxy: ``injector.step(stream.step)`` before each window.

    Proxies the attributes the runner contract relies on (``step``,
    ``seek``, ``source``, ``mesh``) so it is drop-in wherever a
    :class:`repro.data.pipeline.BatchIterator` is accepted.
    """

    def __init__(self, stream, injector: ChaosInjector):
        self._stream = stream
        self._injector = injector

    @property
    def step(self):
        return self._stream.step

    @property
    def mesh(self):
        return getattr(self._stream, "mesh", None)

    @property
    def source(self):
        return self._stream.source

    def seek(self, step: int):
        self._stream.seek(step)
        return self

    def restrict(self, indices):
        return _ChaosStream(self._stream.restrict(indices), self._injector)

    def __iter__(self):
        return self

    def __next__(self):
        self._injector.step(self._stream.step)
        return next(self._stream)
