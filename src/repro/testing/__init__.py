"""Test/chaos support utilities shipped with the library.

Fault injection lives in the package (not in ``tests/``) because the same
injector drives three consumers: the ``tests/chaos/`` harness, the
``benchmarks/elastic_ssp.py`` straggler rows/sec comparison, and ad-hoc
manual chaos drives of the launch CLIs.  See :mod:`repro.testing.chaos`.
"""
from repro.testing.chaos import ChaosInjector, Fault, faults_to_env

__all__ = ["ChaosInjector", "Fault", "faults_to_env"]
