from repro.checkpoint.store import (
    latest_step,
    load_artifact,
    load_metadata,
    prune_checkpoints,
    restore_checkpoint,
    restore_with_metadata,
    save_artifact,
    save_checkpoint,
)

__all__ = [
    "save_checkpoint", "restore_checkpoint", "restore_with_metadata",
    "load_metadata", "latest_step", "prune_checkpoints",
    "save_artifact", "load_artifact",
]
