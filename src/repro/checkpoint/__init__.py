from repro.checkpoint.store import (
    latest_step,
    load_metadata,
    prune_checkpoints,
    restore_checkpoint,
    restore_with_metadata,
    save_checkpoint,
)

__all__ = [
    "save_checkpoint", "restore_checkpoint", "restore_with_metadata",
    "load_metadata", "latest_step", "prune_checkpoints",
]
