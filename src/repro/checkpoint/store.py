"""Restart-based checkpointing of parameter/optimizer pytrees.

The paper leans on Spark RDD lineage for fault tolerance; a TPU pod has no
lineage, so the recovery story is checkpoint + restart (DESIGN.md §2):
:class:`repro.core.runner.DistributedRunner` snapshots its training state
periodically (see ``CheckpointPolicy``) and ``resume`` restarts a killed run
from the latest snapshot, bit-for-bit on the same mesh.

Format: one ``step_<n>.npz`` per step with flattened key paths.  Each file
embeds a JSON record under a reserved key carrying

  * the per-leaf dtypes — required because numpy round-trips extension
    dtypes (``bfloat16``, the float8 family) as raw void arrays; restore
    reinterprets them back, so dtype preservation is exact;
  * optional host-side **metadata** (epoch/round counters, the stream
    position, rng keys) so a resumed run can restart the *whole* loop, not
    just the parameter values.

Writes are crash-safe: the array payload goes to a ``.tmp`` sibling, is
fsync'd, then atomically renamed (and the directory entry fsync'd), so a
kill mid-write can never corrupt the latest visible checkpoint — a
``latest_step`` scan ignores ``.tmp`` leftovers and any non-checkpoint
files.  Arrays are gathered to host before writing (fine for the example
scale; a production variant would write per-shard files — the key-path
format already supports that extension).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "restore_with_metadata",
    "load_metadata",
    "latest_step",
    "prune_checkpoints",
    "save_artifact",
    "load_artifact",
    "atomic_write_text",
    "atomic_write_json",
]

# anchored on both ends: "step_3.npz.tmp", "xstep_3.npz", "notes.txt" never match
_STEP_RE = re.compile(r"^step_(\d+)\.npz$")
#: reserved key inside the npz holding the JSON {dtypes, metadata} record
_META_KEY = "__checkpoint_meta__"


def _path_key(path: Tuple[Any, ...]) -> str:
    """Flatten a tree path to a stable string key: dict keys, sequence
    indices, and dataclass/attr field names all spell naturally."""
    def part(p: Any) -> str:
        for attr in ("key", "idx", "name"):
            if hasattr(p, attr):
                return str(getattr(p, attr))
        return str(p)
    return "/".join(part(p) for p in path)


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _path_key(path)
        if key in flat:
            raise ValueError(
                f"two leaves flatten to the same key {key!r} (a dict key "
                f"containing '/'?) — the checkpoint would silently drop one")
        flat[key] = leaf
    return flat


def _ckpt_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step}.npz")


def _fsync_dir(dirname: str) -> None:
    """Persist the directory entry of a just-renamed file (POSIX crash
    safety: the rename itself is atomic but not durable until the directory
    is synced)."""
    fd = os.open(dirname, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_text(path: str, text: str) -> str:
    """Durably publish ``text`` at ``path``: pid-unique tmp sibling →
    fsync → atomic ``os.replace`` → directory fsync.  The primitive every
    small host-side result/marker file goes through (the
    ``non-atomic-write`` lint rule enforces this inside store
    directories): a reader either sees the old complete file or the new
    complete file, never a torn one."""
    parent = os.path.dirname(path) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = f"{path}.{os.getpid()}.tmp"
    try:
        with open(tmp, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    _fsync_dir(parent)
    return path


def atomic_write_json(path: str, obj: Any, *, indent: Optional[int] = 2) -> str:
    """Atomic JSON publish (see :func:`atomic_write_text`): the standard
    sink for benchmark/launcher result emission."""
    return atomic_write_text(path, json.dumps(obj, indent=indent) + "\n")


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    metadata: Optional[Dict[str, Any]] = None,
                    keep: Optional[int] = None) -> str:
    """Write ``tree`` (any pytree of arrays) at ``step``; returns the path.

    ``metadata`` is any JSON-serializable dict of host-side loop state
    (epoch counters, stream step, rng key) stored inside the same file —
    one atomic unit, so state and counters can never be torn apart by a
    crash.  ``keep`` prunes all but the newest ``keep`` checkpoints after a
    successful publish.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        if k == _META_KEY:
            raise ValueError(f"tree key collides with reserved {_META_KEY!r}")
        a = np.asarray(jax.device_get(v))
        arrays[k] = a
        dtypes[k] = str(a.dtype)
    record = {"step": step, "dtypes": dtypes, "metadata": metadata}
    arrays[_META_KEY] = np.array(json.dumps(record))
    path = _ckpt_path(ckpt_dir, step)
    # pid-unique temp name: two writers racing on the same dir (e.g. an
    # operator resuming while the "dead" run is still flushing) can never
    # clobber each other's in-flight file; the rename stays last-wins atomic
    tmp = f"{path}.{os.getpid()}.tmp"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())    # payload durable before it becomes visible
        os.replace(tmp, path)       # atomic publish
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    _fsync_dir(ckpt_dir)
    if keep is not None:
        prune_checkpoints(ckpt_dir, keep)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest published step, or None.  ``.tmp`` leftovers from a killed
    write and any non-checkpoint files are ignored."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for fn in os.listdir(ckpt_dir)
             if (m := _STEP_RE.match(fn))]
    return max(steps) if steps else None


def prune_checkpoints(ckpt_dir: str, keep: int) -> None:
    """Delete all but the newest ``keep`` published checkpoints.

    Only *published* files are touched: ``.tmp`` partials are left alone
    because one may belong to a concurrently-flushing writer (deleting it
    from under them crashes their atomic rename); dead partials from
    crashes are harmless — every reader ignores them.
    """
    if keep < 1:
        raise ValueError(f"keep must be >= 1, got {keep}")
    if not os.path.isdir(ckpt_dir):
        return
    found = sorted(
        (int(m.group(1)), fn) for fn in os.listdir(ckpt_dir)
        if (m := _STEP_RE.match(fn))
    )
    for _, fn in found[:-keep] if len(found) > keep else []:
        os.remove(os.path.join(ckpt_dir, fn))


def _read_record(data) -> Dict[str, Any]:
    if _META_KEY in data.files:
        return json.loads(str(data[_META_KEY][()]))
    return {"dtypes": {}, "metadata": None}


def _load_arrays(path: str) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Read one checkpoint file: (arrays with dtypes reinterpreted, record).

    The npz handle is context-managed so the underlying zip file is closed
    even on a mismatch error part-way through.
    """
    with np.load(path) as data:
        record = _read_record(data)
        arrays = {}
        for k in data.files:
            if k == _META_KEY:
                continue
            a = data[k]
            want = record["dtypes"].get(k)
            if want is not None and str(a.dtype) != want:
                # extension dtypes (bfloat16, float8_*) come back as raw
                # void arrays; reinterpret with the recorded dtype
                a = a.view(np.dtype(want))
            arrays[k] = a
    return arrays, record


def _resolve_step(ckpt_dir: str, step: Optional[int]) -> int:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    return step


def _restore(ckpt_dir: str, tree: Any, step: Optional[int]
             ) -> Tuple[Any, int, Optional[Dict[str, Any]]]:
    step = _resolve_step(ckpt_dir, step)
    path = _ckpt_path(ckpt_dir, step)
    arrays, record = _load_arrays(path)
    flat_ref = _flatten(tree)
    missing = set(flat_ref) - set(arrays)
    extra = set(arrays) - set(flat_ref)
    if missing or extra:
        raise ValueError(
            f"checkpoint {path} does not match the template tree: "
            f"{len(missing)} template leaves absent from the checkpoint "
            f"(e.g. {sorted(missing)[:5]}), {len(extra)} checkpoint arrays "
            f"with no template leaf (e.g. {sorted(extra)[:5]}) — was the "
            f"checkpoint written for a different model/optimizer state?")
    leaves_ref, _ = jax.tree_util.tree_flatten_with_path(tree)
    keys_in_order = [_path_key(p) for p, _ in leaves_ref]
    restored = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree),
        [jnp.asarray(arrays[k]) for k in keys_in_order])
    return restored, step, record.get("metadata")


def restore_checkpoint(ckpt_dir: str, tree: Any, step: Optional[int] = None
                       ) -> Tuple[Any, int]:
    """Restore into the structure of ``tree`` (an abstract or concrete
    pytree).  Returns (restored_tree, step)."""
    restored, step, _ = _restore(ckpt_dir, tree, step)
    return restored, step


def restore_with_metadata(ckpt_dir: str, tree: Any, step: Optional[int] = None
                          ) -> Tuple[Any, int, Optional[Dict[str, Any]]]:
    """Like :func:`restore_checkpoint` but also returns the host-side
    ``metadata`` dict the checkpoint was saved with (None for checkpoints
    written without one)."""
    return _restore(ckpt_dir, tree, step)


#: step id under which one-shot artifacts (fitted models/pipelines — no
#: training-loop counter) are published
ARTIFACT_STEP = 0


def save_artifact(ckpt_dir: str, tree: Any,
                  metadata: Optional[Dict[str, Any]] = None) -> str:
    """Publish a *fitted artifact* (a trained model or pipeline: array
    state tree + JSON host state) as one atomic checkpoint file.  Same
    crash-safety as :func:`save_checkpoint`; artifacts use a dedicated
    directory and the fixed step :data:`ARTIFACT_STEP`."""
    return save_checkpoint(ckpt_dir, ARTIFACT_STEP, tree, metadata=metadata)


def load_artifact(ckpt_dir: str, tree: Any
                  ) -> Tuple[Any, Optional[Dict[str, Any]]]:
    """Restore an artifact written by :func:`save_artifact` into the
    structure of ``tree``; returns ``(restored_tree, metadata)``."""
    restored, _, meta = _restore(ckpt_dir, tree, ARTIFACT_STEP)
    return restored, meta


def load_metadata(ckpt_dir: str, step: Optional[int] = None
                  ) -> Optional[Dict[str, Any]]:
    """Read just the host-side metadata of a checkpoint — only the JSON
    record entry is decompressed, not the (potentially huge) arrays."""
    step = _resolve_step(ckpt_dir, step)
    with np.load(_ckpt_path(ckpt_dir, step)) as data:
        return _read_record(data).get("metadata")
