"""Restart-based checkpointing of parameter/optimizer pytrees.

The paper leans on Spark RDD lineage for fault tolerance; a TPU pod has no
lineage, so the recovery story is checkpoint + restart (DESIGN.md §2).

Format: one ``step_<n>.npz`` per step with flattened key paths, plus a
``meta.json`` carrying the treedef fingerprint and dtypes.  Arrays are
gathered to host before writing (fine for the example scale; a production
variant would write per-shard files — the key-path format already supports
that extension).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_STEP_RE = re.compile(r"step_(\d+)\.npz$")


def _flatten(tree: Any) -> Dict[str, jnp.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    """Write ``tree`` (any pytree of arrays) at ``step``; returns the path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    path = os.path.join(ckpt_dir, f"step_{step}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)  # atomic publish
    meta = {"step": step, "keys": sorted(arrays),
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()}}
    with open(os.path.join(ckpt_dir, "meta.json"), "w") as f:
        json.dump(meta, f)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for fn in os.listdir(ckpt_dir)
             if (m := _STEP_RE.search(fn))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, tree: Any, step: Optional[int] = None
                       ) -> Tuple[Any, int]:
    """Restore into the structure of ``tree`` (an abstract or concrete
    pytree).  Returns (restored_tree, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}.npz")
    data = np.load(path)
    flat_ref = _flatten(tree)
    missing = set(flat_ref) - set(data.files)
    extra = set(data.files) - set(flat_ref)
    if missing or extra:
        raise ValueError(f"checkpoint/tree mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    restored_flat = {k: jnp.asarray(data[k]) for k in flat_ref}
    leaves_ref, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys_in_order = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                              for p in path_) for path_, _ in leaves_ref]
    restored = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), [restored_flat[k] for k in keys_in_order])
    return restored, step
