"""Pipeline — the paper's Fig. A2 program as ONE fitted object.

``Pipeline([NGrams(...), TfIdf(), Standardizer(), LogisticRegression(...)])``
composes fitted transformers and a terminal estimator into a single
:class:`repro.core.interfaces.Estimator` that is the unit of everything
downstream:

  * **fit** — transformer statistics (vocabulary, IDF weights, column
    means/stds) are computed stage by stage on the *training* table only
    (host tier for schema-changing text stages, device tier — through the
    table's shared-nothing reduces — once the table commits to the mesh),
    then the estimator trains through
    :class:`repro.core.runner.DistributedRunner`;
  * **fit_stream** — same featurization, estimator trained from per-epoch
    minibatch windows; with a :class:`repro.core.runner.CheckpointPolicy`
    every snapshot is ONE atomic file carrying featurizer state + model
    state + stream position, and ``resume=True`` restores all three from
    it (bit-for-bit on the same mesh — the featurizers are *restored*, not
    refit);
  * **search** — :class:`repro.tune.ModelSearch` accepts a Pipeline as its
    algorithm; param spaces address nested stages (``"tfidf.top"``,
    ``"logreg.learning_rate"``) and featurizers are fit per train fold
    (no validation leakage), with stack-key grouping unchanged;
  * **serve** — the fitted pipeline is a :class:`Model` whose ``predict``
    accepts raw serving rows: host-tier vocab lookup, then the device-tier
    tf-idf → standardize → predict chain runs *inside* the
    :class:`repro.serve.ModelPredictor` microbatch jit.

Supervised pipelines follow the library convention: the label sits in
column 0 of the raw table, passes through every featurizer unscathed
(see ``features.scaling.resolve_skip``), and is stripped before the fitted
model's ``predict``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.interfaces import (
    Algorithm,
    Estimator,
    FittedEstimator,
    FittedTransformer,
    StreamFitable,
    Transformer,
)
from repro.core.mltable import MLTable
from repro.core.numeric_table import MLNumericTable
from repro.core.runner import CheckpointPolicy
from repro.features.scaling import FittedBiasAdder, FittedStandardizer
from repro.features.text import (
    FittedHashingVectorizer,
    FittedNGrams,
    FittedTfIdf,
)

__all__ = ["Pipeline", "FittedPipeline"]

#: default stage names — the keys nested search params address
_DEFAULT_NAMES = {
    "NGrams": "ngrams",
    "HashingVectorizer": "hashing",
    "TfIdf": "tfidf",
    "Standardizer": "standardizer",
    "BiasAdder": "bias",
    "LogisticRegressionAlgorithm": "logreg",
    "KMeans": "kmeans",
    "PCA": "pca",
    "GaussianNaiveBayes": "naive_bayes",
    "BroadcastALS": "als",
    "LinearRegressionAlgorithm": "linreg",
    "LinearSVMAlgorithm": "svm",
}

#: host-state ``kind`` → fitted transformer class (checkpoint rebuild)
_FITTED_KINDS = {
    "ngrams": FittedNGrams,
    "hashing": FittedHashingVectorizer,
    "tfidf": FittedTfIdf,
    "standardizer": FittedStandardizer,
    "bias": FittedBiasAdder,
}


def _auto_name(stage: Any) -> str:
    cls = type(stage).__name__
    return _DEFAULT_NAMES.get(cls, cls.lower())


def _is_raw_rows(x: Any) -> bool:
    """True for raw serving input: a str, a sequence of str, or an
    object/str-dtype array — anything the host featurizers must map to
    numbers before the device chain runs."""
    if isinstance(x, str):
        return True
    if isinstance(x, (list, tuple)):
        return bool(x) and isinstance(x[0], str)
    dtype = getattr(x, "dtype", None)
    return dtype is not None and np.dtype(dtype).kind in "OUS"


class FittedPipeline(FittedEstimator):
    """The fitted form of :class:`Pipeline`: fitted transformer stages plus
    the trained terminal model, replayable on tables or raw serving rows.
    """

    def __init__(self, pipeline: "Pipeline",
                 stages: Sequence[Tuple[str, FittedTransformer]],
                 model: Optional[FittedEstimator],
                 num_cols: int) -> None:
        self.pipeline = pipeline
        self.stages: List[Tuple[str, FittedTransformer]] = list(stages)
        self.model = model
        #: column count of the fully-featurized training table (labels
        #: included) — the width checkpoint templates are built from
        self.num_cols = int(num_cols)

    def __getitem__(self, name: str) -> FittedTransformer:
        for n, f in self.stages:
            if n == name:
                return f
        raise KeyError(f"no fitted stage named {name!r}")

    # ------------------------------------------------------------------ #
    # replay
    # ------------------------------------------------------------------ #
    def transform(self, table: Any, *, mesh="inherit", num_shards=None):
        """Replay every fitted transformer over ``table`` (host stages on
        the MLTable tier, device stages after the numeric commit); the
        terminal model is not applied."""
        return self.pipeline._transform_stages(self.stages, table,
                                               mesh=mesh,
                                               num_shards=num_shards)

    def featurize_rows(self, rows: Any) -> np.ndarray:
        """Host-tier replay of raw serving rows (vocab lookup): text →
        count matrix, ready for :meth:`apply_features`."""
        out = rows
        for _, f in self.stages:
            if f.tier == "host":
                out = f.transform_rows(out)
        return np.asarray(out, np.float32)

    def apply_features(self, feats: jnp.ndarray) -> jnp.ndarray:
        """Device-tier replay on label-free feature rows — pure jax, runs
        inside the serving microbatch jit."""
        out = jnp.asarray(feats)
        for _, f in self.stages:
            if f.tier == "device":
                out = f.apply(out)
        return out

    def predict(self, x: Any) -> jnp.ndarray:
        """Raw serving rows (str / list of str) run vocab lookup → device
        feature chain → model predict; numeric rows are taken at the
        post-host-featurization level (count rows) and run the device
        chain directly — which is why this predict is jit-traceable and
        serves through :class:`repro.serve.ModelPredictor` unchanged."""
        if _is_raw_rows(x):
            x = self.featurize_rows(x)
        feats = self.apply_features(x)
        if self.model is None:
            return feats
        return self.model.predict(feats)

    # ------------------------------------------------------------------ #
    # one-artifact checkpointing
    # ------------------------------------------------------------------ #
    @property
    def partial(self) -> Dict[str, Any]:
        tree: Dict[str, Any] = {
            "stages": {n: f.partial for n, f in self.stages}}
        if self.model is not None:
            tree["model"] = self.model.partial
        return tree

    def host_state(self) -> dict:
        state = {
            "stages": [[n, f.host_state()] for n, f in self.stages],
            "num_cols": self.num_cols,
        }
        if self.model is not None:
            state["model_shapes"] = {
                k: [list(np.shape(v)), str(np.asarray(v).dtype)]
                for k, v in self.model.partial.items()}
        return state

    def save(self, ckpt_dir: str) -> str:
        """Publish the whole fitted pipeline (featurizer statistics + model
        state + configuration) as ONE atomic artifact through
        :mod:`repro.checkpoint.store`; :meth:`Pipeline.load` restores it
        value- and dtype-exactly."""
        from repro.checkpoint.store import save_artifact

        return save_artifact(ckpt_dir, self.partial,
                             metadata={"pipeline": self.host_state()})


class Pipeline(Estimator, StreamFitable):
    """Composable Estimator: transformer stages + one terminal estimator.

    Parameters
    ----------
    stages:
        Transformer / estimator instances, or ``(name, stage)`` pairs; the
        final stage may be an :class:`Algorithm` instance (the trained
        model) — a transformer-only pipeline is a pure featurizer.  Names
        default per class (``ngrams``, ``tfidf``, ``standardizer``,
        ``bias``, ``logreg`` …) and are the prefixes nested search params
        address.
    mesh / num_shards:
        Layout of the numeric commit: once the first device-tier stage is
        reached, the (by then fully numeric) table is placed on ``mesh``
        (or split into ``num_shards`` emulated partitions).
    supervised:
        Whether column 0 of the raw table is the label (passed through
        every featurizer, stripped before predict).  Defaults to the
        terminal estimator's declaration.
    """

    def __init__(self, stages: Sequence[Any], *, mesh=None,
                 num_shards: Optional[int] = None,
                 supervised: Optional[bool] = None) -> None:
        if not stages:
            raise ValueError("Pipeline needs at least one stage")
        named: List[Tuple[str, Any]] = []
        seen: Dict[str, int] = {}
        for item in stages:
            name, stage = (item if isinstance(item, tuple) else
                           (_auto_name(item), item))
            if isinstance(stage, type):
                raise TypeError(
                    f"stage {name!r} is a class — pass an instance "
                    f"(hyperparameters in the constructor)")
            seen[name] = seen.get(name, 0) + 1
            if seen[name] > 1:
                name = f"{name}{seen[name]}"
            named.append((name, stage))
        self._estimator_name: Optional[str] = None
        self._estimator: Optional[Estimator] = None
        last_name, last = named[-1]
        if not isinstance(last, Transformer):
            if not isinstance(last, Estimator):
                raise TypeError(
                    f"final stage {last_name!r} is neither a Transformer "
                    f"nor an Estimator")
            self._estimator_name, self._estimator = last_name, last
            named = named[:-1]
        for name, stage in named:
            if not isinstance(stage, Transformer):
                raise TypeError(
                    f"stage {name!r} must be a Transformer (only the final "
                    f"stage may be an estimator)")
        self._stages: List[Tuple[str, Transformer]] = named
        self.mesh = mesh
        self.num_shards = num_shards
        if supervised is None:
            supervised = bool(getattr(self._estimator, "supervised", False))
        self.supervised = bool(supervised)

    # ------------------------------------------------------------------ #
    # introspection / search plumbing
    # ------------------------------------------------------------------ #
    @property
    def estimator(self) -> Optional[Estimator]:
        return self._estimator

    @property
    def estimator_name(self) -> Optional[str]:
        return self._estimator_name

    def stage_names(self) -> List[str]:
        return [n for n, _ in self._stages]

    def describe(self) -> dict:
        """JSON-able identity of the pipeline (stage classes + configs) —
        part of the search fingerprint, so a resumed search against a
        different pipeline refuses."""
        desc = {
            "stages": [[n, type(s).__name__,
                        {k: str(v) for k, v in
                         sorted(getattr(s, "_config", {}).items())}]
                       for n, s in self._stages],
            "supervised": self.supervised,
        }
        if self._estimator is not None:
            desc["estimator"] = [
                self._estimator_name, type(self._estimator).__name__,
                {k: str(v) for k, v in
                 sorted(self._estimator.overrides().items())}
                if isinstance(self._estimator, Algorithm) else {}]
        return desc

    def split_config(self, config: Dict[str, Any]
                     ) -> Tuple[Dict[str, Dict[str, Any]], Dict[str, Any]]:
        """Split a nested search config (``{"tfidf.top": 32,
        "logreg.learning_rate": 0.3}``) into per-transformer overrides and
        the estimator config.  Bare keys go to the estimator."""
        stage_names = set(self.stage_names())
        feat: Dict[str, Dict[str, Any]] = {}
        est: Dict[str, Any] = {}
        for key, v in config.items():
            if "." in key:
                stage, param = key.split(".", 1)
                if stage == self._estimator_name:
                    est[param] = v
                elif stage in stage_names:
                    feat.setdefault(stage, {})[param] = v
                else:
                    raise KeyError(
                        f"config key {key!r} addresses unknown stage "
                        f"{stage!r} (stages: {sorted(stage_names)}, "
                        f"estimator: {self._estimator_name!r})")
            else:
                est[key] = v
        return feat, est

    def with_stage_config(self, feat_cfgs: Dict[str, Dict[str, Any]]
                          ) -> "Pipeline":
        """Clone the pipeline with transformer hyperparameters replaced
        (the estimator instance is shared — its trials carry their own
        config)."""
        stages: List[Any] = [
            (n, s.clone_with(**feat_cfgs[n]) if n in feat_cfgs else s)
            for n, s in self._stages]
        if self._estimator is not None:
            stages.append((self._estimator_name, self._estimator))
        return Pipeline(stages, mesh=self.mesh, num_shards=self.num_shards,
                        supervised=self.supervised)

    # ------------------------------------------------------------------ #
    # tier plumbing
    # ------------------------------------------------------------------ #
    def _default_skip(self) -> Tuple[int, ...]:
        return (0,) if self.supervised else ()

    def _commit(self, table: Any, mesh, num_shards):
        if isinstance(table, MLTable):
            return table.to_numeric(num_shards=num_shards, mesh=mesh)
        return table

    def _resolve_layout(self, mesh, num_shards):
        if mesh == "inherit":
            mesh = self.mesh
            if num_shards is None:
                num_shards = self.num_shards
        return mesh, num_shards

    def _fit_stages(self, table: Any):
        """Fit every transformer stage in order (host tier first, device
        tier after the numeric commit); returns ``(fitted, final_table)``
        with ``final_table`` committed to the numeric tier."""
        fitted: List[Tuple[str, FittedTransformer]] = []
        current = table
        skip = self._default_skip()
        for name, stage in self._stages:
            if stage.tier == "host":
                if not isinstance(current, MLTable):
                    raise TypeError(
                        f"host-tier stage {name!r} needs an MLTable, but "
                        f"the table was already committed to the device "
                        f"tier — put text stages before numeric ones")
                f = stage.fit(current, default_skip=skip)
            else:
                current = self._commit(current, self.mesh, self.num_shards)
                f = stage.fit(current, default_skip=skip)
            current = f.transform(current)
            fitted.append((name, f))
        current = self._commit(current, self.mesh, self.num_shards)
        return fitted, current

    def _transform_stages(self, fitted: Sequence[Tuple[str, Any]],
                          table: Any, *, mesh="inherit",
                          num_shards=None):
        """Replay fitted stages over a table (any table: validation views,
        serving tables) with an optional layout override for views whose
        row counts do not divide the training mesh."""
        mesh, num_shards = self._resolve_layout(mesh, num_shards)
        current = table
        for name, f in fitted:
            if f.tier == "host":
                if not isinstance(current, MLTable):
                    raise TypeError(
                        f"host-tier stage {name!r} needs an MLTable input")
            else:
                current = self._commit(current, mesh, num_shards)
            current = f.transform(current)
        return self._commit(current, mesh, num_shards)

    # ------------------------------------------------------------------ #
    # fit / fit_stream
    # ------------------------------------------------------------------ #
    def fit(self, data: Any) -> FittedPipeline:
        """Fit transformers stage-by-stage, then train the terminal
        estimator through :class:`DistributedRunner` on the featurized
        table (resident)."""
        fitted, final = self._fit_stages(data)
        model = self._estimator.fit(final) if self._estimator else None
        return FittedPipeline(self, fitted, model, final.num_cols)

    def fit_stream(self, data: Any, *, num_epochs: Optional[int] = None,
                   chunks_per_epoch: int = 1,
                   checkpoint: Union[None, str, CheckpointPolicy] = None,
                   resume: bool = False, **stream_kwargs: Any
                   ) -> FittedPipeline:
        """Streaming fit: transformers fit (or, on resume, *restore*) as
        usual, then the estimator trains from per-epoch minibatch windows
        of the featurized table through
        :meth:`DistributedRunner.run_epochs`.

        With a checkpoint, every snapshot is ONE atomic file holding
        featurizer state + model state + stream position
        (:class:`CheckpointPolicy` ``extra_state``); ``resume=True``
        restores all three from the newest snapshot and continues
        bit-for-bit — the featurizers are rebuilt from the snapshot, never
        refit.
        """
        est = self._estimator
        if not isinstance(est, StreamFitable):
            raise TypeError(
                f"terminal estimator {type(est).__name__} does not "
                f"support fit_stream")
        if isinstance(checkpoint, str):
            checkpoint = CheckpointPolicy(checkpoint)
        if resume and checkpoint is None:
            raise ValueError("resume=True requires a checkpoint")

        if resume:
            fitted, num_cols = self._restore_stages(checkpoint, est)
            final = self._transform_stages(fitted, data)
            if final.num_cols != num_cols:
                raise ValueError(
                    f"resumed featurizers produce {final.num_cols} columns "
                    f"but the snapshot was written with {num_cols} — "
                    f"different raw data?")
        else:
            fitted, final = self._fit_stages(data)
            num_cols = final.num_cols
            if checkpoint is not None:
                checkpoint.extra_state = {
                    "stages": {n: f.partial for n, f in fitted}}
                checkpoint.extra_metadata = {"pipeline": {
                    "stages": [[n, f.host_state()] for n, f in fitted],
                    "num_cols": int(num_cols)}}

        X = np.asarray(final.data)
        mesh = final.mesh if final.mesh is not None else None

        def window_source(step: int):
            return {"data": X}

        from repro.data.pipeline import BatchIterator

        stream = BatchIterator(window_source, mesh=mesh)
        model = est.fit_stream(stream, num_epochs=num_epochs,
                               num_shards=final.num_shards,
                               chunks_per_epoch=chunks_per_epoch,
                               checkpoint=checkpoint, resume=resume,
                               **stream_kwargs)
        return FittedPipeline(self, fitted, model, num_cols)

    # ------------------------------------------------------------------ #
    # restore
    # ------------------------------------------------------------------ #
    @staticmethod
    def _stage_templates(pmeta: dict) -> Dict[str, Any]:
        out = {}
        for name, hs in pmeta["stages"]:
            cls = _FITTED_KINDS[hs["kind"]]
            out[name] = cls.partial_template(hs)
        return out

    @staticmethod
    def _rebuild_stages(pmeta: dict, arrays: Dict[str, Any]
                        ) -> List[Tuple[str, FittedTransformer]]:
        fitted = []
        for name, hs in pmeta["stages"]:
            cls = _FITTED_KINDS[hs["kind"]]
            fitted.append((name, cls.from_state(hs, arrays.get(name, {}))))
        return fitted

    def _restore_stages(self, policy: CheckpointPolicy, est: Any):
        """Rebuild the fitted featurizers from the newest streaming
        snapshot (one atomic file: the model carry restores next to them
        in :meth:`DistributedRunner.resume`) and prime the policy so later
        snapshots keep carrying the same state."""
        from repro.checkpoint.store import load_metadata, \
            restore_with_metadata

        meta = load_metadata(policy.ckpt_dir)
        if not meta or "extra" not in (meta or {}) or \
                "pipeline" not in meta["extra"]:
            raise ValueError(
                f"newest checkpoint under {policy.ckpt_dir} carries no "
                f"pipeline state — was it written by Pipeline.fit_stream?")
        pmeta = meta["extra"]["pipeline"]
        num_cols = int(pmeta["num_cols"])
        templates = {"stages": self._stage_templates(pmeta)}
        model_template = est.stream_state_template(num_cols)
        tree, _, _ = restore_with_metadata(
            policy.ckpt_dir, {"state": model_template, "extra": templates})
        fitted = self._rebuild_stages(pmeta, tree["extra"]["stages"])
        policy.extra_state = tree["extra"]
        policy.extra_metadata = meta["extra"]
        return fitted, num_cols

    def load(self, ckpt_dir: str) -> FittedPipeline:
        """Restore a fitted pipeline published by
        :meth:`FittedPipeline.save` — featurizer statistics and model
        state come back value- and dtype-exact."""
        from repro.checkpoint.store import ARTIFACT_STEP, load_artifact, \
            load_metadata

        meta = load_metadata(ckpt_dir, ARTIFACT_STEP)
        if not meta or "pipeline" not in meta:
            raise ValueError(f"{ckpt_dir} holds no pipeline artifact")
        pmeta = meta["pipeline"]
        template: Dict[str, Any] = {"stages": self._stage_templates(pmeta)}
        if "model_shapes" in pmeta:
            template["model"] = {
                k: jnp.zeros(tuple(shape), np.dtype(dtype))
                for k, (shape, dtype) in pmeta["model_shapes"].items()}
        tree, _ = load_artifact(ckpt_dir, template)
        fitted = self._rebuild_stages(pmeta, tree["stages"])
        model = None
        if "model" in template:
            if self._estimator is None:
                raise ValueError(
                    "artifact carries a trained model but this pipeline "
                    "has no terminal estimator to rebuild it")
            model = self._estimator.rebuild(tree["model"])
        return FittedPipeline(self, fitted, model, int(pmeta["num_cols"]))
