"""One fitted-model contract from raw table to serving (paper Fig. A2).

``Pipeline`` composes fitted transformers (:class:`repro.features.NGrams`,
``TfIdf``, ``HashingVectorizer``, ``Standardizer``, ``BiasAdder``) and one
terminal estimator (any of the six core algorithms) into a single object
that fits through :class:`repro.core.runner.DistributedRunner` (resident or
streaming), is searchable by :class:`repro.tune.ModelSearch` over nested
stage params, checkpoint/resumes as one atomic artifact, and serves raw
rows through :class:`repro.serve.ModelPredictor`.
"""
from repro.pipeline.pipeline import FittedPipeline, Pipeline

__all__ = ["Pipeline", "FittedPipeline"]
