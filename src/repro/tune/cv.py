"""Cross-validation splitters as row-index views (no data copy on host).

A fold here is nothing but a sorted array of row indices: :class:`KFold`
deterministically shuffles ``range(num_rows)`` with a seeded generator and
deals the permutation into k near-equal folds.  The *data* never moves on
the host — a split materializes either as

  * a **table view**: :func:`fold_view` row-gathers an
    :class:`repro.core.numeric_table.MLNumericTable` device-side (one
    ``jnp.take``, re-placed on the table's own mesh when the view still
    divides evenly over its shards), or
  * a **stream view**: :meth:`repro.data.pipeline.BatchIterator.restrict`
    applies the same index gather to every window the source yields, so a
    streamed search trains on exactly the rows a resident view would.

The two views agree row-for-row (property-tested in ``tests/test_cv.py``:
disjointness, exact cover, seed stability, resident/stream agreement).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterator, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["KFold", "fold_view", "holdout_split", "take_rows"]


@dataclasses.dataclass(frozen=True)
class KFold:
    """Deterministic k-fold assignment over ``num_rows`` rows.

    The seeded permutation is dealt into ``k`` folds whose sizes differ by
    at most one row (equal when ``k`` divides ``num_rows``); indices within
    each split are sorted ascending so views preserve the table's row
    order.  Construction is a pure function of ``(num_rows, k, seed)`` —
    re-creating with the same seed yields identical folds, which is what
    lets a resumed search re-derive its splits from checkpoint metadata.
    """

    num_rows: int
    k: int
    seed: int = 0

    def __post_init__(self) -> None:
        if not 2 <= self.k <= self.num_rows:
            raise ValueError(
                f"need 2 <= k <= num_rows, got k={self.k}, "
                f"num_rows={self.num_rows}")
        perm = np.random.default_rng(self.seed).permutation(self.num_rows)
        fold_of = np.empty(self.num_rows, dtype=np.int64)
        for i, chunk in enumerate(np.array_split(perm, self.k)):
            fold_of[chunk] = i
        # frozen dataclass: the cached assignment is derived state, not a field
        object.__setattr__(self, "_fold_of", fold_of)

    def _assignment(self) -> np.ndarray:
        """(num_rows,) fold id per row — the permutation dealt in order."""
        return self._fold_of

    def val_indices(self, fold: int) -> np.ndarray:
        """Sorted row indices of validation fold ``fold``."""
        self._check_fold(fold)
        return np.flatnonzero(self._assignment() == fold)

    def train_indices(self, fold: int) -> np.ndarray:
        """Sorted row indices of every fold except ``fold``."""
        self._check_fold(fold)
        return np.flatnonzero(self._assignment() != fold)

    def split(self, fold: int) -> Tuple[np.ndarray, np.ndarray]:
        """(train_indices, val_indices) of one fold."""
        self._check_fold(fold)
        fold_of = self._assignment()
        return np.flatnonzero(fold_of != fold), np.flatnonzero(fold_of == fold)

    def splits(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Iterate all k (train_indices, val_indices) pairs."""
        fold_of = self._assignment()
        for i in range(self.k):
            yield np.flatnonzero(fold_of != i), np.flatnonzero(fold_of == i)

    def _check_fold(self, fold: int) -> None:
        if not 0 <= fold < self.k:
            raise ValueError(f"fold must be in [0, {self.k}), got {fold}")


def holdout_split(num_rows: int, val_fraction: float = 0.25, seed: int = 0
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """One deterministic (train_indices, val_indices) split with
    ``ceil(val_fraction * num_rows)`` validation rows — the degenerate
    1-fold view for searches that don't need full CV."""
    if not 0.0 < val_fraction < 1.0:
        raise ValueError(f"val_fraction must be in (0, 1), got {val_fraction}")
    n_val = math.ceil(num_rows * val_fraction)
    if not 0 < n_val < num_rows:
        raise ValueError(
            f"val_fraction {val_fraction} leaves no rows for one of the "
            f"splits of {num_rows}")
    perm = np.random.default_rng(seed).permutation(num_rows)
    return np.sort(perm[n_val:]), np.sort(perm[:n_val])


def take_rows(table: Any, indices: np.ndarray) -> Any:
    """Row view of *any* table tier: an :class:`repro.core.mltable.MLTable`
    is gathered host-side (schema and partition count preserved) — the view
    pipeline featurizers fit on during a fold-aware search — while numeric
    tables delegate to :func:`fold_view`."""
    from repro.core.mltable import MLTable, _chunk

    if isinstance(table, MLTable):
        rows = table.collect()
        sel = [rows[int(i)] for i in np.asarray(indices)]
        return MLTable(_chunk(sel, table.num_partitions), table.schema)
    return fold_view(table, indices)


def fold_view(table: Any, indices: np.ndarray) -> Any:
    """Row-gather a table view: an MLNumericTable of ``table``'s rows at
    ``indices`` (sorted order preserved as given).

    The gather runs device-side (``jnp.take``) — no host round-trip.  When
    the view's row count still divides the table's mesh shards, the view
    keeps the same mesh placement; otherwise it falls back to a
    single-shard emulated table (collectives degrade to local reductions,
    semantics unchanged).
    """
    from repro.core.numeric_table import MLNumericTable

    idx = jnp.asarray(np.asarray(indices), jnp.int32)
    rows = jnp.take(table.data, idx, axis=0)
    if table.mesh is not None and rows.shape[0] % table.num_shards == 0:
        return MLNumericTable(rows, num_shards=table.num_shards,
                              mesh=table.mesh,
                              data_axes=table.data_axes or None)
    num_shards = table.num_shards if rows.shape[0] % table.num_shards == 0 else 1
    return MLNumericTable(rows, num_shards=num_shards)
