"""Model search: config enumeration, median early stopping, and the driver.

:func:`grid` and :func:`sample` enumerate candidate configurations
deterministically (the paper's MLbase motivation: train many candidate
``Parameters`` and keep the best).  :class:`ModelSearch` executes them over
a row-partitioned table:

  * **folds** — k-fold or holdout splits from :mod:`repro.tune.cv`,
    expressed as row-index views (train view streamed, validation view
    scored in place);
  * **execution** — ``"stacked"`` vmaps every same-shape group of trials
    over a leading trial axis so one jitted round advances the whole
    group (``DistributedRunner.run_stacked_epochs``), ``"sequential"``
    runs one trial per unit, ``"auto"`` = stacked where shapes allow;
  * **training** — always the PR-2 streaming path: each epoch pulls the
    train view's window from a :class:`repro.data.pipeline.BatchIterator`
    and scans ``chunks_per_epoch`` minibatch rounds over it, so searches
    inherit checkpoint/resume and the collective-schedule knob unchanged;
  * **early stopping** — the median rule (:class:`MedianStoppingRule`):
    after each rung, trials scoring below the median of their peers at
    the same rung are frozen (masked in stacked groups, skipped in
    sequential units);
  * **fault tolerance** — with ``ckpt_dir`` the search snapshots after
    every completed unit and ``run(..., resume=True)`` continues
    trial-for-trial after a kill.

Scores are **higher-is-better** throughout (loss metrics are negated).
Everything is a pure function of ``(configs, seed, data)`` — the
determinism ``tests/test_tune_determinism.py`` pins across collective
schedules and execution modes.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collectives import CollectiveSchedule
from repro.core.runner import DistributedRunner
from repro.data.pipeline import BatchIterator
from repro.tune.callback import (
    CallbackEnv,
    EarlyStopException,
    EvalEntry,
    fire_callbacks,
    split_callbacks,
)
from repro.tune.cv import KFold, fold_view, holdout_split, take_rows
from repro.tune.trials import (
    SearchCheckpointer,
    TrialSpec,
    fingerprint,
    group_trials,
    tree_stack,
    tree_unstack,
)

__all__ = [
    "grid",
    "sample",
    "MedianStoppingRule",
    "AsyncSuccessiveHalving",
    "AshaScheduler",
    "TrialResult",
    "SearchResult",
    "ModelSearch",
]


# --------------------------------------------------------------------------- #
# config enumeration
# --------------------------------------------------------------------------- #
def _is_range(v: Any) -> bool:
    return (isinstance(v, tuple) and len(v) == 3
            and v[0] in ("uniform", "loguniform"))


def grid(space: Dict[str, Sequence[Any]]) -> List[Dict[str, Any]]:
    """Cartesian product of a ``{param: [values…]}`` space, in sorted-key
    order — a pure function of the space, so every run of the same grid
    enumerates trials identically."""
    for k, v in space.items():
        if _is_range(v):
            raise ValueError(
                f"{k}={v!r} is a continuous range — ranges are for "
                f"sample(); a grid needs an explicit value list")
    keys = sorted(space)
    return [dict(zip(keys, vals))
            for vals in itertools.product(*(space[k] for k in keys))]


def sample(space: Dict[str, Any], num_samples: int, seed: int = 0
           ) -> List[Dict[str, Any]]:
    """Random search: ``num_samples`` deterministic draws from ``space``.

    Per key, a list/tuple of values is sampled uniformly; the 3-tuples
    ``("uniform", lo, hi)`` and ``("loguniform", lo, hi)`` draw continuous
    values.  Seeded — the same ``(space, num_samples, seed)`` always
    yields the same trial list, in the same order.
    """
    rng = np.random.default_rng(seed)
    configs = []
    for _ in range(num_samples):
        cfg: Dict[str, Any] = {}
        for k in sorted(space):
            v = space[k]
            if _is_range(v):
                lo, hi = float(v[1]), float(v[2])
                if lo > hi:
                    raise ValueError(f"{k}: range lower bound {lo} exceeds "
                                     f"upper bound {hi}")
                if v[0] == "loguniform":
                    if lo <= 0:
                        raise ValueError(
                            f"{k}: loguniform bounds must be positive, got "
                            f"[{lo}, {hi}]")
                    cfg[k] = float(np.exp(rng.uniform(np.log(lo), np.log(hi))))
                else:
                    cfg[k] = float(rng.uniform(lo, hi))
            else:
                options = list(v)
                choice = options[int(rng.integers(len(options)))]
                cfg[k] = choice.item() if hasattr(choice, "item") else choice
        configs.append(cfg)
    return configs


# --------------------------------------------------------------------------- #
# median early stopping
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class MedianStoppingRule:
    """Stop a trial whose rung score falls below the median of its peers.

    After rung ``r`` (0-indexed; rungs before ``min_rungs`` are always
    survived), a trial stops when at least ``min_trials`` *other* trials
    have recorded a score at the same rung and the trial's score is
    strictly below their median.  With sequential execution the
    comparators are previously-run trials (the classic asynchronous
    rule); with stacked execution the whole group reaches the rung
    together, so the comparison is synchronous.  Stopped trials keep
    their last score and their state freezes (masked in the stacked
    carry) — the round structure stays static, so no recompilation.
    """

    min_rungs: int = 1
    min_trials: int = 3

    def stop(self, rung: int, score: float, peer_scores: Sequence[float]) -> bool:
        if rung < self.min_rungs:
            return False
        if len(peer_scores) < self.min_trials:
            return False
        return score < float(np.median(np.asarray(peer_scores, np.float64)))


# --------------------------------------------------------------------------- #
# asynchronous successive halving
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class AsyncSuccessiveHalving:
    """ASHA: per-rung promotion decided the moment a trial reports.

    Rungs sit at trial-local epochs ``min_rounds * reduction_factor^j``
    (capped at the search's epoch budget, which is always the final
    rung).  When a trial reaches its next rung it reports its validation
    score and the decision is immediate — no cohort barrier: the trial
    is **promoted** when its score is at or above the top ``1/
    reduction_factor`` quantile of everything reported *at that rung so
    far*, else **stopped**, freeing its execution slot for the next
    pending trial (the same backfill move ``serve.SlotScheduler`` makes
    when a decode slot retires).  Early decisions are made against few
    peers and are therefore optimistic — exactly the asynchronous
    trade-off (Li et al., ASHA): slots never idle, so at a fixed device
    budget far more of the search space gets a first-rung look.

    Parameters
    ----------
    reduction_factor:
        Promote the top ``1/reduction_factor`` of each rung (and space
        rungs geometrically by the same factor).
    min_rounds:
        Trial-local epochs before the first rung.
    slots:
        Concurrent trial slots (stacked lane width).  Default: up to 8,
        capped at the config count.
    epoch_budget:
        Total slot-epochs the search may consume; admission stops once
        spent (running trials drain).  ``None`` = run the whole pool.
    """

    reduction_factor: int = 3
    min_rounds: int = 1
    slots: Optional[int] = None
    epoch_budget: Optional[int] = None

    def __post_init__(self) -> None:
        if self.reduction_factor < 2:
            raise ValueError(
                f"reduction_factor must be >= 2, got {self.reduction_factor}")
        if self.min_rounds < 1:
            raise ValueError(f"min_rounds must be >= 1, got {self.min_rounds}")
        if self.slots is not None and self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")

    def rung_epochs(self, num_epochs: int) -> List[int]:
        """Trial-local epoch of each rung, ascending; the final entry is
        always ``num_epochs`` (the finish line)."""
        out: List[int] = []
        e = self.min_rounds
        while e < num_epochs:
            out.append(e)
            e *= self.reduction_factor
        out.append(num_epochs)
        return out

    def promote(self, score: float, rung_scores: Sequence[float]) -> bool:
        """Promote iff ``score`` is at or above the top ``1/rf`` quantile
        of every score reported at this rung so far (itself included) —
        the asynchronous decision: later reports never revisit it."""
        q = 100.0 * (1.0 - 1.0 / self.reduction_factor)
        cut = float(np.percentile(np.asarray(rung_scores, np.float64), q))
        return float(score) >= cut


class AshaScheduler:
    """Host-side ASHA bookkeeping: slot table, pending queue, rung ledger.

    Pure control state — it never touches device arrays, so the SAME
    scheduler drives both execution modes (stacked lanes and sequential
    trials) through an identical decision sequence, which is what makes
    stacked-vs-sequential ASHA promotion-identical by construction.  The
    driver loop:

        admits = sched.admit()            # backfill free slots (FIFO)
        delta = sched.tick_size()         # epochs until the next rung
        ... advance every occupied slot by delta epochs ...
        sched.advance(delta)
        for slot, trial in sched.due():   # rung reached, in slot order
            sched.report(trial, score)    # promote | stop | done

    Everything is JSON-serializable (:meth:`state_dict` /
    :meth:`from_state_dict`), so a killed search restores the scheduler
    mid-rung and continues bit-for-bit.
    """

    def __init__(self, rule: AsyncSuccessiveHalving, num_trials: int,
                 num_epochs: int, slots: int):
        self.rule = rule
        self.rungs = rule.rung_epochs(num_epochs)
        self.num_trials = int(num_trials)
        self.slots: List[Optional[int]] = [None] * int(slots)
        self.pending: List[int] = list(range(num_trials))
        self.local_epoch: Dict[int, int] = {}
        self.next_rung: Dict[int, int] = {}
        # per rung: scores / trial ids in report order (the asynchronous
        # ledger each promotion decision quantiles over)
        self.rung_scores: List[List[float]] = [[] for _ in self.rungs]
        self.rung_trials: List[List[int]] = [[] for _ in self.rungs]
        self.terminal: Dict[int, str] = {}      # trial -> "stopped" | "done"
        self.slot_epochs = 0                    # budget meter
        self.global_epoch = 0

    # -- queries ------------------------------------------------------- #
    def occupied(self) -> List[Tuple[int, int]]:
        return [(j, t) for j, t in enumerate(self.slots) if t is not None]

    def exhausted(self) -> bool:
        return (self.rule.epoch_budget is not None
                and self.slot_epochs >= self.rule.epoch_budget)

    def finished(self) -> bool:
        return not self.occupied() and (not self.pending or self.exhausted())

    def tick_size(self) -> int:
        """Epochs until the nearest occupied slot reaches its next rung —
        the longest segment the driver can run without a decision."""
        rem = [self.rungs[self.next_rung[t]] - self.local_epoch[t]
               for _, t in self.occupied()]
        return min(rem) if rem else 0

    def due(self) -> List[Tuple[int, int]]:
        """Occupied slots whose trial sits exactly at its next rung, in
        slot order — the deterministic report order both execution modes
        share."""
        return [(j, t) for j, t in self.occupied()
                if self.local_epoch[t] == self.rungs[self.next_rung[t]]]

    # -- transitions --------------------------------------------------- #
    def admit(self) -> List[Tuple[int, int]]:
        """Backfill every free slot from the pending queue (FIFO), unless
        the epoch budget is spent.  Returns the (slot, trial) admissions."""
        admits: List[Tuple[int, int]] = []
        for j, occ in enumerate(self.slots):
            if occ is not None or not self.pending or self.exhausted():
                continue
            t = self.pending.pop(0)
            self.slots[j] = t
            self.local_epoch[t] = 0
            self.next_rung[t] = 0
            admits.append((j, t))
        return admits

    def advance(self, delta: int) -> None:
        occ = self.occupied()
        for _, t in occ:
            self.local_epoch[t] += delta
        self.slot_epochs += delta * len(occ)
        self.global_epoch += delta

    def report(self, trial: int, score: float) -> bool:
        """Record ``trial``'s score at its rung and decide immediately.
        Returns True when the trial keeps running (promoted), False when
        its slot was freed (stopped below the cut, or finished the final
        rung)."""
        rung = self.next_rung[trial]
        self.rung_scores[rung].append(float(score))
        self.rung_trials[rung].append(int(trial))
        j = self.slots.index(trial)
        if rung == len(self.rungs) - 1:
            self.terminal[trial] = "done"
            self.slots[j] = None
            return False
        if self.rule.promote(score, self.rung_scores[rung]):
            self.next_rung[trial] = rung + 1
            return True
        self.terminal[trial] = "stopped"
        self.slots[j] = None
        return False

    # -- persistence --------------------------------------------------- #
    def state_dict(self) -> dict:
        return {
            "rungs": self.rungs,
            "num_trials": self.num_trials,
            "slots": [(-1 if t is None else t) for t in self.slots],
            "pending": list(self.pending),
            "local_epoch": {str(t): e for t, e in self.local_epoch.items()},
            "next_rung": {str(t): r for t, r in self.next_rung.items()},
            "rung_scores": self.rung_scores,
            "rung_trials": self.rung_trials,
            "terminal": {str(t): s for t, s in self.terminal.items()},
            "slot_epochs": self.slot_epochs,
            "global_epoch": self.global_epoch,
        }

    @classmethod
    def from_state_dict(cls, rule: AsyncSuccessiveHalving, num_epochs: int,
                        state: dict) -> "AshaScheduler":
        sched = cls(rule, int(state["num_trials"]), num_epochs,
                    len(state["slots"]))
        if sched.rungs != [int(r) for r in state["rungs"]]:
            raise ValueError(
                f"checkpointed rung ladder {state['rungs']} does not match "
                f"this rule's {sched.rungs} — refusing to resume")
        sched.slots = [None if t == -1 else int(t) for t in state["slots"]]
        sched.pending = [int(t) for t in state["pending"]]
        sched.local_epoch = {int(t): int(e)
                             for t, e in state["local_epoch"].items()}
        sched.next_rung = {int(t): int(r)
                           for t, r in state["next_rung"].items()}
        sched.rung_scores = [[float(s) for s in rung]
                             for rung in state["rung_scores"]]
        sched.rung_trials = [[int(t) for t in rung]
                             for rung in state["rung_trials"]]
        sched.terminal = {int(t): str(s) for t, s in state["terminal"].items()}
        sched.slot_epochs = int(state["slot_epochs"])
        sched.global_epoch = int(state["global_epoch"])
        return sched


# --------------------------------------------------------------------------- #
# results
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class TrialResult:
    """Outcome of one trial: its config, the (higher-is-better) validation
    score averaged over folds, the per-rung score history, the final
    trained state of fold 0, and whether the median rule stopped it."""

    index: int
    config: Dict[str, Any]
    score: float
    rung_scores: List[float]
    state: Any
    stopped: bool = False
    # the trial's trained Model (spec.finalize(state)); None for custom
    # specs without a finalizer
    model: Any = None


@dataclasses.dataclass
class SearchResult:
    """All trials in enumeration order, plus the winner."""

    trials: List[TrialResult]

    @property
    def best(self) -> TrialResult:
        """Highest score; ties break to the lowest trial index, so the
        winner is deterministic under fp-equal scores."""
        return max(self.trials, key=lambda t: (t.score, -t.index))

    @property
    def scores(self) -> List[float]:
        return [t.score for t in self.trials]


# --------------------------------------------------------------------------- #
# the driver
# --------------------------------------------------------------------------- #
def _builtin_builder(algorithm: str, metric: Optional[str]
                     ) -> Callable[[Dict[str, Any]], TrialSpec]:
    """Resolve a registered algorithm name to its trial-spec builder
    (imported lazily: core algorithms must not import tune at load)."""
    if algorithm == "logreg":
        from repro.core.algorithms.logistic_regression import \
            LogisticRegressionAlgorithm as A
        return lambda cfg: A.trial_spec(cfg, metric=metric or "accuracy")
    if algorithm == "kmeans":
        from repro.core.algorithms.kmeans import KMeans as A
        return lambda cfg: A.trial_spec(cfg, metric=metric or "silhouette")
    raise ValueError(
        f"unknown algorithm {algorithm!r} — pass 'logreg', 'kmeans', or a "
        f"spec-builder callable")


def _tree_set(stacked: Any, lane: int, value: Any) -> Any:
    """Write one trial's pytree into lane ``lane`` of a stacked (K, …)
    tree — how an ASHA admission takes over a freed slot without touching
    the other lanes (or the compiled structure)."""
    return jax.tree.map(
        lambda s, v: s.at[lane].set(jnp.asarray(v, s.dtype)), stacked, value)


def _asha_history(sched: "AshaScheduler", trial: int) -> List[float]:
    """One trial's rung-score trajectory, rebuilt from the scheduler's
    per-rung ledger (ascending rung order — a trial reports at most once
    per rung)."""
    return [s for scores, trials in zip(sched.rung_scores, sched.rung_trials)
            for t, s in zip(trials, scores) if t == trial]


def _window_source(window: np.ndarray) -> Callable[[int], Dict[str, np.ndarray]]:
    """Stream source for a fold's train view: every epoch's window is the
    view's rows (a pure function of the step — seekable, resume-exact)."""
    def source(step: int) -> Dict[str, np.ndarray]:
        return {"data": window}

    return source


@dataclasses.dataclass
class ModelSearch:
    """Grid/random model search over one algorithm and one table.

    Parameters
    ----------
    algorithm:
        ``"logreg"``, ``"kmeans"``, a callable ``config -> TrialSpec``, or
        a :class:`repro.pipeline.Pipeline` instance — then ``run`` takes
        the *raw* table, config keys address nested stages
        (``"tfidf.top"``, ``"logreg.learning_rate"``; bare keys go to the
        estimator), and featurizers are fit per train fold only (no
        validation leakage).  Trials sharing a featurizer config and the
        estimator's stack key device-stack exactly as before.
    configs:
        The candidate list (:func:`grid` / :func:`sample` output).
    num_epochs / chunks_per_epoch:
        Streaming-epoch budget per trial: each epoch scans the train
        window in ``chunks_per_epoch`` minibatch rounds.
    folds:
        ``k >= 2`` for k-fold CV (scores averaged over folds); ``None``
        for a single ``val_fraction`` holdout split.
    execution:
        ``"auto"`` (stack same-shape groups) | ``"stacked"`` |
        ``"sequential"``.
    early_stop / rung_epochs:
        Optional stopping rule.  A :class:`MedianStoppingRule` applies
        every ``rung_epochs`` epochs (default 1 when a rule is set, else
        one rung spanning the whole budget).  An
        :class:`AsyncSuccessiveHalving` rule switches the driver to the
        slot-backfilling ASHA loop (its own geometric rung ladder;
        ``rung_epochs`` is ignored).
    callbacks:
        :mod:`repro.tune.callback` hooks.  Under the median driver they
        are threaded into every training segment (so ``hyper_schedule``
        steers epochs) AND fired at every rung boundary with the rung's
        scores as ``EvalEntry`` evals (so ``record_evaluation`` captures
        per-rung snapshots and ``early_stopping`` can halt the whole
        search).  Under ASHA they fire at rung boundaries only — trials
        in a slot table sit at *different* local epochs, so per-epoch
        hooks would see no consistent epoch counter across execution
        modes.  A rung-boundary ``{"hyper": ...}`` swap reaches later
        rungs; ``state``/``active`` swaps at the search level are
        refused — the stopping rule owns the mask.
    ckpt_dir:
        Search-level checkpoint directory (snapshot after every completed
        unit — or, under ASHA, after every rung report); ``run(resume=
        True)`` continues from it.
    """

    algorithm: Union[str, Callable[[Dict[str, Any]], TrialSpec]]
    configs: List[Dict[str, Any]]
    num_epochs: int = 8
    chunks_per_epoch: int = 1
    folds: Optional[int] = None
    val_fraction: float = 0.25
    metric: Optional[str] = None
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE
    execution: str = "auto"
    seed: int = 0
    early_stop: Union[MedianStoppingRule, AsyncSuccessiveHalving, None] = None
    rung_epochs: Optional[int] = None
    callbacks: Sequence[Callable] = ()
    ckpt_dir: Optional[str] = None
    # observer called after every completed (and checkpointed) unit with
    # (units_done, trial_indices) — progress lines, fault injection in the
    # kill-and-resume tests.  Not part of the search fingerprint.
    unit_callback: Optional[Callable[[int, List[int]], None]] = None

    def __post_init__(self) -> None:
        if not self.configs:
            raise ValueError("configs must not be empty")
        if self.folds is not None and self.folds < 2:
            raise ValueError(f"folds must be >= 2, got {self.folds}")

    # ------------------------------------------------------------------ #
    def _rungs(self) -> List[Tuple[int, int]]:
        """(start_epoch, end_epoch) segments: one per rung when early
        stopping is on, else a single segment spanning the budget."""
        step = self.rung_epochs or (1 if self.early_stop else self.num_epochs)
        edges = list(range(0, self.num_epochs, step)) + [self.num_epochs]
        return [(a, b) for a, b in zip(edges, edges[1:]) if b > a]

    def _fingerprint(self, table: Any, pipeline: Any = None) -> str:
        """Identity of this search INCLUDING the dataset shape — a resumed
        search against a different table must refuse, not silently mix
        scores computed on different data."""
        name = (self.algorithm if isinstance(self.algorithm, str)
                else getattr(self.algorithm, "__name__", "custom"))
        if pipeline is not None:
            name = {"pipeline": pipeline.describe()}
        if isinstance(self.early_stop, AsyncSuccessiveHalving):
            rule = self.early_stop
            rungs = rule.rung_epochs(self.num_epochs)
            early = ["asha", rule.reduction_factor, rule.min_rounds,
                     rule.slots, rule.epoch_budget]
        else:
            rungs = self._rungs()
            early = (None if self.early_stop is None else
                     [self.early_stop.min_rungs, self.early_stop.min_trials])
        return fingerprint({
            "algorithm": name, "configs": self.configs,
            "num_epochs": self.num_epochs,
            "chunks_per_epoch": self.chunks_per_epoch,
            "folds": self.folds, "val_fraction": self.val_fraction,
            "metric": self.metric,
            "schedule": CollectiveSchedule.parse(self.schedule).value,
            "execution": self.execution, "seed": self.seed,
            "rungs": rungs,
            "early_stop": early,
            "data_shape": [int(table.num_rows), int(table.num_cols)],
        })

    # ------------------------------------------------------------------ #
    def _prepare(self, table: Any) -> Tuple[DistributedRunner,
                                            CollectiveSchedule,
                                            List[np.ndarray], List[Any],
                                            List[Any]]:
        """Fold splits + execution layout, shared by every driver.

        Layout: keep the table's mesh whenever every train view can fill
        at least one (shards x chunks) window, else fall back to an
        emulated single shard.  MLI partitions are equal-sized by
        construction, so each train window is trimmed (deterministically,
        from the tail of the sorted index) to the largest multiple of
        shards * chunks_per_epoch — at most shards*chunks - 1 rows per
        fold sit out of training; validation views are never trimmed.
        """
        schedule = CollectiveSchedule.parse(self.schedule)
        n = table.num_rows
        if self.folds:
            splits = list(KFold(n, self.folds, self.seed).splits())
        else:
            splits = [holdout_split(n, self.val_fraction, self.seed)]

        mesh, shards = table.mesh, table.num_shards
        unit = shards * self.chunks_per_epoch
        if any(len(tr) < unit for tr, _ in splits):
            mesh, shards = None, 1
            unit = self.chunks_per_epoch
        runner = DistributedRunner(mesh=mesh, num_shards=shards,
                                   schedule=schedule)

        host_rows = np.asarray(table.data)
        train_idx = [tr[: len(tr) - len(tr) % unit] for tr, _ in splits]
        if any(len(tr) == 0 for tr in train_idx):
            raise ValueError(
                f"a train split is smaller than chunks_per_epoch="
                f"{self.chunks_per_epoch} — nothing left to train on")
        # one host copy per fold, shared by every execution unit
        train_windows = [np.ascontiguousarray(host_rows[tr])
                         for tr in train_idx]
        init_tables = [fold_view(table, tr) for tr in train_idx]
        val_tables = [fold_view(table, va) for _, va in splits]
        return runner, schedule, train_windows, init_tables, val_tables

    # ------------------------------------------------------------------ #
    def run(self, table: Any, resume: bool = False) -> SearchResult:
        """Execute the search over ``table`` and return every trial.

        The table is split into folds; each unit's trials stream the
        fold's train window for ``num_epochs`` epochs and are scored on
        the fold's validation view with the configured schedule; scores
        average over folds.  With ``resume=True`` (and ``ckpt_dir``),
        completed units restore from the newest snapshot and execution
        continues at the first unfinished unit.
        """
        from repro.pipeline import Pipeline

        if isinstance(self.algorithm, Pipeline):
            if isinstance(self.early_stop, AsyncSuccessiveHalving):
                raise NotImplementedError(
                    "ASHA over a Pipeline search is not supported yet — "
                    "use a MedianStoppingRule, or search the estimator "
                    "directly")
            return self._run_pipeline(table, resume)

        builder = (self.algorithm if callable(self.algorithm)
                   else _builtin_builder(self.algorithm, self.metric))
        specs = [builder(dict(cfg)) for cfg in self.configs]
        (runner, schedule, train_windows,
         init_tables, val_tables) = self._prepare(table)

        if isinstance(self.early_stop, AsyncSuccessiveHalving):
            return self._run_asha(table, specs, runner, schedule,
                                  train_windows, init_tables, val_tables,
                                  resume)

        groups = group_trials(specs, self.execution)
        rungs = self._rungs()

        done_states: Dict[int, Any] = {}
        done_info: Dict[int, Dict[str, Any]] = {}
        units_done = 0
        ckpt = (SearchCheckpointer(self.ckpt_dir, self._fingerprint(table))
                if self.ckpt_dir else None)
        if resume:
            if ckpt is None:
                raise ValueError("resume=True requires ckpt_dir")
            snap = ckpt.resume(lambda i: specs[i].init(init_tables[0]))
            if snap is not None:
                done_states, done_info, units_done = snap

        for unit_no, group in enumerate(groups):
            if unit_no < units_done:
                continue  # restored from the snapshot
            halted = self._run_unit(runner, specs, group, train_windows,
                                    init_tables, val_tables, rungs, schedule,
                                    done_states, done_info, unit_no=unit_no)
            units_done = unit_no + 1
            if ckpt is not None:
                ckpt.save(done_states, done_info, units_done)
            if self.unit_callback is not None:
                self.unit_callback(units_done, list(group))
            if halted:
                break  # a callback raised EarlyStopException: end the search

        trials = [
            TrialResult(index=i, config=dict(self.configs[i]),
                        score=done_info[i]["score"],
                        rung_scores=list(done_info[i]["rung_scores"]),
                        state=done_states[i],
                        stopped=bool(done_info[i]["stopped"]),
                        model=(specs[i].finalize(done_states[i])
                               if specs[i].finalize else None))
            for i in sorted(done_info)
        ]
        return SearchResult(trials=trials)

    # ------------------------------------------------------------------ #
    # pipeline search: featurizers fit per train fold, nested stage params
    # ------------------------------------------------------------------ #
    def _run_pipeline(self, table: Any, resume: bool = False) -> SearchResult:
        """Search a :class:`repro.pipeline.Pipeline` over a *raw* table.

        Each config splits into transformer overrides (``"tfidf.top"``)
        and estimator params; trials sharing a featurizer config share one
        featurization, and that featurization is fit on the fold's TRAIN
        view only — validation rows are transformed with the train-fitted
        statistics, never refit (the leakage rule the fitted-transformer
        redesign exists to enforce).  Execution, stacking, early stopping,
        and checkpoint/resume are the standard :meth:`run` machinery.
        """
        pipeline = self.algorithm
        est = pipeline.estimator
        if est is None or not hasattr(type(est), "trial_spec"):
            raise ValueError(
                "pipeline search needs a terminal estimator with a "
                "trial_spec (Searchable)")
        schedule = CollectiveSchedule.parse(self.schedule)
        base_over = est.overrides()
        metric_kw = {"metric": self.metric} if self.metric else {}

        split_cfgs = [pipeline.split_config(dict(c)) for c in self.configs]
        feat_keys = [json.dumps(fc, sort_keys=True, default=str)
                     for fc, _ in split_cfgs]
        specs: List[TrialSpec] = []
        for (fc, ec), fk in zip(split_cfgs, feat_keys):
            spec = type(est).trial_spec({**base_over, **ec}, **metric_kw)
            prev = (spec.stack_key if isinstance(spec.stack_key, tuple)
                    else (spec.stack_key,))
            specs.append(dataclasses.replace(spec, stack_key=(fk,) + prev))

        n = table.num_rows
        if self.folds:
            splits = list(KFold(n, self.folds, self.seed).splits())
        else:
            splits = [holdout_split(n, self.val_fraction, self.seed)]

        # layout mirrors run(): keep the pipeline's mesh when every train
        # window fills at least one (shards x chunks) unit, else emulate
        mesh = pipeline.mesh
        shards = (DistributedRunner(mesh=mesh).num_shards
                  if mesh is not None else (pipeline.num_shards or 1))
        unit = shards * self.chunks_per_epoch
        if any(len(tr) < unit for tr, _ in splits):
            mesh, shards = None, 1
            unit = self.chunks_per_epoch
        runner = DistributedRunner(mesh=mesh, num_shards=shards,
                                   schedule=schedule)
        train_idx = [tr[: len(tr) - len(tr) % unit] for tr, _ in splits]
        if any(len(tr) == 0 for tr in train_idx):
            raise ValueError(
                f"a train split is smaller than chunks_per_epoch="
                f"{self.chunks_per_epoch} — nothing left to train on")
        val_idx = [va for _, va in splits]

        # raw fold views are shared by every featurizer config — collect
        # the host rows once instead of re-gathering per config per fold
        raw_views: Dict[int, Any] = {}
        from repro.core.mltable import MLTable, _chunk

        host_rows = table.collect() if isinstance(table, MLTable) else None

        def raw_view(key: int, idx) -> Any:
            if key not in raw_views:
                if host_rows is not None:
                    raw_views[key] = MLTable(
                        _chunk([host_rows[int(i)] for i in idx],
                               table.num_partitions), table.schema)
                else:
                    raw_views[key] = take_rows(table, idx)
            return raw_views[key]

        # one featurization per distinct transformer config, lazy + cached:
        # (train windows, init tables, val tables) per fold, featurizers
        # fit on the train view only
        feat_cache: Dict[str, Tuple[List[np.ndarray], List[Any], List[Any]]] = {}

        def featurized(trial: int):
            fk = feat_keys[trial]
            if fk not in feat_cache:
                fp = pipeline.with_stage_config(split_cfgs[trial][0])
                fp.mesh, fp.num_shards = mesh, shards
                windows, inits, vals = [], [], []
                for f, (tr, va) in enumerate(zip(train_idx, val_idx)):
                    fitted, ftab = fp._fit_stages(raw_view(2 * f, tr))
                    windows.append(np.ascontiguousarray(
                        np.asarray(ftab.data)))
                    inits.append(ftab)
                    vals.append(fp._transform_stages(
                        fitted, raw_view(2 * f + 1, va), mesh=None,
                        num_shards=1))
                feat_cache[fk] = (windows, inits, vals)
            return feat_cache[fk]

        groups = group_trials(specs, self.execution)
        rungs = self._rungs()

        done_states: Dict[int, Any] = {}
        done_info: Dict[int, Dict[str, Any]] = {}
        units_done = 0
        ckpt = (SearchCheckpointer(self.ckpt_dir,
                                   self._fingerprint(table, pipeline))
                if self.ckpt_dir else None)
        if resume:
            if ckpt is None:
                raise ValueError("resume=True requires ckpt_dir")
            snap = ckpt.resume(lambda i: specs[i].init(featurized(i)[1][0]))
            if snap is not None:
                done_states, done_info, units_done = snap

        for unit_no, group in enumerate(groups):
            if unit_no < units_done:
                continue  # restored from the snapshot
            windows, inits, vals = featurized(group[0])
            halted = self._run_unit(runner, specs, group, windows, inits,
                                    vals, rungs, schedule, done_states,
                                    done_info, unit_no=unit_no)
            units_done = unit_no + 1
            if ckpt is not None:
                ckpt.save(done_states, done_info, units_done)
            if self.unit_callback is not None:
                self.unit_callback(units_done, list(group))
            if halted:
                break  # a callback raised EarlyStopException: end the search

        trials = [
            TrialResult(index=i, config=dict(self.configs[i]),
                        score=done_info[i]["score"],
                        rung_scores=list(done_info[i]["rung_scores"]),
                        state=done_states[i],
                        stopped=bool(done_info[i]["stopped"]),
                        model=(specs[i].finalize(done_states[i])
                               if specs[i].finalize else None))
            for i in sorted(done_info)
        ]
        return SearchResult(trials=trials)

    # ------------------------------------------------------------------ #
    def _run_unit(self, runner: DistributedRunner, specs: List[TrialSpec],
                  group: List[int], train_windows: List[np.ndarray],
                  init_tables: List[Any],
                  val_tables: List[Any], rungs: List[Tuple[int, int]],
                  schedule: CollectiveSchedule,
                  done_states: Dict[int, Any],
                  done_info: Dict[int, Dict[str, Any]], *,
                  unit_no: int = 0) -> bool:
        """Advance one execution unit (a stacked group or a single trial)
        through every rung of every fold, then record its trials.
        Returns True when a rung-boundary callback raised
        :class:`EarlyStopException` (the driver ends the search)."""
        spec0 = specs[group[0]]
        k = len(group)
        hyper = tree_stack([specs[i].hyper for i in group])
        states = [tree_stack([specs[i].init(t) for i in group])
                  for t in init_tables]
        streams = [BatchIterator(_window_source(w), mesh=runner.mesh)
                   for w in train_windows]
        active = np.ones(k, dtype=bool)
        rung_scores: Dict[int, List[float]] = {i: [] for i in group}
        halted = False
        metric_name = self.metric or "score"
        _, after_cbs = split_callbacks(self.callbacks)

        for rung_no, (start, end) in enumerate(rungs):
            if not active.any():
                # every trial is frozen: later rungs would change no state
                # and record no scores — the stopping rule's whole point
                # is to skip this compute
                break
            mask = jnp.asarray(active)
            for f, stream in enumerate(streams):
                states[f] = runner.run_stacked_epochs(
                    stream, states[f], hyper, spec0.local_step, end,
                    combine=spec0.combine, update=spec0.update,
                    active=mask, chunks_per_epoch=self.chunks_per_epoch,
                    start_epoch=start, callbacks=self.callbacks)
            fold_scores = np.stack([
                np.asarray(spec0.score(val_tables[f], states[f], schedule),
                           np.float64).reshape(k)
                for f in range(len(val_tables))
            ])                                     # (folds, K)
            scores_now = fold_scores.mean(axis=0)  # (K,)
            for j, i in enumerate(group):
                if active[j]:
                    rung_scores[i].append(float(scores_now[j]))
            if after_cbs:
                evals = tuple(
                    EvalEntry(i, metric_name, float(scores_now[j]), True)
                    for j, i in enumerate(group) if active[j])
                env = CallbackEnv(
                    epoch=end, begin_epoch=start, end_epoch=end,
                    round=end * self.chunks_per_epoch, state=states[0],
                    hyper=hyper, active=active.copy(), unit=unit_no,
                    trial_ids=tuple(group), evals=evals)
                try:
                    swaps = fire_callbacks(after_cbs, env)
                except EarlyStopException:
                    halted = True
                    break
                hyper = self._apply_search_swaps(swaps, hyper)
            if self.early_stop is not None and rung_no < len(rungs) - 1:
                self._apply_median_rule(group, active, rung_no, rung_scores,
                                        done_info)

        final_states = tree_unstack(states[0])
        for j, i in enumerate(group):
            done_states[i] = final_states[j]
            done_info[i] = {
                "score": rung_scores[i][-1],
                "rung_scores": rung_scores[i],
                "stopped": not bool(active[j]) or
                           (halted and len(rung_scores[i]) < len(rungs)),
            }
        return halted

    @staticmethod
    def _apply_search_swaps(swaps: dict, hyper: Any) -> Any:
        """Fold a rung-boundary callback's carry swaps into the search.
        Only ``hyper`` may be steered here — the stopping rule owns the
        active mask and the driver owns trial state."""
        if not swaps:
            return hyper
        refused = set(swaps) - {"hyper"}
        if refused:
            raise ValueError(
                f"search-level callbacks may only swap 'hyper' at rung "
                f"boundaries, got {sorted(refused)} — state/active are "
                f"owned by the search driver")
        return swaps["hyper"]

    # ------------------------------------------------------------------ #
    # ASHA driver: slot table + pending-queue backfill (no cohort barrier)
    # ------------------------------------------------------------------ #
    def _run_asha(self, table: Any, specs: List[TrialSpec],
                  runner: DistributedRunner, schedule: CollectiveSchedule,
                  train_windows: List[np.ndarray], init_tables: List[Any],
                  val_tables: List[Any], resume: bool) -> SearchResult:
        """Execute the search under :class:`AsyncSuccessiveHalving`.

        A fixed table of execution slots advances concurrently-resident
        trials; whenever any trial reaches its next rung the segment ends,
        the trial reports, and the decision is immediate — stopped/finished
        trials free their slot, which the next ``admit`` backfills from the
        pending queue (the ``serve.SlotScheduler`` move).  With stacked
        execution the slot table IS the stacked carry: lane ``j`` hosts
        slot ``j``'s trial, per-lane ``round_offsets`` give every admission
        a private round origin, and the (K,) active mask covers freed lanes
        — one compiled epoch serves the whole slot table throughout the
        search, no recompiles.  Sequential execution drives the *same*
        :class:`AshaScheduler` with one K=1 segment per occupied slot, so
        both modes make the identical promotion sequence by construction.

        With ``ckpt_dir`` every decision batch snapshots {terminal trials,
        live slot states, scheduler control state} atomically; an
        interrupted search resumes rung-for-rung bit-identically.
        """
        rule = self.early_stop
        n = len(specs)
        slots = min(rule.slots or 8, n)
        # lanes must share one compiled structure; ragged stack keys fall
        # back to sequential slots (same scheduler, same decisions)
        stacked = (self.execution in ("auto", "stacked")
                   and len({s.stack_key for s in specs}) == 1)
        chunks = self.chunks_per_epoch
        folds = len(init_tables)
        metric_name = self.metric or "score"
        _, after_cbs = split_callbacks(self.callbacks)
        spec0 = specs[0]

        ckpt = (SearchCheckpointer(self.ckpt_dir, self._fingerprint(table))
                if self.ckpt_dir else None)
        done_states: Dict[int, Any] = {}
        done_info: Dict[int, Dict[str, Any]] = {}
        units_done = 0
        sched = AshaScheduler(rule, n, self.num_epochs, slots)
        live: Dict[int, List[Any]] = {}

        if resume:
            if ckpt is None:
                raise ValueError("resume=True requires ckpt_dir")
            snap = ckpt.resume(lambda i: specs[i].init(init_tables[0]),
                               with_live=True)
            if snap is not None:
                done_states, done_info, units_done, live, extra = snap
                if not extra or "asha" not in extra:
                    raise ValueError(
                        "checkpoint carries no ASHA scheduler state — was it "
                        "written by a median-rule search?")
                sched = AshaScheduler.from_state_dict(
                    rule, self.num_epochs, extra["asha"])

        streams = [BatchIterator(_window_source(w), mesh=runner.mesh)
                   for w in train_windows]

        hyper = states = None
        offsets = np.zeros(slots, np.int32)
        active = np.zeros(slots, bool)
        if stacked:
            # lane tensors; on resume, occupied lanes restore from `live`
            hyper = tree_stack([
                specs[t].hyper if (t := sched.slots[j]) is not None
                else specs[0].hyper for j in range(slots)])
            states = [tree_stack([
                live[t][f] if (t := sched.slots[j]) is not None
                else specs[0].init(init_tables[f]) for j in range(slots)])
                for f in range(folds)]
            for j, t in sched.occupied():
                offsets[j] = (sched.global_epoch - sched.local_epoch[t]) \
                    * chunks
                active[j] = True

        halted = False
        while not sched.finished():
            for j, t in sched.admit():
                if stacked:
                    for f in range(folds):
                        states[f] = _tree_set(states[f], j,
                                              specs[t].init(init_tables[f]))
                    hyper = _tree_set(hyper, j, specs[t].hyper)
                    # admission at an epoch boundary: the offset is a
                    # multiple of chunks, so the chunk phase (r % chunks)
                    # matches a solo run exactly
                    offsets[j] = sched.global_epoch * chunks
                    active[j] = True
                else:
                    live[t] = [specs[t].init(init_tables[f])
                               for f in range(folds)]
            if not sched.occupied():
                break  # budget exhausted with trials still pending
            delta = sched.tick_size()
            g0 = sched.global_epoch
            if stacked:
                mask = jnp.asarray(active)
                offs = jnp.asarray(offsets)
                for f, stream in enumerate(streams):
                    states[f] = runner.run_stacked_epochs(
                        stream, states[f], hyper, spec0.local_step,
                        g0 + delta, combine=spec0.combine,
                        update=spec0.update, active=mask,
                        chunks_per_epoch=chunks, start_epoch=g0,
                        round_offsets=offs)
            else:
                for j, t in sched.occupied():
                    le = sched.local_epoch[t]
                    spec = specs[t]
                    h1 = tree_stack([spec.hyper])
                    for f, stream in enumerate(streams):
                        st = runner.run_stacked_epochs(
                            stream, tree_stack([live[t][f]]), h1,
                            spec.local_step, le + delta,
                            combine=spec.combine, update=spec.update,
                            chunks_per_epoch=chunks, start_epoch=le)
                        live[t][f] = tree_unstack(st)[0]
            sched.advance(delta)
            due = sched.due()
            if not due:
                continue  # defensive: tick_size targets the nearest rung
            if stacked:
                fold_scores = np.stack([
                    np.asarray(spec0.score(val_tables[f], states[f],
                                           schedule),
                               np.float64).reshape(slots)
                    for f in range(folds)])
                lane_scores = fold_scores.mean(axis=0)
                due_scores = [float(lane_scores[j]) for j, _ in due]
            else:
                due_scores = []
                for j, t in due:
                    per_fold = [float(np.asarray(
                        specs[t].score(val_tables[f],
                                       tree_stack([live[t][f]]), schedule),
                        np.float64).reshape(1)[0]) for f in range(folds)]
                    due_scores.append(float(np.mean(per_fold)))

            newly_terminal: List[int] = []
            for (j, t), s in zip(due, due_scores):
                if sched.report(t, s):
                    continue  # promoted — keeps its slot
                if stacked:
                    done_states[t] = jax.tree.map(lambda x: x[j], states[0])
                    active[j] = False
                else:
                    done_states[t] = live.pop(t)[0]
                hist = _asha_history(sched, t)
                done_info[t] = {
                    "score": hist[-1],
                    "rung_scores": hist,
                    "stopped": sched.terminal[t] == "stopped",
                }
                newly_terminal.append(t)

            if after_cbs:
                evals = tuple(EvalEntry(t, metric_name, s, True)
                              for (_, t), s in zip(due, due_scores))
                env = CallbackEnv(
                    epoch=sched.global_epoch, begin_epoch=0,
                    end_epoch=self.num_epochs,
                    round=sched.global_epoch * chunks,
                    state=states[0] if stacked else None,
                    hyper=hyper if stacked else None,
                    active=active.copy() if stacked else None,
                    unit=units_done, trial_ids=tuple(t for _, t in due),
                    evals=evals)
                try:
                    swaps = fire_callbacks(after_cbs, env)
                except EarlyStopException:
                    halted = True
                    swaps = {}
                if swaps:
                    if not stacked:
                        raise ValueError(
                            "hyper steering under ASHA requires stacked "
                            "execution — sequential slots have no shared "
                            "hyper tree")
                    hyper = self._apply_search_swaps(swaps, hyper)

            units_done += 1
            if ckpt is not None:
                if stacked:
                    live = {t: [jax.tree.map(lambda x: x[j], states[f])
                                for f in range(folds)]
                            for j, t in sched.occupied()}
                ckpt.save(done_states, done_info, units_done, live=live,
                          extra={"asha": sched.state_dict()})
            if self.unit_callback is not None:
                self.unit_callback(len(done_info), newly_terminal)
            if halted:
                break

        if halted:
            # drain: running trials end as stopped with their last rung
            # score; trials that never reached a rung are simply unreported
            for j, t in sched.occupied():
                hist = _asha_history(sched, t)
                if not hist:
                    continue
                done_states[t] = (jax.tree.map(lambda x: x[j], states[0])
                                  if stacked else live[t][0])
                done_info[t] = {"score": hist[-1], "rung_scores": hist,
                                "stopped": True}

        trials = [
            TrialResult(index=i, config=dict(self.configs[i]),
                        score=done_info[i]["score"],
                        rung_scores=list(done_info[i]["rung_scores"]),
                        state=done_states[i],
                        stopped=bool(done_info[i]["stopped"]),
                        model=(specs[i].finalize(done_states[i])
                               if specs[i].finalize else None))
            for i in sorted(done_info)
        ]
        return SearchResult(trials=trials)

    def _apply_median_rule(self, group: List[int], active: np.ndarray,
                           rung_no: int,
                           rung_scores: Dict[int, List[float]],
                           done_info: Dict[int, Dict[str, Any]]) -> None:
        """Freeze every active trial scoring below the median of its peers
        at this rung (peers: completed trials with a score at the same
        rung, plus the rest of the group)."""
        def score_at(history: Sequence[float]) -> Optional[float]:
            return history[rung_no] if len(history) > rung_no else None

        peer_pool = {
            i: score_at(info["rung_scores"])
            for i, info in done_info.items()
        }
        peer_pool.update({i: score_at(rung_scores[i])
                          for j, i in enumerate(group) if active[j]})
        for j, i in enumerate(group):
            if not active[j]:
                continue
            mine = peer_pool[i]
            peers = [s for t, s in sorted(peer_pool.items())
                     if t != i and s is not None]
            if mine is not None and self.early_stop.stop(rung_no, mine, peers):
                active[j] = False
