"""Model search: config enumeration, median early stopping, and the driver.

:func:`grid` and :func:`sample` enumerate candidate configurations
deterministically (the paper's MLbase motivation: train many candidate
``Parameters`` and keep the best).  :class:`ModelSearch` executes them over
a row-partitioned table:

  * **folds** — k-fold or holdout splits from :mod:`repro.tune.cv`,
    expressed as row-index views (train view streamed, validation view
    scored in place);
  * **execution** — ``"stacked"`` vmaps every same-shape group of trials
    over a leading trial axis so one jitted round advances the whole
    group (``DistributedRunner.run_stacked_epochs``), ``"sequential"``
    runs one trial per unit, ``"auto"`` = stacked where shapes allow;
  * **training** — always the PR-2 streaming path: each epoch pulls the
    train view's window from a :class:`repro.data.pipeline.BatchIterator`
    and scans ``chunks_per_epoch`` minibatch rounds over it, so searches
    inherit checkpoint/resume and the collective-schedule knob unchanged;
  * **early stopping** — the median rule (:class:`MedianStoppingRule`):
    after each rung, trials scoring below the median of their peers at
    the same rung are frozen (masked in stacked groups, skipped in
    sequential units);
  * **fault tolerance** — with ``ckpt_dir`` the search snapshots after
    every completed unit and ``run(..., resume=True)`` continues
    trial-for-trial after a kill.

Scores are **higher-is-better** throughout (loss metrics are negated).
Everything is a pure function of ``(configs, seed, data)`` — the
determinism ``tests/test_tune_determinism.py`` pins across collective
schedules and execution modes.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.collectives import CollectiveSchedule
from repro.core.runner import DistributedRunner
from repro.data.pipeline import BatchIterator
from repro.tune.cv import KFold, fold_view, holdout_split, take_rows
from repro.tune.trials import (
    SearchCheckpointer,
    TrialSpec,
    fingerprint,
    group_trials,
    tree_stack,
    tree_unstack,
)

__all__ = [
    "grid",
    "sample",
    "MedianStoppingRule",
    "TrialResult",
    "SearchResult",
    "ModelSearch",
]


# --------------------------------------------------------------------------- #
# config enumeration
# --------------------------------------------------------------------------- #
def _is_range(v: Any) -> bool:
    return (isinstance(v, tuple) and len(v) == 3
            and v[0] in ("uniform", "loguniform"))


def grid(space: Dict[str, Sequence[Any]]) -> List[Dict[str, Any]]:
    """Cartesian product of a ``{param: [values…]}`` space, in sorted-key
    order — a pure function of the space, so every run of the same grid
    enumerates trials identically."""
    for k, v in space.items():
        if _is_range(v):
            raise ValueError(
                f"{k}={v!r} is a continuous range — ranges are for "
                f"sample(); a grid needs an explicit value list")
    keys = sorted(space)
    return [dict(zip(keys, vals))
            for vals in itertools.product(*(space[k] for k in keys))]


def sample(space: Dict[str, Any], num_samples: int, seed: int = 0
           ) -> List[Dict[str, Any]]:
    """Random search: ``num_samples`` deterministic draws from ``space``.

    Per key, a list/tuple of values is sampled uniformly; the 3-tuples
    ``("uniform", lo, hi)`` and ``("loguniform", lo, hi)`` draw continuous
    values.  Seeded — the same ``(space, num_samples, seed)`` always
    yields the same trial list, in the same order.
    """
    rng = np.random.default_rng(seed)
    configs = []
    for _ in range(num_samples):
        cfg: Dict[str, Any] = {}
        for k in sorted(space):
            v = space[k]
            if _is_range(v):
                lo, hi = float(v[1]), float(v[2])
                if lo > hi:
                    raise ValueError(f"{k}: range lower bound {lo} exceeds "
                                     f"upper bound {hi}")
                if v[0] == "loguniform":
                    if lo <= 0:
                        raise ValueError(
                            f"{k}: loguniform bounds must be positive, got "
                            f"[{lo}, {hi}]")
                    cfg[k] = float(np.exp(rng.uniform(np.log(lo), np.log(hi))))
                else:
                    cfg[k] = float(rng.uniform(lo, hi))
            else:
                options = list(v)
                choice = options[int(rng.integers(len(options)))]
                cfg[k] = choice.item() if hasattr(choice, "item") else choice
        configs.append(cfg)
    return configs


# --------------------------------------------------------------------------- #
# median early stopping
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class MedianStoppingRule:
    """Stop a trial whose rung score falls below the median of its peers.

    After rung ``r`` (0-indexed; rungs before ``min_rungs`` are always
    survived), a trial stops when at least ``min_trials`` *other* trials
    have recorded a score at the same rung and the trial's score is
    strictly below their median.  With sequential execution the
    comparators are previously-run trials (the classic asynchronous
    rule); with stacked execution the whole group reaches the rung
    together, so the comparison is synchronous.  Stopped trials keep
    their last score and their state freezes (masked in the stacked
    carry) — the round structure stays static, so no recompilation.
    """

    min_rungs: int = 1
    min_trials: int = 3

    def stop(self, rung: int, score: float, peer_scores: Sequence[float]) -> bool:
        if rung < self.min_rungs:
            return False
        if len(peer_scores) < self.min_trials:
            return False
        return score < float(np.median(np.asarray(peer_scores, np.float64)))


# --------------------------------------------------------------------------- #
# results
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class TrialResult:
    """Outcome of one trial: its config, the (higher-is-better) validation
    score averaged over folds, the per-rung score history, the final
    trained state of fold 0, and whether the median rule stopped it."""

    index: int
    config: Dict[str, Any]
    score: float
    rung_scores: List[float]
    state: Any
    stopped: bool = False
    # the trial's trained Model (spec.finalize(state)); None for custom
    # specs without a finalizer
    model: Any = None


@dataclasses.dataclass
class SearchResult:
    """All trials in enumeration order, plus the winner."""

    trials: List[TrialResult]

    @property
    def best(self) -> TrialResult:
        """Highest score; ties break to the lowest trial index, so the
        winner is deterministic under fp-equal scores."""
        return max(self.trials, key=lambda t: (t.score, -t.index))

    @property
    def scores(self) -> List[float]:
        return [t.score for t in self.trials]


# --------------------------------------------------------------------------- #
# the driver
# --------------------------------------------------------------------------- #
def _builtin_builder(algorithm: str, metric: Optional[str]
                     ) -> Callable[[Dict[str, Any]], TrialSpec]:
    """Resolve a registered algorithm name to its trial-spec builder
    (imported lazily: core algorithms must not import tune at load)."""
    if algorithm == "logreg":
        from repro.core.algorithms.logistic_regression import \
            LogisticRegressionAlgorithm as A
        return lambda cfg: A.trial_spec(cfg, metric=metric or "accuracy")
    if algorithm == "kmeans":
        from repro.core.algorithms.kmeans import KMeans as A
        return lambda cfg: A.trial_spec(cfg, metric=metric or "silhouette")
    raise ValueError(
        f"unknown algorithm {algorithm!r} — pass 'logreg', 'kmeans', or a "
        f"spec-builder callable")


def _window_source(window: np.ndarray) -> Callable[[int], Dict[str, np.ndarray]]:
    """Stream source for a fold's train view: every epoch's window is the
    view's rows (a pure function of the step — seekable, resume-exact)."""
    def source(step: int) -> Dict[str, np.ndarray]:
        return {"data": window}

    return source


@dataclasses.dataclass
class ModelSearch:
    """Grid/random model search over one algorithm and one table.

    Parameters
    ----------
    algorithm:
        ``"logreg"``, ``"kmeans"``, a callable ``config -> TrialSpec``, or
        a :class:`repro.pipeline.Pipeline` instance — then ``run`` takes
        the *raw* table, config keys address nested stages
        (``"tfidf.top"``, ``"logreg.learning_rate"``; bare keys go to the
        estimator), and featurizers are fit per train fold only (no
        validation leakage).  Trials sharing a featurizer config and the
        estimator's stack key device-stack exactly as before.
    configs:
        The candidate list (:func:`grid` / :func:`sample` output).
    num_epochs / chunks_per_epoch:
        Streaming-epoch budget per trial: each epoch scans the train
        window in ``chunks_per_epoch`` minibatch rounds.
    folds:
        ``k >= 2`` for k-fold CV (scores averaged over folds); ``None``
        for a single ``val_fraction`` holdout split.
    execution:
        ``"auto"`` (stack same-shape groups) | ``"stacked"`` |
        ``"sequential"``.
    early_stop / rung_epochs:
        Optional :class:`MedianStoppingRule`, applied every
        ``rung_epochs`` epochs (default 1 when a rule is set, else one
        rung spanning the whole budget).
    ckpt_dir:
        Search-level checkpoint directory (snapshot after every completed
        unit); ``run(resume=True)`` continues from it.
    """

    algorithm: Union[str, Callable[[Dict[str, Any]], TrialSpec]]
    configs: List[Dict[str, Any]]
    num_epochs: int = 8
    chunks_per_epoch: int = 1
    folds: Optional[int] = None
    val_fraction: float = 0.25
    metric: Optional[str] = None
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE
    execution: str = "auto"
    seed: int = 0
    early_stop: Optional[MedianStoppingRule] = None
    rung_epochs: Optional[int] = None
    ckpt_dir: Optional[str] = None
    # observer called after every completed (and checkpointed) unit with
    # (units_done, trial_indices) — progress lines, fault injection in the
    # kill-and-resume tests.  Not part of the search fingerprint.
    unit_callback: Optional[Callable[[int, List[int]], None]] = None

    def __post_init__(self) -> None:
        if not self.configs:
            raise ValueError("configs must not be empty")
        if self.folds is not None and self.folds < 2:
            raise ValueError(f"folds must be >= 2, got {self.folds}")

    # ------------------------------------------------------------------ #
    def _rungs(self) -> List[Tuple[int, int]]:
        """(start_epoch, end_epoch) segments: one per rung when early
        stopping is on, else a single segment spanning the budget."""
        step = self.rung_epochs or (1 if self.early_stop else self.num_epochs)
        edges = list(range(0, self.num_epochs, step)) + [self.num_epochs]
        return [(a, b) for a, b in zip(edges, edges[1:]) if b > a]

    def _fingerprint(self, table: Any, pipeline: Any = None) -> str:
        """Identity of this search INCLUDING the dataset shape — a resumed
        search against a different table must refuse, not silently mix
        scores computed on different data."""
        name = (self.algorithm if isinstance(self.algorithm, str)
                else getattr(self.algorithm, "__name__", "custom"))
        if pipeline is not None:
            name = {"pipeline": pipeline.describe()}
        return fingerprint({
            "algorithm": name, "configs": self.configs,
            "num_epochs": self.num_epochs,
            "chunks_per_epoch": self.chunks_per_epoch,
            "folds": self.folds, "val_fraction": self.val_fraction,
            "metric": self.metric,
            "schedule": CollectiveSchedule.parse(self.schedule).value,
            "execution": self.execution, "seed": self.seed,
            "rungs": self._rungs(),
            "early_stop": (None if self.early_stop is None else
                           [self.early_stop.min_rungs,
                            self.early_stop.min_trials]),
            "data_shape": [int(table.num_rows), int(table.num_cols)],
        })

    # ------------------------------------------------------------------ #
    def run(self, table: Any, resume: bool = False) -> SearchResult:
        """Execute the search over ``table`` and return every trial.

        The table is split into folds; each unit's trials stream the
        fold's train window for ``num_epochs`` epochs and are scored on
        the fold's validation view with the configured schedule; scores
        average over folds.  With ``resume=True`` (and ``ckpt_dir``),
        completed units restore from the newest snapshot and execution
        continues at the first unfinished unit.
        """
        from repro.pipeline import Pipeline

        if isinstance(self.algorithm, Pipeline):
            return self._run_pipeline(table, resume)

        schedule = CollectiveSchedule.parse(self.schedule)
        builder = (self.algorithm if callable(self.algorithm)
                   else _builtin_builder(self.algorithm, self.metric))
        specs = [builder(dict(cfg)) for cfg in self.configs]

        n = table.num_rows
        if self.folds:
            splits = list(KFold(n, self.folds, self.seed).splits())
        else:
            splits = [holdout_split(n, self.val_fraction, self.seed)]

        # layout: keep the table's mesh whenever every train view can
        # fill at least one (shards x chunks) window, else fall back to an
        # emulated single shard.  MLI partitions are equal-sized by
        # construction, so each train window is trimmed (deterministically,
        # from the tail of the sorted index) to the largest multiple of
        # shards * chunks_per_epoch — at most shards*chunks - 1 rows per
        # fold sit out of training; validation views are never trimmed.
        mesh, shards = table.mesh, table.num_shards
        unit = shards * self.chunks_per_epoch
        if any(len(tr) < unit for tr, _ in splits):
            mesh, shards = None, 1
            unit = self.chunks_per_epoch
        runner = DistributedRunner(mesh=mesh, num_shards=shards,
                                   schedule=schedule)

        host_rows = np.asarray(table.data)
        train_idx = [tr[: len(tr) - len(tr) % unit] for tr, _ in splits]
        if any(len(tr) == 0 for tr in train_idx):
            raise ValueError(
                f"a train split is smaller than chunks_per_epoch="
                f"{self.chunks_per_epoch} — nothing left to train on")
        # one host copy per fold, shared by every execution unit
        train_windows = [np.ascontiguousarray(host_rows[tr])
                         for tr in train_idx]
        init_tables = [fold_view(table, tr) for tr in train_idx]
        val_tables = [fold_view(table, va) for _, va in splits]

        groups = group_trials(specs, self.execution)
        rungs = self._rungs()

        done_states: Dict[int, Any] = {}
        done_info: Dict[int, Dict[str, Any]] = {}
        units_done = 0
        ckpt = (SearchCheckpointer(self.ckpt_dir, self._fingerprint(table))
                if self.ckpt_dir else None)
        if resume:
            if ckpt is None:
                raise ValueError("resume=True requires ckpt_dir")
            snap = ckpt.resume(lambda i: specs[i].init(init_tables[0]))
            if snap is not None:
                done_states, done_info, units_done = snap

        for unit_no, group in enumerate(groups):
            if unit_no < units_done:
                continue  # restored from the snapshot
            self._run_unit(runner, specs, group, train_windows,
                           init_tables, val_tables, rungs, schedule,
                           done_states, done_info)
            units_done = unit_no + 1
            if ckpt is not None:
                ckpt.save(done_states, done_info, units_done)
            if self.unit_callback is not None:
                self.unit_callback(units_done, list(group))

        trials = [
            TrialResult(index=i, config=dict(self.configs[i]),
                        score=done_info[i]["score"],
                        rung_scores=list(done_info[i]["rung_scores"]),
                        state=done_states[i],
                        stopped=bool(done_info[i]["stopped"]),
                        model=(specs[i].finalize(done_states[i])
                               if specs[i].finalize else None))
            for i in sorted(done_info)
        ]
        return SearchResult(trials=trials)

    # ------------------------------------------------------------------ #
    # pipeline search: featurizers fit per train fold, nested stage params
    # ------------------------------------------------------------------ #
    def _run_pipeline(self, table: Any, resume: bool = False) -> SearchResult:
        """Search a :class:`repro.pipeline.Pipeline` over a *raw* table.

        Each config splits into transformer overrides (``"tfidf.top"``)
        and estimator params; trials sharing a featurizer config share one
        featurization, and that featurization is fit on the fold's TRAIN
        view only — validation rows are transformed with the train-fitted
        statistics, never refit (the leakage rule the fitted-transformer
        redesign exists to enforce).  Execution, stacking, early stopping,
        and checkpoint/resume are the standard :meth:`run` machinery.
        """
        pipeline = self.algorithm
        est = pipeline.estimator
        if est is None or not hasattr(type(est), "trial_spec"):
            raise ValueError(
                "pipeline search needs a terminal estimator with a "
                "trial_spec (Searchable)")
        schedule = CollectiveSchedule.parse(self.schedule)
        base_over = est.overrides()
        metric_kw = {"metric": self.metric} if self.metric else {}

        split_cfgs = [pipeline.split_config(dict(c)) for c in self.configs]
        feat_keys = [json.dumps(fc, sort_keys=True, default=str)
                     for fc, _ in split_cfgs]
        specs: List[TrialSpec] = []
        for (fc, ec), fk in zip(split_cfgs, feat_keys):
            spec = type(est).trial_spec({**base_over, **ec}, **metric_kw)
            prev = (spec.stack_key if isinstance(spec.stack_key, tuple)
                    else (spec.stack_key,))
            specs.append(dataclasses.replace(spec, stack_key=(fk,) + prev))

        n = table.num_rows
        if self.folds:
            splits = list(KFold(n, self.folds, self.seed).splits())
        else:
            splits = [holdout_split(n, self.val_fraction, self.seed)]

        # layout mirrors run(): keep the pipeline's mesh when every train
        # window fills at least one (shards x chunks) unit, else emulate
        mesh = pipeline.mesh
        shards = (DistributedRunner(mesh=mesh).num_shards
                  if mesh is not None else (pipeline.num_shards or 1))
        unit = shards * self.chunks_per_epoch
        if any(len(tr) < unit for tr, _ in splits):
            mesh, shards = None, 1
            unit = self.chunks_per_epoch
        runner = DistributedRunner(mesh=mesh, num_shards=shards,
                                   schedule=schedule)
        train_idx = [tr[: len(tr) - len(tr) % unit] for tr, _ in splits]
        if any(len(tr) == 0 for tr in train_idx):
            raise ValueError(
                f"a train split is smaller than chunks_per_epoch="
                f"{self.chunks_per_epoch} — nothing left to train on")
        val_idx = [va for _, va in splits]

        # raw fold views are shared by every featurizer config — collect
        # the host rows once instead of re-gathering per config per fold
        raw_views: Dict[int, Any] = {}
        from repro.core.mltable import MLTable, _chunk

        host_rows = table.collect() if isinstance(table, MLTable) else None

        def raw_view(key: int, idx) -> Any:
            if key not in raw_views:
                if host_rows is not None:
                    raw_views[key] = MLTable(
                        _chunk([host_rows[int(i)] for i in idx],
                               table.num_partitions), table.schema)
                else:
                    raw_views[key] = take_rows(table, idx)
            return raw_views[key]

        # one featurization per distinct transformer config, lazy + cached:
        # (train windows, init tables, val tables) per fold, featurizers
        # fit on the train view only
        feat_cache: Dict[str, Tuple[List[np.ndarray], List[Any], List[Any]]] = {}

        def featurized(trial: int):
            fk = feat_keys[trial]
            if fk not in feat_cache:
                fp = pipeline.with_stage_config(split_cfgs[trial][0])
                fp.mesh, fp.num_shards = mesh, shards
                windows, inits, vals = [], [], []
                for f, (tr, va) in enumerate(zip(train_idx, val_idx)):
                    fitted, ftab = fp._fit_stages(raw_view(2 * f, tr))
                    windows.append(np.ascontiguousarray(
                        np.asarray(ftab.data)))
                    inits.append(ftab)
                    vals.append(fp._transform_stages(
                        fitted, raw_view(2 * f + 1, va), mesh=None,
                        num_shards=1))
                feat_cache[fk] = (windows, inits, vals)
            return feat_cache[fk]

        groups = group_trials(specs, self.execution)
        rungs = self._rungs()

        done_states: Dict[int, Any] = {}
        done_info: Dict[int, Dict[str, Any]] = {}
        units_done = 0
        ckpt = (SearchCheckpointer(self.ckpt_dir,
                                   self._fingerprint(table, pipeline))
                if self.ckpt_dir else None)
        if resume:
            if ckpt is None:
                raise ValueError("resume=True requires ckpt_dir")
            snap = ckpt.resume(lambda i: specs[i].init(featurized(i)[1][0]))
            if snap is not None:
                done_states, done_info, units_done = snap

        for unit_no, group in enumerate(groups):
            if unit_no < units_done:
                continue  # restored from the snapshot
            windows, inits, vals = featurized(group[0])
            self._run_unit(runner, specs, group, windows, inits, vals,
                           rungs, schedule, done_states, done_info)
            units_done = unit_no + 1
            if ckpt is not None:
                ckpt.save(done_states, done_info, units_done)
            if self.unit_callback is not None:
                self.unit_callback(units_done, list(group))

        trials = [
            TrialResult(index=i, config=dict(self.configs[i]),
                        score=done_info[i]["score"],
                        rung_scores=list(done_info[i]["rung_scores"]),
                        state=done_states[i],
                        stopped=bool(done_info[i]["stopped"]),
                        model=(specs[i].finalize(done_states[i])
                               if specs[i].finalize else None))
            for i in sorted(done_info)
        ]
        return SearchResult(trials=trials)

    # ------------------------------------------------------------------ #
    def _run_unit(self, runner: DistributedRunner, specs: List[TrialSpec],
                  group: List[int], train_windows: List[np.ndarray],
                  init_tables: List[Any],
                  val_tables: List[Any], rungs: List[Tuple[int, int]],
                  schedule: CollectiveSchedule,
                  done_states: Dict[int, Any],
                  done_info: Dict[int, Dict[str, Any]]) -> None:
        """Advance one execution unit (a stacked group or a single trial)
        through every rung of every fold, then record its trials."""
        spec0 = specs[group[0]]
        k = len(group)
        hyper = tree_stack([specs[i].hyper for i in group])
        states = [tree_stack([specs[i].init(t) for i in group])
                  for t in init_tables]
        streams = [BatchIterator(_window_source(w), mesh=runner.mesh)
                   for w in train_windows]
        active = np.ones(k, dtype=bool)
        rung_scores: Dict[int, List[float]] = {i: [] for i in group}

        for rung_no, (start, end) in enumerate(rungs):
            if not active.any():
                # every trial is frozen: later rungs would change no state
                # and record no scores — the stopping rule's whole point
                # is to skip this compute
                break
            mask = jnp.asarray(active)
            for f, stream in enumerate(streams):
                states[f] = runner.run_stacked_epochs(
                    stream, states[f], hyper, spec0.local_step, end,
                    combine=spec0.combine, update=spec0.update,
                    active=mask, chunks_per_epoch=self.chunks_per_epoch,
                    start_epoch=start)
            fold_scores = np.stack([
                np.asarray(spec0.score(val_tables[f], states[f], schedule),
                           np.float64).reshape(k)
                for f in range(len(val_tables))
            ])                                     # (folds, K)
            scores_now = fold_scores.mean(axis=0)  # (K,)
            for j, i in enumerate(group):
                if active[j]:
                    rung_scores[i].append(float(scores_now[j]))
            if self.early_stop is not None and rung_no < len(rungs) - 1:
                self._apply_median_rule(group, active, rung_no, rung_scores,
                                        done_info)

        final_states = tree_unstack(states[0])
        for j, i in enumerate(group):
            done_states[i] = final_states[j]
            done_info[i] = {
                "score": rung_scores[i][-1],
                "rung_scores": rung_scores[i],
                "stopped": not bool(active[j]),
            }

    def _apply_median_rule(self, group: List[int], active: np.ndarray,
                           rung_no: int,
                           rung_scores: Dict[int, List[float]],
                           done_info: Dict[int, Dict[str, Any]]) -> None:
        """Freeze every active trial scoring below the median of its peers
        at this rung (peers: completed trials with a score at the same
        rung, plus the rest of the group)."""
        def score_at(history: Sequence[float]) -> Optional[float]:
            return history[rung_no] if len(history) > rung_no else None

        peer_pool = {
            i: score_at(info["rung_scores"])
            for i, info in done_info.items()
        }
        peer_pool.update({i: score_at(rung_scores[i])
                          for j, i in enumerate(group) if active[j]})
        for j, i in enumerate(group):
            if not active[j]:
                continue
            mine = peer_pool[i]
            peers = [s for t, s in sorted(peer_pool.items())
                     if t != i and s is not None]
            if mine is not None and self.early_stop.stop(rung_no, mine, peers):
                active[j] = False
