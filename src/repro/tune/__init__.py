"""Parallel model search over MLI algorithms (the MLbase end goal).

The paper positions MLI as the API layer of MLbase, whose purpose is
*model search*: train many candidate configurations and keep the best.
This package is that layer for this repo:

  * :mod:`repro.tune.search` — grid / random config enumeration, the
    median early-stopping rule, asynchronous successive halving (ASHA),
    and the :class:`ModelSearch` driver;
  * :mod:`repro.tune.trials` — trial execution: device-stacked groups
    (K same-shape trials vmapped over a leading axis, one jitted round
    advancing all K) with a sequential fallback for ragged configs, plus
    mid-search checkpoint/resume;
  * :mod:`repro.tune.callback` — LightGBM-style training/search hooks
    (:func:`early_stopping`, :func:`record_evaluation`,
    :func:`hyper_schedule`) fired host-side between compiled epochs;
  * :mod:`repro.tune.cv` — k-fold and holdout splitters as row-index
    views over `MLNumericTable` / `BatchIterator`.

Scoring lives in :mod:`repro.eval.metrics`.  See ``docs/architecture.md``
("Model search") and ``examples/model_search.py``.
"""
from repro.tune.callback import (  # noqa: F401
    CallbackEnv,
    EarlyStopException,
    EvalEntry,
    early_stopping,
    hyper_schedule,
    record_evaluation,
)
from repro.tune.cv import KFold, fold_view, holdout_split  # noqa: F401
from repro.tune.search import (  # noqa: F401
    AshaScheduler,
    AsyncSuccessiveHalving,
    MedianStoppingRule,
    ModelSearch,
    SearchResult,
    TrialResult,
    grid,
    sample,
)
from repro.tune.trials import TrialSpec, tree_stack, tree_unstack  # noqa: F401

__all__ = [
    "KFold",
    "fold_view",
    "holdout_split",
    "grid",
    "sample",
    "AshaScheduler",
    "AsyncSuccessiveHalving",
    "MedianStoppingRule",
    "ModelSearch",
    "SearchResult",
    "TrialResult",
    "TrialSpec",
    "tree_stack",
    "tree_unstack",
    "CallbackEnv",
    "EarlyStopException",
    "EvalEntry",
    "early_stopping",
    "hyper_schedule",
    "record_evaluation",
]
