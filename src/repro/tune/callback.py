"""LightGBM-style callback protocol for training and search loops.

The training loops (:meth:`repro.core.runner.DistributedRunner.run_epochs`
and ``run_stacked_epochs``) and the search driver
(:class:`repro.tune.search.ModelSearch`) expose *host-side hook points
between compiled epochs*: after each jitted epoch scan returns, every
registered callback is called with a frozen :class:`CallbackEnv` snapshot.
Nothing a callback does changes the compiled round structure — the (K,)
active mask stays the only device-visible control — so hooks cost zero
recompiles.  What a callback CAN do:

  * **observe** — metric streaming, progress lines, custom logging
    (:func:`record_evaluation` appends per-rung snapshots to a
    :class:`repro.eval.metrics.MetricHistory`);
  * **stop** — raise :class:`EarlyStopException` to end the loop early
    (:func:`early_stopping` does this when the best score stops
    improving); the loop still writes its tail checkpoint, so a stopped
    run resumes like any other;
  * **steer** — return a ``{"state": ...}`` / ``{"hyper": ...}`` dict to
    swap the corresponding carry component before the next epoch
    (:func:`hyper_schedule` reschedules a traced hyperparameter, e.g. a
    learning-rate schedule, without retracing — the hyper leaves are
    traced inputs, not baked constants).

A callback is any callable ``cb(env) -> None | dict``.  Two optional
attributes refine dispatch (the LightGBM convention):

  * ``cb.order`` (int, default 10) — callbacks fire in ascending order;
  * ``cb.before_epoch`` (bool, default False) — fire *before* the epoch
    instead of after it (schedules set values the upcoming epoch uses;
    evaluation-driven callbacks need the epoch's result).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

__all__ = [
    "CallbackEnv",
    "EvalEntry",
    "EarlyStopException",
    "early_stopping",
    "record_evaluation",
    "hyper_schedule",
    "split_callbacks",
    "fire_callbacks",
]


class EvalEntry(NamedTuple):
    """One evaluation result: ``(trial, metric, value, higher_better)``.

    ``trial`` is the trial's search-wide index (0 for plain single-model
    training loops); scores follow the tune convention — ``higher_better``
    says which direction improves, the stored value is untransformed.
    """

    trial: int
    metric: str
    value: float
    higher_better: bool = True


@dataclasses.dataclass(frozen=True)
class CallbackEnv:
    """Frozen snapshot handed to every callback at a hook point.

    Fields
    ------
    epoch:
        Epochs completed so far (after-epoch hooks) or the epoch about to
        run (before-epoch hooks).
    begin_epoch / end_epoch:
        The segment bounds of the surrounding loop call — rung-segmented
        searches fire hooks with the rung's bounds.
    round:
        Global round index at this boundary (``epoch * chunks_per_epoch``).
    state:
        The model-state handle: the raw carry pytree for plain loops, the
        stacked (K, …) trial tree for stacked loops.  A handle, not a
        copy — read freely, mutate never; return ``{"state": new}`` to
        swap it.
    hyper:
        The (K,)-stacked traced hyperparameter tree (stacked loops) or
        ``None``; return ``{"hyper": new}`` to swap it.
    active:
        Host copy of the (K,) bool active mask, or ``None``.
    unit / trial_ids:
        Search context: the execution-unit ordinal and the search-wide
        trial indices in lane order (``(0,)`` for plain loops).
    evals:
        Tuple of :class:`EvalEntry` for this boundary — empty unless the
        loop was given an ``eval_fn`` (or the search computed rung
        scores).
    """

    epoch: int
    begin_epoch: int = 0
    end_epoch: int = 0
    round: int = 0
    state: Any = None
    hyper: Any = None
    active: Any = None
    unit: int = 0
    trial_ids: Tuple[int, ...] = (0,)
    evals: Tuple[EvalEntry, ...] = ()


class EarlyStopException(Exception):
    """Raised by a callback to stop the surrounding loop.

    Carries the epoch the stop was decided at and a human-readable
    reason; the loop checkpoints its tail state before returning, so an
    early-stopped run is resumable/inspectable like a completed one.
    """

    def __init__(self, epoch: int, reason: str = "early stop"):
        self.epoch = int(epoch)
        self.reason = reason
        super().__init__(f"{reason} (epoch {epoch})")


def split_callbacks(callbacks: Sequence[Callable]
                    ) -> Tuple[Tuple[Callable, ...], Tuple[Callable, ...]]:
    """Partition callbacks into (before-epoch, after-epoch) groups, each
    sorted by ``order`` (stable, so equal orders keep registration
    order)."""
    before = [cb for cb in callbacks if getattr(cb, "before_epoch", False)]
    after = [cb for cb in callbacks if not getattr(cb, "before_epoch", False)]
    key = lambda cb: getattr(cb, "order", 10)  # noqa: E731
    return tuple(sorted(before, key=key)), tuple(sorted(after, key=key))


def fire_callbacks(callbacks: Sequence[Callable], env: CallbackEnv) -> dict:
    """Run one hook point: call each callback with ``env``, folding any
    returned carry swaps (``{"state": ...}`` / ``{"hyper": ...}``) into
    the env later callbacks in the same hook see.  Returns the merged
    swap dict (empty when no callback steered).  An
    :class:`EarlyStopException` propagates to the loop."""
    swaps: dict = {}
    for cb in callbacks:
        out = cb(env)
        if not out:
            continue
        unknown = set(out) - {"state", "hyper", "active"}
        if unknown:
            raise ValueError(
                f"callback {cb!r} returned unknown carry keys {unknown} — "
                f"only 'state', 'hyper', 'active' can be swapped")
        swaps.update(out)
        env = dataclasses.replace(env, **{k: v for k, v in out.items()
                                          if k in ("state", "hyper", "active")})
    return swaps


# --------------------------------------------------------------------------- #
# built-in callbacks
# --------------------------------------------------------------------------- #
def early_stopping(stopping_rounds: int, min_delta: float = 0.0,
                   verbose: bool = False) -> Callable:
    """Stop when no tracked trial improves for ``stopping_rounds``
    consecutive evaluated hook points.

    Tracks the best value of every ``(trial, metric)`` pair seen in
    ``env.evals`` (direction per entry's ``higher_better``).  A hook
    point with at least one improvement of more than ``min_delta``
    resets the stall counter; ``stopping_rounds`` stalled hook points in
    a row raise :class:`EarlyStopException`.  Hook points with no evals
    are ignored (they carry no evidence either way).

    The callback exposes its running state as ``cb.best`` (``{(trial,
    metric): value}``) and is idempotent under replay: re-feeding the
    evaluations a resumed run already saw reproduces the same counter.
    """
    if stopping_rounds < 1:
        raise ValueError(f"stopping_rounds must be >= 1, got {stopping_rounds}")
    best: dict = {}
    stall = {"count": 0}

    def cb(env: CallbackEnv) -> None:
        if not env.evals:
            return
        improved = False
        for e in env.evals:
            key = (e.trial, e.metric)
            prev = best.get(key)
            if prev is None:
                best[key] = e.value
                improved = True  # a fresh baseline is never a stall
                continue
            gain = e.value - prev if e.higher_better else prev - e.value
            if gain > 0:
                best[key] = e.value
                if gain > min_delta:
                    improved = True
        stall["count"] = 0 if improved else stall["count"] + 1
        if verbose:
            print(f"early_stopping: epoch {env.epoch} "
                  f"stall {stall['count']}/{stopping_rounds}")
        if stall["count"] >= stopping_rounds:
            raise EarlyStopException(
                env.epoch, f"no improvement > {min_delta} for "
                           f"{stopping_rounds} evaluations")

    cb.order = 30          # after observers: they must see the final env
    cb.before_epoch = False
    cb.best = best
    return cb


def record_evaluation(history: Any) -> Callable:
    """Append every :class:`EvalEntry` to ``history`` (anything with a
    ``record(trial, metric, epoch, value)`` method — canonically a
    :class:`repro.eval.metrics.MetricHistory`).

    Recording is keyed by ``(trial, metric, epoch)`` and overwrites, so
    replaying a boundary a resumed search already recorded is idempotent
    — the history of a killed-and-resumed run equals the uninterrupted
    one.  ``cb.history`` exposes the target for later inspection.
    """
    if not hasattr(history, "record"):
        raise TypeError(
            f"record_evaluation needs an object with .record(trial, metric, "
            f"epoch, value) — got {type(history).__name__}; use "
            f"repro.eval.metrics.MetricHistory")

    def cb(env: CallbackEnv) -> None:
        for e in env.evals:
            history.record(e.trial, e.metric, env.epoch, e.value)

    cb.order = 20          # observers fire before controllers
    cb.before_epoch = False
    cb.history = history
    return cb


def hyper_schedule(param: str, fn: Callable[[int], float]) -> Callable:
    """Reschedule one traced hyperparameter before every epoch.

    ``fn(epoch) -> value`` computes the upcoming epoch's value for
    ``hyper[param]`` (a learning-rate schedule being the canonical use);
    the returned ``{"hyper": ...}`` swap reaches the next compiled epoch
    as a traced input — same compiled function, new value, no retrace.
    In stacked loops the value broadcasts over all K lanes.  Loops
    without a hyper tree (plain ``run_epochs``) are left untouched.
    """
    import jax.numpy as jnp

    def cb(env: CallbackEnv) -> Optional[dict]:
        if env.hyper is None:
            return None
        if not isinstance(env.hyper, dict) or param not in env.hyper:
            raise KeyError(
                f"hyper_schedule: no hyperparameter {param!r} in the hyper "
                f"tree (have {sorted(env.hyper) if isinstance(env.hyper, dict) else type(env.hyper).__name__})")
        old = env.hyper[param]
        new = dict(env.hyper)
        new[param] = jnp.full_like(jnp.asarray(old), fn(env.epoch))
        return {"hyper": new}

    cb.order = 0           # schedules run first: the epoch uses their value
    cb.before_epoch = True
    return cb
