"""Trial execution for model search: device-stacked groups + checkpointing.

A *trial* is one candidate configuration of an algorithm.  Algorithms
describe a trial with a :class:`TrialSpec` — the same pure-local-function
contract the :class:`repro.core.runner.DistributedRunner` already speaks,
plus a per-trial ``hyper`` pytree of *traced scalar hyperparameters*.
Because the hyperparameters are traced values (not Python constants baked
into the jit), K same-shape trials can be stacked along a leading axis and
advanced by ONE compiled round (``DistributedRunner.run_stacked_rounds`` /
``run_stacked_epochs``): one jit dispatch and one collective per round for
the whole group, instead of K of each.

Trials whose compiled structure differs (different solver, local batch
size, cluster count — anything in ``stack_key``) are *ragged* and cannot
share a vmap; :func:`group_trials` deals every trial into the largest
stackable groups (or all-singletons for sequential execution), in first-
occurrence order so the grouping is deterministic and resumable.

:class:`SearchCheckpointer` snapshots a search after every completed
execution unit through :mod:`repro.checkpoint.store` — the same atomic
store the PR-2 streaming path uses — so a SIGKILLed search resumes
trial-for-trial (``tests/test_tune_resume.py``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.runner import TrialStep, TrialUpdateFn

__all__ = [
    "TrialSpec",
    "tree_stack",
    "tree_unstack",
    "group_trials",
    "SearchCheckpointer",
    "fingerprint",
]


def tree_stack(trees: Sequence[Any]) -> Any:
    """Stack a list of identically-structured pytrees into one pytree whose
    every leaf has a new leading (K,) trial axis."""
    return jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                        *trees)


def tree_unstack(stacked: Any) -> List[Any]:
    """Inverse of :func:`tree_stack`: split the leading trial axis back
    into a list of K pytrees."""
    leaves = jax.tree.leaves(stacked)
    if not leaves:
        return []
    k = leaves[0].shape[0]
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(k)]


@dataclasses.dataclass
class TrialSpec:
    """One candidate configuration, in runner form.

    Within a stack group every spec's ``local_step`` / ``update`` /
    ``score`` must be *interchangeable* (config differences expressed only
    through ``hyper`` and ``init``); the executor uses the first spec's
    functions for the whole group.  Algorithm builders guarantee this by
    sharing one module-level step function per ``stack_key`` — which also
    means repeated searches hit the runner's compiled-epoch cache.

    Fields
    ------
    config:
        The raw search-point dict (JSON-able; recorded in checkpoints).
    hyper:
        Pytree of scalar jnp values — the *traced* hyperparameters
        (learning rate, regularizers, decay).  Stacked to (K,) leaves.
    init:
        ``init(train_table) -> state pytree`` — data-dependent state
        init (zeros for logreg, seeded rows for k-means centroids).
    local_step / combine / update:
        The runner contract for one trial:
        ``local_step(block, state, r, hyper) -> partial`` combined under
        ``combine``, then ``update(state, combined, r, hyper)``.
    stack_key:
        Trials with equal ``stack_key`` share one compiled structure and
        may be device-stacked; everything else is ragged.
    score:
        ``score(val_table, stacked_states, schedule) -> (K,)`` validation
        scores, **higher is better** (losses negated).  Shard-aware via
        :mod:`repro.eval.metrics`.
    finalize:
        ``finalize(state) -> Model`` for the winning trial.
    """

    config: Dict[str, Any]
    hyper: Any
    init: Callable[[Any], Any]
    local_step: TrialStep
    combine: str = "mean"
    update: Optional[TrialUpdateFn] = None
    stack_key: Hashable = ()
    score: Optional[Callable[[Any, Any, Any], jnp.ndarray]] = None
    finalize: Optional[Callable[[Any], Any]] = None


def group_trials(specs: Sequence[TrialSpec], execution: str = "auto"
                 ) -> List[List[int]]:
    """Deal trial indices into execution units.

    ``"stacked"``/``"auto"`` group by ``stack_key`` in first-occurrence
    order (ragged configs land in their own groups — possibly singletons);
    ``"sequential"`` forces one unit per trial.  Deterministic, so a
    resumed search re-derives the identical unit order.
    """
    if execution == "sequential":
        return [[i] for i in range(len(specs))]
    if execution not in ("auto", "stacked"):
        raise ValueError(f"unknown execution mode {execution!r}")
    groups: Dict[Hashable, List[int]] = {}
    order: List[Hashable] = []
    for i, spec in enumerate(specs):
        if spec.stack_key not in groups:
            groups[spec.stack_key] = []
            order.append(spec.stack_key)
        groups[spec.stack_key].append(i)
    return [groups[k] for k in order]


def fingerprint(payload: Dict[str, Any]) -> str:
    """Stable hash of the search definition (configs, schedule, epochs,
    folds, seed…) — a resumed search must run the *same* search."""
    blob = json.dumps(payload, sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()


class SearchCheckpointer:
    """Search-level checkpoint/resume at execution-unit granularity.

    After every completed unit (one trial, or one stacked group) the
    checkpointer writes ONE atomic snapshot through
    :mod:`repro.checkpoint.store`: the final state pytree of every
    completed trial (keyed by trial index) plus a JSON record of scores,
    rung histories, and the search fingerprint.  ``resume`` restores the
    newest snapshot, refuses a mismatched fingerprint, and hands back the
    completed set so the driver skips straight to the first unfinished
    unit — a SIGKILLed ``launch/tune.py`` continues trial-for-trial.
    """

    def __init__(self, ckpt_dir: str, search_fingerprint: str) -> None:
        self.ckpt_dir = ckpt_dir
        self.fingerprint = search_fingerprint

    def save(self, states: Dict[int, Any], info: Dict[int, Dict[str, Any]],
             units_done: int, *,
             live: Optional[Dict[int, List[Any]]] = None,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot all completed trials (cumulative) at ``units_done``.

        Each snapshot carries the *whole* completed set, so older steps are
        fully redundant — ``keep=2`` prunes them (the newest plus one
        published predecessor as insurance) instead of letting a long
        search accumulate O(units²) trial-state storage.

        ``live`` (``{trial: [state per fold]}``) rides the snapshot
        alongside the completed set: the mid-flight states of trials still
        occupying execution slots, which an asynchronous search (ASHA)
        needs to resume rung-for-rung instead of unit-for-unit.  ``extra``
        is a JSON-able dict stored in the metadata — the slot scheduler's
        control state lives here.  Both are atomic with the rest: one
        file, one rename.
        """
        from repro.checkpoint.store import save_checkpoint

        tree: Dict[str, Any] = {
            "states": {str(i): states[i] for i in sorted(states)}}
        meta: Dict[str, Any] = {
            "fingerprint": self.fingerprint,
            "units_done": units_done,
            "trials": {str(i): info[i] for i in sorted(info)},
        }
        if live is not None:
            tree["live"] = {str(t): {str(f): fs
                                     for f, fs in enumerate(live[t])}
                            for t in sorted(live)}
            meta["live_trials"] = sorted(live)
            meta["num_folds"] = (len(next(iter(live.values())))
                                 if live else 0)
        if extra is not None:
            meta["extra"] = extra
        save_checkpoint(self.ckpt_dir, units_done, tree, metadata=meta,
                        keep=2)

    def resume(self, template_init: Callable[[int], Any], *,
               with_live: bool = False) -> Optional[tuple]:
        """Restore the newest search snapshot, if any.

        ``template_init(trial_index) -> state pytree`` supplies the
        restore template for each completed trial (values ignored, only
        structure/shape/dtype matter).  Returns ``(states, info,
        units_done)`` or ``None`` when the directory holds no snapshot.

        With ``with_live=True`` the return grows to ``(states, info,
        units_done, live, extra)``: the in-flight trial states saved by
        ``save(..., live=...)`` (``{}`` when the snapshot carried none)
        and the extra metadata dict (``None`` when absent).
        """
        from repro.checkpoint.store import latest_step, load_metadata, \
            restore_checkpoint

        step = latest_step(self.ckpt_dir)
        if step is None:
            return None
        meta = load_metadata(self.ckpt_dir, step)
        if not meta or meta.get("fingerprint") != self.fingerprint:
            raise ValueError(
                f"checkpoint in {self.ckpt_dir} was written by a different "
                f"search (fingerprint mismatch) — refusing to resume")
        indices = sorted(int(i) for i in meta["trials"])
        template: Dict[str, Any] = {
            "states": {str(i): template_init(i) for i in indices}}
        live_ids = [int(t) for t in meta.get("live_trials", [])]
        num_folds = int(meta.get("num_folds", 0))
        # the template must cover live entries whenever the snapshot has
        # them — restore refuses checkpoints with unclaimed arrays
        if "live_trials" in meta:
            template["live"] = {str(t): {str(f): template_init(t)
                                         for f in range(num_folds)}
                                for t in live_ids}
        tree, _ = restore_checkpoint(self.ckpt_dir, template, step)
        states = {i: tree["states"][str(i)] for i in indices}
        info = {i: meta["trials"][str(i)] for i in indices}
        if not with_live:
            return states, info, int(meta["units_done"])
        live = {t: [tree["live"][str(t)][str(f)] for f in range(num_folds)]
                for t in live_ids} if "live" in tree else {}
        return states, info, int(meta["units_done"]), live, meta.get("extra")
