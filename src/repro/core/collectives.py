"""Collective schedules for global parameter combination (paper §IV-A).

The paper contrasts two ways to combine per-partition results each round:

  * **GATHER_BROADCAST** — MLI/Spark's schedule: gather all partition results
    to the master, average, and one-to-many broadcast the average back.  In
    SPMD form this is an ``all_gather`` followed by a local mean: the gather
    and the broadcast are one fused collective, but the wire pattern (every
    device receives *all* N partial vectors, O(N·d) bytes in) is preserved —
    which is exactly the communication property the paper reasons about.
  * **ALLREDUCE** — Vowpal Wabbit's schedule: a reduction tree (each device
    receives O(d) bytes).  ``jax.lax.pmean`` lowers to XLA's all-reduce,
    which the TPU ICI executes as the bandwidth-optimal ring/tree.

Beyond the paper we add **REDUCE_SCATTER**: psum_scatter + all_gather, the
two-phase bandwidth-optimal schedule modern frameworks use; it shards the
reduction work across devices.  All three compute the same mean — tests
assert bit-level agreement to fp tolerance — but lower to different HLO
collectives, which the roofline benchmark quantifies.

These functions must be called inside a ``shard_map`` body (they use named
axes).
"""
from __future__ import annotations

import enum
from functools import partial
from typing import Any, Sequence, Union

import jax
import jax.numpy as jnp

__all__ = ["CollectiveSchedule", "combine_mean", "combine_sum"]

AxisNames = Union[str, Sequence[str]]


class CollectiveSchedule(enum.Enum):
    ALLREDUCE = "allreduce"                 # VW-style (paper §IV-A)
    GATHER_BROADCAST = "gather_broadcast"   # MLI/Spark-style (paper §IV-A)
    REDUCE_SCATTER = "reduce_scatter"       # beyond-paper two-phase

    @classmethod
    def parse(cls, v: Union[str, "CollectiveSchedule"]) -> "CollectiveSchedule":
        return v if isinstance(v, cls) else cls(str(v).lower())


def _axis_size(axis_names: AxisNames) -> jnp.ndarray:
    names = (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)
    size = 1
    for n in names:
        size *= jax.lax.axis_size(n)
    return size


def _leaf_mean(x: jnp.ndarray, axis_names: AxisNames,
               schedule: CollectiveSchedule) -> jnp.ndarray:
    names = (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)
    if schedule is CollectiveSchedule.ALLREDUCE:
        return jax.lax.pmean(x, names)
    if schedule is CollectiveSchedule.GATHER_BROADCAST:
        g = x
        for n in names:
            g = jax.lax.all_gather(g, n)           # gather partials to everyone
            g = jnp.mean(g, axis=0)                # local average == broadcastee
        return g
    if schedule is CollectiveSchedule.REDUCE_SCATTER:
        flat = x.reshape(-1)
        n_dev = 1
        for n in names:
            n_dev *= jax.lax.axis_size(n)
        pad = (-flat.shape[0]) % n_dev
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        for n in names:
            flat = jax.lax.psum_scatter(flat, n, scatter_dimension=0, tiled=True)
        for n in reversed(names):
            flat = jax.lax.all_gather(flat, n, tiled=True)
        flat = flat / n_dev
        if pad:
            flat = flat[: x.size]
        return flat.reshape(x.shape)
    raise ValueError(schedule)


def combine_mean(tree: Any, axis_names: AxisNames,
                 schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE) -> Any:
    """Average a pytree of per-partition values across the data axes using the
    selected collective schedule.  This is the paper's 'average all parameters
    at each iteration' step, factored so the schedule is a knob."""
    schedule = CollectiveSchedule.parse(schedule)
    return jax.tree.map(partial(_leaf_mean, axis_names=axis_names, schedule=schedule), tree)


def combine_sum(tree: Any, axis_names: AxisNames,
                schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE) -> Any:
    """Sum variant (used for full-batch gradient accumulation)."""
    schedule = CollectiveSchedule.parse(schedule)
    size = None

    def leaf(x):
        nonlocal size
        m = _leaf_mean(x, axis_names, schedule)
        return m * _axis_size(axis_names)

    return jax.tree.map(leaf, tree)
