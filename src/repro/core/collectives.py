"""Collective schedules for global parameter combination (paper §IV-A).

The paper contrasts two ways to combine per-partition results each round:

  * **GATHER_BROADCAST** — MLI/Spark's schedule: gather all partition results
    to the master, average, and one-to-many broadcast the average back.  In
    SPMD form this is an ``all_gather`` followed by a local mean: the gather
    and the broadcast are one fused collective, but the wire pattern (every
    device receives *all* N partial vectors, O(N·d) bytes in) is preserved —
    which is exactly the communication property the paper reasons about.
  * **ALLREDUCE** — Vowpal Wabbit's schedule: a reduction tree (each device
    receives O(d) bytes).  ``jax.lax.pmean`` lowers to XLA's all-reduce,
    which the TPU ICI executes as the bandwidth-optimal ring/tree.

Beyond the paper we add **REDUCE_SCATTER**: psum_scatter + all_gather, the
two-phase bandwidth-optimal schedule modern frameworks use; it shards the
reduction work across devices.  All three compute the same mean — tests
assert agreement to fp tolerance — but lower to different HLO collectives,
which ``benchmarks/collective_schedules.py`` quantifies (see
``docs/benchmarks.md``).

Every algorithm reaches these functions through
:class:`repro.core.runner.DistributedRunner`, which owns the ``shard_map``
context they require; the schedule is the runner's pluggable knob (see
``docs/architecture.md`` for the full data flow and ``docs/api.md`` for the
public surface).

These functions must be called inside a ``shard_map`` body (they use named
axes).
"""
from __future__ import annotations

import dataclasses
import enum
from functools import partial
from typing import Any, Dict, List, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.compat import axis_size as _compat_axis_size

__all__ = [
    "CollectiveSchedule",
    "SyncPolicy",
    "combine_mean",
    "combine_sum",
    "combine_concat",
    "ssp_read_round",
    "ssp_trace",
]

AxisNames = Union[str, Sequence[str]]


class CollectiveSchedule(enum.Enum):
    """Wire schedule for the per-round global combine (paper §IV-A).

    Members:
      * ``ALLREDUCE`` — VW's reduction tree (paper §IV-A); O(d) bytes per
        device.
      * ``GATHER_BROADCAST`` — MLI/Spark's gather-to-master + broadcast
        (paper §IV-A, Fig. 2a discussion); O(N·d) bytes per device.
      * ``REDUCE_SCATTER`` — beyond-paper two-phase psum_scatter +
        all_gather; bandwidth-optimal on ring interconnects.

    All members produce identical results to fp tolerance (asserted in
    ``tests/test_runner.py``); they differ only in lowered HLO collectives.
    See ``docs/benchmarks.md`` for the measured wire-byte comparison.
    """

    ALLREDUCE = "allreduce"                 # VW-style (paper §IV-A)
    GATHER_BROADCAST = "gather_broadcast"   # MLI/Spark-style (paper §IV-A)
    REDUCE_SCATTER = "reduce_scatter"       # beyond-paper two-phase

    @classmethod
    def parse(cls, v: Union[str, "CollectiveSchedule"]) -> "CollectiveSchedule":
        """Accept either a member or its lowercase string value — so
        hyperparameter dataclasses, CLI flags, and JSON payloads can all
        carry a schedule."""
        return v if isinstance(v, cls) else cls(str(v).lower())


def _names(axis_names: AxisNames) -> Sequence[str]:
    return (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)


def _axis_size(axis_names: AxisNames) -> int:
    size = 1
    for n in _names(axis_names):
        size *= _compat_axis_size(n)
    return size


def _leaf_mean(x: jnp.ndarray, axis_names: AxisNames,
               schedule: CollectiveSchedule) -> jnp.ndarray:
    names = _names(axis_names)
    if schedule is CollectiveSchedule.ALLREDUCE:
        return jax.lax.pmean(x, names)
    if schedule is CollectiveSchedule.GATHER_BROADCAST:
        g = x
        for n in names:
            g = jax.lax.all_gather(g, n)           # gather partials to everyone
            g = jnp.mean(g, axis=0)                # local average == broadcastee
        return g
    if schedule is CollectiveSchedule.REDUCE_SCATTER:
        flat = x.reshape(-1)
        n_dev = _axis_size(names)
        pad = (-flat.shape[0]) % n_dev
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        for n in names:
            flat = jax.lax.psum_scatter(flat, n, scatter_dimension=0, tiled=True)
        for n in reversed(names):
            flat = jax.lax.all_gather(flat, n, tiled=True)
        flat = flat / n_dev
        if pad:
            flat = flat[: x.size]
        return flat.reshape(x.shape)
    raise ValueError(schedule)


def _leaf_concat(x: jnp.ndarray, axis_names: AxisNames,
                 schedule: CollectiveSchedule) -> jnp.ndarray:
    """Concatenate every partition's (rows, ...) block into the full
    (total_rows, ...) array on every device — the paper's Fig. A9 'broadcast
    the factor' step, under the selected wire pattern.

    Axes are walked innermost-first so the row order matches the
    ``P((pod, data))`` partition layout.
    """
    names = _names(axis_names)
    for n in reversed(names):
        size = _compat_axis_size(n)
        if schedule is CollectiveSchedule.GATHER_BROADCAST:
            # the direct wire pattern: one tiled all-gather
            x = jax.lax.all_gather(x, n, tiled=True)
        else:
            # place the local block at its global offset, combine by summing
            # disjoint supports: ALLREDUCE in one phase, REDUCE_SCATTER via
            # the two-phase psum_scatter + all_gather pipeline.
            rows = x.shape[0]
            full = jnp.zeros((size * rows,) + x.shape[1:], x.dtype)
            idx = jax.lax.axis_index(n)
            full = jax.lax.dynamic_update_slice_in_dim(full, x, idx * rows, axis=0)
            if schedule is CollectiveSchedule.ALLREDUCE:
                x = jax.lax.psum(full, n)
            elif schedule is CollectiveSchedule.REDUCE_SCATTER:
                part = jax.lax.psum_scatter(full, n, scatter_dimension=0, tiled=True)
                x = jax.lax.all_gather(part, n, tiled=True)
            else:
                raise ValueError(schedule)
    return x


def combine_mean(tree: Any, axis_names: AxisNames,
                 schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE) -> Any:
    """Average a pytree of per-partition values across the data axes using the
    selected collective schedule.

    This is the paper's 'average all parameters at each iteration' step
    (§IV-A, Fig. A4 ``avgWeights``), factored so the schedule is a knob.
    Used by :class:`repro.core.runner.DistributedRunner` with
    ``combine="mean"``; documented in ``docs/api.md``.
    """
    schedule = CollectiveSchedule.parse(schedule)
    return jax.tree.map(partial(_leaf_mean, axis_names=axis_names, schedule=schedule), tree)


def combine_sum(tree: Any, axis_names: AxisNames,
                schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE) -> Any:
    """Sum a pytree of per-partition values across the data axes.

    The combine used when partial results are *sufficient statistics* rather
    than parameters: full-batch gradient accumulation (paper Fig. A4 top),
    k-means cluster sums/counts, PCA moments, naive Bayes counts.  Runner
    spelling: ``combine="sum"``; documented in ``docs/api.md``.
    """
    schedule = CollectiveSchedule.parse(schedule)

    def leaf(x):
        m = _leaf_mean(x, axis_names, schedule)
        return m * _axis_size(axis_names)

    return jax.tree.map(leaf, tree)


def combine_concat(tree: Any, axis_names: AxisNames,
                   schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.GATHER_BROADCAST) -> Any:
    """Concatenate per-partition row blocks into the full array on every
    device, preserving partition order.

    This is the combine behind BroadcastALS (paper §IV-B, Fig. A9): each
    half-sweep computes the rows of one factor partition-locally, then the
    whole factor must be *broadcast* to every partition for the next sweep.
    ``GATHER_BROADCAST`` is the paper's literal wire pattern (one
    all-gather); ``ALLREDUCE`` and ``REDUCE_SCATTER`` realize the same
    broadcast as a sum of disjointly-placed blocks, so ALS keeps the same
    schedule knob as the gradient methods.  Runner spelling:
    ``combine="concat"``; documented in ``docs/api.md``.
    """
    schedule = CollectiveSchedule.parse(schedule)
    return jax.tree.map(partial(_leaf_concat, axis_names=axis_names, schedule=schedule), tree)


# --------------------------------------------------------------------------- #
# barrier discipline: BSP / SSP / elastic (beyond paper; Petuum, PAPERS.md)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SyncPolicy:
    """The *barrier discipline* of multi-host rounds — the second axis of the
    collective schedule (the first, :class:`CollectiveSchedule`, is the wire
    pattern of one combine; this is *when* workers are allowed to combine).

      * ``staleness == 0`` — **BSP** (bulk-synchronous): every worker blocks
        at every round boundary until all peers publish that round; the
        combine always reads round-``r`` partials from everyone.
      * ``staleness == s > 0`` — **SSP** (stale-synchronous, Petuum): a
        worker at round ``r`` may proceed using each peer's freshest
        published partial, as long as that partial is no older than round
        ``r - s``; it blocks only when a peer falls more than ``s`` rounds
        behind.  With ``s = 0`` this degenerates bit-for-bit to BSP
        (asserted in ``tests/chaos/``).
      * ``elastic`` — membership may change mid-run: a host that leaves (or
        dies) triggers a repartition and the survivors resume from the
        latest atomic checkpoint on the resized mesh (see
        :mod:`repro.core.elastic`).

    The executable spec of the SSP read rule is :func:`ssp_read_round` /
    :func:`ssp_trace` below; the real executor
    (:meth:`repro.core.runner.DistributedRunner.run_epochs_ssp`) follows the
    same rule through the file-based :class:`repro.core.exchange.ParamStore`.
    """

    staleness: int = 0
    elastic: bool = False

    def __post_init__(self) -> None:
        if self.staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {self.staleness}")

    @property
    def mode(self) -> str:
        return "bsp" if self.staleness == 0 else "ssp"

    @classmethod
    def parse(cls, v: Union[None, int, "SyncPolicy"]) -> "SyncPolicy":
        """Accept a policy, a bare staleness integer, or None (BSP)."""
        if v is None:
            return cls()
        if isinstance(v, cls):
            return v
        return cls(staleness=int(v))


def ssp_read_round(my_round: int, peer_clock: int, staleness: int) -> int:
    """Which round of a peer's publishes a worker at ``my_round`` combines.

    ``peer_clock`` is the number of rounds the peer has published (its next
    round index).  The worker reads the peer's freshest partial **capped at
    its own round** (never reads the future, so ``staleness = 0`` is exactly
    lockstep BSP), and must block until the peer has published at least
    round ``my_round - staleness`` — the Petuum bound.  Returns the round
    index to read; raises if the peer is still too far behind (the caller
    waits and retries).
    """
    if peer_clock <= my_round - staleness:
        raise ValueError(
            f"peer at clock {peer_clock} is more than {staleness} rounds "
            f"behind round {my_round} — SSP requires blocking here")
    return min(peer_clock - 1, my_round)


def ssp_trace(durations: Sequence[Sequence[float]], staleness: int
              ) -> List[List[Dict[int, int]]]:
    """Executable spec of the SSP discipline: simulate ``W`` workers running
    ``R`` rounds where worker ``w``'s round ``r`` takes ``durations[w][r]``
    seconds of compute, and return ``trace[w][r] = {peer: read_round}`` — the
    round of each peer's publish that worker ``w`` combined at its round
    ``r``.

    The discipline (mirrored by the real executor):

      1. worker ``w`` computes round ``r`` and *publishes* its partial;
      2. it then waits until every peer has published round ``>= r - s``;
      3. it reads each peer's freshest publish available at that moment,
         capped at its own round ``r`` (:func:`ssp_read_round`), merges, and
         proceeds to round ``r + 1``.

    Invariants (property-tested in ``tests/chaos/test_ssp_property.py``):
    every read is within ``[r - s, r]``, and with ``s = 0`` every read is
    exactly ``r`` — the BSP trace.
    """
    W = len(durations)
    R = len(durations[0]) if W else 0
    if any(len(d) != R for d in durations):
        raise ValueError("every worker needs a duration for every round")
    # publish[w][r]: wall time worker w publishes round r
    # merged[w][r]:  wall time worker w finishes round r's merge
    publish = [[0.0] * R for _ in range(W)]
    merged = [[0.0] * R for _ in range(W)]
    trace: List[List[Dict[int, int]]] = [[{} for _ in range(R)] for _ in range(W)]
    # Rounds resolve in dependency order: worker w's round r depends on its
    # own round r-1 and on peers' rounds <= r - 1 (waits target r - s - 1,
    # reads cap at r) — iterating rounds outermost is a valid topological
    # order because a merge at round r never waits on a peer publish later
    # than round r, and peer publishes at round r depend only on merges at
    # r - 1.
    for r in range(R):
        for w in range(W):
            start = merged[w][r - 1] if r else 0.0
            publish[w][r] = start + durations[w][r]
        for w in range(W):
            # wait until every peer has published round >= r - s
            t = publish[w][r]
            for p in range(W):
                if p != w and r - staleness >= 0:
                    t = max(t, publish[p][r - staleness])
            merged[w][r] = t
            for p in range(W):
                if p == w:
                    continue
                # freshest publish of p available at time t, capped at r
                clock = 0
                for k in range(min(r, R - 1) + 1):
                    if publish[p][k] <= t:
                        clock = k + 1
                trace[w][r][p] = ssp_read_round(r, clock, staleness)
    return trace
