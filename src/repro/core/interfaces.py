"""Algorithm / Model interfaces (paper §III-C).

An Algorithm is a class with a ``train()`` method that accepts data and
hyperparameters and produces a Model; a Model is an object that makes
predictions.  These are deliberately thin — their value is the *uniform
contract* across every algorithm in the library (and, in the paper, across
the whole MLBASE system).
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Any, Generic, TypeVar

import jax.numpy as jnp

from repro.core.numeric_table import MLNumericTable

__all__ = ["Algorithm", "NumericAlgorithm", "Model"]

P_ = TypeVar("P_")  # hyperparameter dataclass
M_ = TypeVar("M_", bound="Model")


class Model(abc.ABC):
    """An object which makes predictions (paper §III-C)."""

    @abc.abstractmethod
    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        ...

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.predict(x)


class Algorithm(abc.ABC, Generic[P_, M_]):
    """train(data, hyperparameters) -> Model."""

    @classmethod
    @abc.abstractmethod
    def default_parameters(cls) -> P_:
        ...

    @classmethod
    @abc.abstractmethod
    def train(cls, data: Any, params: P_) -> M_:
        ...

    # paper spelling
    @classmethod
    def defaultParameters(cls) -> P_:
        return cls.default_parameters()


class NumericAlgorithm(Algorithm[P_, M_]):
    """An Algorithm whose ``train`` expects an MLNumericTable (each row is a
    feature vector; by library convention column 0 is the label when the
    algorithm is supervised — matching Fig. A4's ``vec(0)``)."""

    @classmethod
    @abc.abstractmethod
    def train(cls, data: MLNumericTable, params: P_) -> M_:
        ...
