"""Estimator / Model interfaces — the MLI contract (paper §III-C), redesigned
around *fitted objects*.

The user-facing contract is one pair of objects:

    est = SomeEstimator(learning_rate=0.3)     # hyperparameters in the ctor
    fitted = est.fit(table)                    # -> FittedEstimator
    fitted.predict(x) / fitted.transform(x)    # replayable on any rows

Every algorithm and every featurizer in the library implements it, so the
paper's Fig. A2 program — raw text → nGrams → tfIdf → train → predict — is
one composable object (:class:`repro.pipeline.Pipeline`) that trains through
:class:`repro.core.runner.DistributedRunner`, is searched by
:class:`repro.tune.ModelSearch`, checkpoints through
:mod:`repro.checkpoint.store`, and serves through
:class:`repro.serve.ModelPredictor`.

Capability mixins declare what an estimator can do beyond plain ``fit``:

  * :class:`StreamFitable` — ``fit_stream(stream, …)`` trains from per-epoch
    minibatch windows (never fully resident) with checkpoint/resume;
  * :class:`Searchable` — ``trial_spec(config)`` describes one model-search
    trial in the device-stackable form :mod:`repro.tune` executes;
  * fitted objects expose ``partial`` — the checkpointable state pytree —
    and estimators ``rebuild(partial)`` a fitted object from it, which is
    how a whole pipeline round-trips through one atomic checkpoint.

The seed-era classmethod spellings (``Algorithm.train(data, params)``,
``defaultParameters``) keep working as thin deprecation shims delegating to
the instances; they warn with :class:`DeprecationWarning` (carved out of the
repo's warnings-as-errors filter) and are bit-identical to the new path
(``tests/test_estimators.py``).
"""
from __future__ import annotations

import abc
import dataclasses
import warnings
from typing import Any, ClassVar, Generic, Optional, TypeVar

import jax.numpy as jnp

from repro.core.numeric_table import MLNumericTable

__all__ = [
    "Estimator",
    "FittedEstimator",
    "Transformer",
    "FittedTransformer",
    "StreamFitable",
    "Searchable",
    "Algorithm",
    "NumericAlgorithm",
    "Model",
]

P_ = TypeVar("P_")  # hyperparameter dataclass
M_ = TypeVar("M_", bound="Model")


def _warn_deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} — the instance-based Estimator "
        f"contract (hyperparameters in the constructor, fit() returning a "
        f"fitted model). The shim delegates and is bit-identical.",
        DeprecationWarning, stacklevel=3)


# --------------------------------------------------------------------------- #
# fitted objects
# --------------------------------------------------------------------------- #
class Model(abc.ABC):
    """A fitted estimator: an object which makes predictions (paper §III-C).

    ``transform`` is the transformer-style spelling of the same replay
    (identical for projection models like PCA); ``partial`` exposes the
    checkpointable state pytree (arrays only) so fitted objects ride in
    :mod:`repro.checkpoint.store` snapshots — rebuild one with
    :meth:`Estimator.rebuild`.
    """

    @abc.abstractmethod
    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        ...

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.predict(x)

    def transform(self, x: jnp.ndarray) -> jnp.ndarray:
        """Transformer spelling of the fitted replay (defaults to predict)."""
        return self.predict(x)

    @property
    def partial(self) -> Any:
        """The fitted state as a pytree of arrays (for checkpointing)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not expose partial state")


#: the fitted half of the Estimator contract (predict/transform + partial)
FittedEstimator = Model


# --------------------------------------------------------------------------- #
# estimators
# --------------------------------------------------------------------------- #
class Estimator(abc.ABC):
    """fit(data) -> FittedEstimator; hyperparameters live in the instance."""

    @abc.abstractmethod
    def fit(self, data: Any) -> FittedEstimator:
        ...

    def rebuild(self, partial: Any) -> FittedEstimator:
        """Reconstruct a fitted object from its ``partial`` state pytree
        (the checkpoint-restore path)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support rebuild()")


class StreamFitable(abc.ABC):
    """Capability mixin: the estimator trains from a stream of per-epoch
    minibatch windows (:class:`repro.data.pipeline.BatchIterator`) through
    :meth:`repro.core.runner.DistributedRunner.run_epochs`, inheriting its
    checkpoint/resume story."""

    @abc.abstractmethod
    def fit_stream(self, stream: Any, **kwargs: Any) -> FittedEstimator:
        ...


class Searchable(abc.ABC):
    """Capability mixin: the estimator describes one model-search trial as
    a :class:`repro.tune.trials.TrialSpec` (device-stackable where shapes
    allow; see :mod:`repro.tune`)."""

    @classmethod
    @abc.abstractmethod
    def trial_spec(cls, config: dict, metric: Optional[str] = None):
        ...


# --------------------------------------------------------------------------- #
# transformers (featurizers)
# --------------------------------------------------------------------------- #
class FittedTransformer(abc.ABC):
    """A fitted feature transformer: corpus statistics (vocabulary, IDF
    weights, column means/stds) are computed once at ``fit`` and *replayed*
    at ``transform`` on any table or raw serving row — never refit, so a
    transformer fit on train folds cannot leak validation statistics.

    ``tier`` declares where the transform runs: ``"host"`` stages are
    schema-changing row programs (text → counts) executed on the MLTable
    tier; ``"device"`` stages are pure numeric maps whose :meth:`apply`
    is jax-traceable and runs inside the serving microbatch jit.
    """

    tier: ClassVar[str] = "device"

    @abc.abstractmethod
    def transform(self, table: Any) -> Any:
        """Replay the fitted statistics over a whole table."""

    def __call__(self, table: Any) -> Any:
        return self.transform(table)

    def apply(self, feats: jnp.ndarray) -> jnp.ndarray:
        """Device-tier row replay on label-free feature rows (jittable).
        Host-tier transformers raise; use :meth:`transform_rows`."""
        raise NotImplementedError(
            f"{type(self).__name__} has no device-tier apply()")

    def transform_rows(self, rows: Any) -> Any:
        """Host-tier row replay (e.g. raw text → count vectors)."""
        raise NotImplementedError(
            f"{type(self).__name__} has no host-tier transform_rows()")

    @property
    def partial(self) -> Any:
        """Fitted state as a pytree of arrays (may be empty)."""
        return {}

    def host_state(self) -> dict:
        """Fitted state that is not arrays (vocabulary, column indices) as
        a JSON-able dict; together with ``partial`` it fully determines the
        fitted transformer (see ``from_state``)."""
        return {}


class Transformer(Estimator):
    """An Estimator whose fitted form transforms tables (same contract as
    the algorithms: statistics at fit, replay at transform)."""

    tier: ClassVar[str] = "device"

    def fit_transform(self, table: Any):
        """Convenience: fit on ``table`` and transform it; returns
        ``(fitted, transformed_table)``."""
        fitted = self.fit(table)
        return fitted, fitted.transform(table)

    def clone_with(self, **overrides: Any) -> "Transformer":
        """A new transformer of the same type with some constructor
        hyperparameters replaced — how :class:`repro.tune.ModelSearch`
        addresses nested stage params (``"tfidf.top"``)."""
        cfg = dict(getattr(self, "_config", {}))
        for k in overrides:
            if k not in cfg:
                raise ValueError(
                    f"{type(self).__name__} has no hyperparameter {k!r} "
                    f"(searchable: {sorted(cfg)})")
        cfg.update(overrides)
        return type(self)(**cfg)


# --------------------------------------------------------------------------- #
# parameters-carrying algorithms (the paper's Algorithm, instance-based)
# --------------------------------------------------------------------------- #
class Algorithm(Estimator, Generic[P_, M_]):
    """An Estimator whose hyperparameters are a ``Parameters`` dataclass.

    Instances are constructed either from a full dataclass or from field
    overrides::

        LogisticRegressionAlgorithm(learning_rate=0.3, max_iter=20)
        KMeans(KMeansParameters(k=8, seed=1))

    The legacy classmethod spellings (``train``, ``defaultParameters``) are
    deprecation shims delegating to ``cls(params).fit(data)``.
    """

    #: the hyperparameter dataclass of this algorithm (set by subclasses)
    Parameters: ClassVar[Optional[type]] = None
    #: whether fit() expects the label in column 0 (library convention) —
    #: pipelines use this to protect the label column from featurizers
    supervised: ClassVar[bool] = False

    def __init__(self, params: Optional[P_] = None, **overrides: Any) -> None:
        cls = type(self)
        if cls.Parameters is None:
            raise TypeError(f"{cls.__name__} declares no Parameters class")
        if params is None:
            params = cls.Parameters(**overrides)
        elif overrides:
            params = dataclasses.replace(params, **overrides)
        self.params: P_ = params

    def overrides(self) -> dict:
        """The hyperparameters that differ from the defaults — merged under
        trial configs by the pipeline search path, so an instance's settings
        are the baseline every trial overrides."""
        base = type(self).Parameters()
        return {f.name: getattr(self.params, f.name)
                for f in dataclasses.fields(self.params)
                if getattr(self.params, f.name) != getattr(base, f.name)}

    @classmethod
    def default_parameters(cls) -> P_:
        return cls.Parameters()

    # ------------------------------------------------------------------ #
    # legacy classmethod contract (deprecation shims)
    # ------------------------------------------------------------------ #
    @classmethod
    def train(cls, data: Any, params: Optional[P_] = None, **kwargs: Any) -> M_:
        """Deprecated: ``cls.train(data, params)`` → ``cls(params).fit(data)``.

        Bit-identical to the new path (it *is* the new path)."""
        _warn_deprecated(f"{cls.__name__}.train(data, params)",
                         f"{cls.__name__}(params).fit(data)")
        return cls(params).fit(data, **kwargs)

    @classmethod
    def train_stream(cls, stream: Any, params: Optional[P_] = None,
                     **kwargs: Any) -> M_:
        """Legacy spelling of :meth:`StreamFitable.fit_stream` (kept quiet —
        internal launchers routed through it until this release)."""
        return cls(params).fit_stream(stream, **kwargs)

    @classmethod
    def defaultParameters(cls) -> P_:  # paper spelling
        _warn_deprecated(f"{cls.__name__}.defaultParameters()",
                         f"{cls.__name__}.default_parameters()")
        return cls.default_parameters()


class NumericAlgorithm(Algorithm[P_, M_]):
    """An Algorithm whose ``fit`` expects an MLNumericTable (each row is a
    feature vector; by library convention column 0 is the label when the
    algorithm is supervised — matching Fig. A4's ``vec(0)``)."""
