"""Principal Component Analysis through the MLI contract (beyond-paper,
supporting the paper's §IV claim that the API 'naturally extends to a
diverse group of ML algorithms').

Pattern: partition-local second-moment blocks via ``matrixBatchMap`` (each
partition emits its d×d Gram matrix — one output row block per partition),
one explicit global sum, then a LOCAL eigendecomposition of the d×d
covariance (d ≪ n; the paper's shared-nothing rule — only O(d²) crosses
the wire, never the data)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.interfaces import Model, NumericAlgorithm
from repro.core.local_matrix import LocalMatrix
from repro.core.numeric_table import MLNumericTable

__all__ = ["PCAParameters", "PCAModel", "PCA"]


@dataclasses.dataclass
class PCAParameters:
    n_components: int = 2


class PCAModel(Model):
    def __init__(self, components: jnp.ndarray, mean: jnp.ndarray,
                 explained_variance: jnp.ndarray):
        self.components = components            # (k, d) rows = PCs
        self.mean = mean                        # (d,)
        self.explained_variance = explained_variance  # (k,)

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        """Project (n, d) -> (n, k)."""
        return (x - self.mean) @ self.components.T

    transform = predict

    def inverse_transform(self, z: jnp.ndarray) -> jnp.ndarray:
        return z @ self.components + self.mean


class PCA(NumericAlgorithm[PCAParameters, PCAModel]):
    @classmethod
    def default_parameters(cls) -> PCAParameters:
        return PCAParameters()

    @classmethod
    def train(cls, data: MLNumericTable,
              params: Optional[PCAParameters] = None) -> PCAModel:
        p = params or cls.default_parameters()
        n, d = data.num_rows, data.num_cols

        # partition-local [sum | Gram] blocks, concatenated row-wise:
        # each partition contributes a (d+1, d) block [Σx ; XᵀX]
        def local_moments(m: LocalMatrix) -> LocalMatrix:
            s = jnp.sum(m.data, axis=0, keepdims=True)          # (1, d)
            gram = m.data.T @ m.data                            # (d, d)
            return LocalMatrix(jnp.concatenate([s, gram], axis=0))

        blocks = data.matrix_batch_map(local_moments)            # (P·(d+1), d)
        stacked = blocks.data.reshape(data.num_shards, d + 1, d)
        total = jnp.sum(stacked, axis=0)                         # explicit sum
        mean = total[0] / n
        cov = total[1:] / n - jnp.outer(mean, mean)

        # local eigendecomposition of the d×d covariance
        evals, evecs = jnp.linalg.eigh(cov)                      # ascending
        order = jnp.argsort(evals)[::-1][: p.n_components]
        components = evecs[:, order].T                           # (k, d)
        return PCAModel(components, mean, evals[order])
