"""Principal Component Analysis through the MLI contract (beyond-paper,
supporting the paper's §IV claim that the API 'naturally extends to a
diverse group of ML algorithms').

Pattern: the pure local function :func:`_local_moments` emits each
partition's [Σx ; XᵀX] block; one global sum — executed by
:class:`repro.core.runner.DistributedRunner` under the configured
:class:`CollectiveSchedule` — then a LOCAL eigendecomposition of the d×d
covariance (d ≪ n; the paper's shared-nothing rule — only O(d²) crosses
the wire, never the data)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.collectives import CollectiveSchedule
from repro.core.interfaces import Model, NumericAlgorithm
from repro.core.numeric_table import MLNumericTable
from repro.core.runner import DistributedRunner

__all__ = ["PCAParameters", "PCAModel", "PCA"]


@dataclasses.dataclass
class PCAParameters:
    n_components: int = 2
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE


def _local_moments(block: jnp.ndarray) -> jnp.ndarray:
    """Pure local function: a partition's (d+1, d) block [Σx ; XᵀX]."""
    s = jnp.sum(block, axis=0, keepdims=True)           # (1, d)
    gram = block.T @ block                              # (d, d)
    return jnp.concatenate([s, gram], axis=0)


class PCAModel(Model):
    def __init__(self, components: jnp.ndarray, mean: jnp.ndarray,
                 explained_variance: jnp.ndarray):
        self.components = components            # (k, d) rows = PCs
        self.mean = mean                        # (d,)
        self.explained_variance = explained_variance  # (k,)

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        """Project (n, d) -> (n, k)."""
        return (x - self.mean) @ self.components.T

    transform = predict

    def inverse_transform(self, z: jnp.ndarray) -> jnp.ndarray:
        return z @ self.components + self.mean

    @property
    def partial(self):
        return {"components": self.components, "mean": self.mean,
                "explained_variance": self.explained_variance}


class PCA(NumericAlgorithm[PCAParameters, PCAModel]):
    """Instance-based Estimator: ``PCA(n_components=2).fit(table)``."""

    Parameters = PCAParameters
    supervised = False

    def fit(self, data: MLNumericTable) -> PCAModel:
        p = self.params
        n, d = data.num_rows, data.num_cols

        runner = DistributedRunner.for_table(data, schedule=p.schedule)
        total = runner.run_once(data, _local_moments, combine="sum")  # (d+1, d)
        mean = total[0] / n
        cov = total[1:] / n - jnp.outer(mean, mean)

        # local eigendecomposition of the d×d covariance
        evals, evecs = jnp.linalg.eigh(cov)                      # ascending
        order = jnp.argsort(evals)[::-1][: p.n_components]
        components = evecs[:, order].T                           # (k, d)
        return PCAModel(components, mean, evals[order])

    def rebuild(self, partial) -> PCAModel:
        return PCAModel(jnp.asarray(partial["components"]),
                        jnp.asarray(partial["mean"]),
                        jnp.asarray(partial["explained_variance"]))
