"""Logistic regression via partition-local SGD (paper §IV-A, Fig. A4).

Library convention (as in Fig. A4): the input MLNumericTable carries the
label in column 0 and the features in columns 1..d.  The gradient closure is
literally the paper's:

    def gradient(vec, w):
        x = vec[1:]
        return x * (sigmoid(x · w) - vec[0])

and training is one call into the SGD optimizer, which iterates through
:class:`repro.core.runner.DistributedRunner` — ``params.schedule`` selects
the §IV-A collective schedule of the per-round weight averaging.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.collectives import CollectiveSchedule
from repro.core.interfaces import (
    Model,
    NumericAlgorithm,
    Searchable,
    StreamFitable,
)
from repro.core.numeric_table import MLNumericTable
from repro.core.optimizer import (
    GradientDescent,
    GradientDescentParameters,
    StochasticGradientDescent,
    StochasticGradientDescentParameters,
    sgd_trial_round,
    soft_threshold,
)

__all__ = [
    "LogisticRegressionParameters",
    "LogisticRegressionModel",
    "LogisticRegressionAlgorithm",
    "LogisticRegression",
]


def sigmoid(z: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.sigmoid(z)


@dataclasses.dataclass
class LogisticRegressionParameters:
    learning_rate: float = 0.5
    max_iter: int = 10
    l2: float = 0.0
    l1: float = 0.0
    local_batch_size: int = 1
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.GATHER_BROADCAST
    solver: str = "sgd"  # "sgd" (paper) | "gd" (MATLAB reference)
    lr_decay: float = 1.0
    use_kernel: bool = False  # route the gradient through the Pallas kernel


class LogisticRegressionModel(Model):
    def __init__(self, params: LogisticRegressionParameters, weights: jnp.ndarray):
        self.params = params
        self.weights = weights

    def predict_proba(self, x: jnp.ndarray) -> jnp.ndarray:
        return sigmoid(x @ self.weights)

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        return (self.predict_proba(x) > 0.5).astype(jnp.float32)

    def loss(self, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        """Mean negative log likelihood."""
        logits = x @ self.weights
        return jnp.mean(jnp.logaddexp(0.0, logits) - y * logits)

    @property
    def partial(self):
        return {"weights": self.weights}


def _make_gradient(p: LogisticRegressionParameters):
    """The paper's gradient closure (or its Pallas-kernel twin), shared by
    the resident and streaming training paths."""
    if p.use_kernel:
        from repro.kernels import ops as kops

        def gradient(vec: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
            # kernel path operates on a (1, d) block
            x = vec[1:][None, :]
            y = vec[0][None]
            return kops.logreg_grad(x, y, w)
    else:
        def gradient(vec: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
            x = vec[1:]
            g = x * (sigmoid(jnp.dot(x, w)) - vec[0])
            if p.l2:
                g = g + p.l2 * w
            return g

    return gradient


# --------------------------------------------------------------------------- #
# trial-stackable form (model search; repro.tune)
# --------------------------------------------------------------------------- #
def _hyper_gradient(vec: jnp.ndarray, w: jnp.ndarray, hyper: dict) -> jnp.ndarray:
    """The paper's gradient closure with L2 as a traced hyperparameter —
    ``l2 = 0`` adds an exact zero, so regularized and unregularized
    configs share one compiled round."""
    x = vec[1:]
    return x * (sigmoid(jnp.dot(x, w)) - vec[0]) + hyper["l2"] * w


# one shared local round per local_batch_size: every trial of a stack group
# (and every fold/rung segment) reuses the same function object, so the
# runner's compiled-epoch cache hits instead of retracing
_TRIAL_ROUNDS: dict = {}


def _trial_round(local_batch_size: int):
    if local_batch_size not in _TRIAL_ROUNDS:
        _TRIAL_ROUNDS[local_batch_size] = sgd_trial_round(
            _hyper_gradient, local_batch_size)
    return _TRIAL_ROUNDS[local_batch_size]


_SCORERS: dict = {}


def _scorer(metric: str):
    """(val_table, stacked_W, schedule) -> (K,) higher-is-better scores,
    one shard-aware pass for the whole stack (repro.eval.metrics)."""
    if metric in _SCORERS:
        return _SCORERS[metric]
    from repro.eval import metrics as M

    if metric == "accuracy":
        def score(val_table, W, schedule):
            return M.accuracy(
                val_table,
                lambda X: (sigmoid(X @ W.T).T > 0.5).astype(jnp.float32),
                schedule=schedule)
    elif metric == "log_loss":
        def score(val_table, W, schedule):
            return -M.log_loss(val_table, lambda X: sigmoid(X @ W.T).T,
                               schedule=schedule)
    else:
        raise ValueError(
            f"unknown logreg metric {metric!r} (accuracy | log_loss)")
    _SCORERS[metric] = score
    return score


class LogisticRegressionAlgorithm(
    NumericAlgorithm[LogisticRegressionParameters, LogisticRegressionModel],
    StreamFitable, Searchable,
):
    """Instance-based Estimator: ``LogisticRegressionAlgorithm(
    learning_rate=0.3).fit(table) -> LogisticRegressionModel`` (the legacy
    ``train`` classmethod is an inherited deprecation shim)."""

    Parameters = LogisticRegressionParameters
    supervised = True

    def fit(self, data: MLNumericTable) -> LogisticRegressionModel:
        p = self.params
        d = data.num_cols - 1
        gradient = _make_gradient(p)
        prox = soft_threshold(p.l1) if p.l1 else None
        w0 = jnp.zeros((d,), jnp.float32)

        if p.solver == "gd":
            opt = GradientDescent(GradientDescentParameters(
                w_init=w0, grad=gradient, learning_rate=p.learning_rate,
                max_iter=p.max_iter, schedule=p.schedule, prox=prox))
        else:
            opt = StochasticGradientDescent(StochasticGradientDescentParameters(
                w_init=w0, grad=gradient, learning_rate=p.learning_rate,
                max_iter=p.max_iter, schedule=p.schedule,
                local_batch_size=p.local_batch_size, prox=prox,
                lr_decay=p.lr_decay))
        weights = opt.apply(data, None)
        return LogisticRegressionModel(p, weights)

    def rebuild(self, partial) -> LogisticRegressionModel:
        return LogisticRegressionModel(self.params,
                                       jnp.asarray(partial["weights"]))

    def stream_state_template(self, num_cols: int) -> jnp.ndarray:
        """Shape/dtype template of the streaming-training carry for a table
        with ``num_cols`` columns (label included) — what a checkpointed
        pipeline restores into."""
        return jnp.zeros((num_cols - 1,), jnp.float32)

    @classmethod
    def trial_spec(cls, config: dict, metric: str = "accuracy"):
        """One model-search trial (see :mod:`repro.tune`): ``config``
        overrides :class:`LogisticRegressionParameters` fields, and every
        continuous hyperparameter (``learning_rate``, ``l2``, ``l1``,
        ``lr_decay``) becomes a *traced* value in the trial's hyper pytree
        — so a grid over regularization × step size stacks into one
        compiled round per K configs.  ``local_batch_size`` changes the
        compiled fold structure and therefore rides in the stack key
        (configs differing there run in separate groups).  Only the paper
        ``"sgd"`` solver is searchable (full-batch GD is a resident-table
        method with a different update structure).
        """
        import dataclasses as _dc

        from repro.tune.trials import TrialSpec

        p = _dc.replace(cls.default_parameters(), **config)
        if p.solver != "sgd":
            raise ValueError(
                f"model search supports solver='sgd' only, got {p.solver!r}")
        if p.use_kernel:
            raise ValueError("model search does not stack the Pallas-kernel "
                             "gradient (its L2 term is not hyper-traced)")
        hyper = {
            "lr": jnp.asarray(p.learning_rate, jnp.float32),
            "decay": jnp.asarray(p.lr_decay, jnp.float32),
            "l1": jnp.asarray(p.l1, jnp.float32),
            "l2": jnp.asarray(p.l2, jnp.float32),
        }

        def init(table) -> jnp.ndarray:
            return jnp.zeros((table.num_cols - 1,), jnp.float32)

        return TrialSpec(
            config=dict(config), hyper=hyper, init=init,
            local_step=_trial_round(p.local_batch_size), combine="mean",
            stack_key=("logreg", "sgd", int(p.local_batch_size)),
            score=_scorer(metric),
            finalize=lambda w: LogisticRegressionModel(p, w))

    def fit_stream(self, stream, *,
                   num_epochs: Optional[int] = None,
                   num_features: Optional[int] = None,
                   num_shards: int = 1,
                   chunks_per_epoch: Optional[int] = None,
                   checkpoint=None, resume: bool = False,
                   store=None, staleness: int = 0,
                   allow_resize: bool = False,
                   trace: Optional[list] = None
                   ) -> LogisticRegressionModel:
        """Streaming training over a :class:`repro.data.pipeline.
        BatchIterator` whose windows follow the library convention (label
        in column 0): one window per epoch, ``chunks_per_epoch`` SGD rounds
        per window, optional checkpoint/resume (see
        :meth:`repro.core.runner.DistributedRunner.run_epochs`).

        ``num_features`` may be omitted when the stream has a peekable
        ``source`` (a ``BatchIterator``); only the ``"sgd"`` solver
        streams — full-batch GD needs the whole table resident by
        definition.

        ``store`` (a :class:`repro.core.exchange.ParamStore`) selects the
        stale-synchronous multi-host lane: this host trains its own window
        locally each epoch and averages weights with its peers under the
        ``staleness`` bound.  ``allow_resize=True`` lets a resumed run
        continue on a mesh of a different world size (elastic restart).
        """
        p = self.params
        if p.solver != "sgd":
            raise ValueError(
                f"streaming supports solver='sgd' only, got {p.solver!r} "
                f"(full-batch GD is a resident-table method)")
        if num_features is None:
            if not hasattr(stream, "source"):
                raise ValueError("pass num_features= for non-peekable streams")
            num_features = stream.source(stream.step)["data"].shape[1] - 1
        gradient = _make_gradient(p)
        prox = soft_threshold(p.l1) if p.l1 else None
        opt = StochasticGradientDescent(StochasticGradientDescentParameters(
            w_init=jnp.zeros((num_features,), jnp.float32), grad=gradient,
            learning_rate=p.learning_rate, max_iter=p.max_iter,
            schedule=p.schedule, local_batch_size=p.local_batch_size,
            prox=prox, lr_decay=p.lr_decay))
        weights = opt.apply_stream(
            stream, num_epochs if num_epochs is not None else p.max_iter,
            num_shards=num_shards, chunks_per_epoch=chunks_per_epoch,
            checkpoint=checkpoint, resume=resume, store=store,
            staleness=staleness, allow_resize=allow_resize, trace=trace)
        return LogisticRegressionModel(p, weights)


#: estimator-style name for the paper's Fig. A2 terminal stage
LogisticRegression = LogisticRegressionAlgorithm
