"""Logistic regression via partition-local SGD (paper §IV-A, Fig. A4).

Library convention (as in Fig. A4): the input MLNumericTable carries the
label in column 0 and the features in columns 1..d.  The gradient closure is
literally the paper's:

    def gradient(vec, w):
        x = vec[1:]
        return x * (sigmoid(x · w) - vec[0])

and training is one call into the SGD optimizer, which iterates through
:class:`repro.core.runner.DistributedRunner` — ``params.schedule`` selects
the §IV-A collective schedule of the per-round weight averaging.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.collectives import CollectiveSchedule
from repro.core.interfaces import Model, NumericAlgorithm
from repro.core.numeric_table import MLNumericTable
from repro.core.optimizer import (
    GradientDescent,
    GradientDescentParameters,
    StochasticGradientDescent,
    StochasticGradientDescentParameters,
    soft_threshold,
)

__all__ = [
    "LogisticRegressionParameters",
    "LogisticRegressionModel",
    "LogisticRegressionAlgorithm",
]


def sigmoid(z: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.sigmoid(z)


@dataclasses.dataclass
class LogisticRegressionParameters:
    learning_rate: float = 0.5
    max_iter: int = 10
    l2: float = 0.0
    l1: float = 0.0
    local_batch_size: int = 1
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.GATHER_BROADCAST
    solver: str = "sgd"  # "sgd" (paper) | "gd" (MATLAB reference)
    lr_decay: float = 1.0
    use_kernel: bool = False  # route the gradient through the Pallas kernel


class LogisticRegressionModel(Model):
    def __init__(self, params: LogisticRegressionParameters, weights: jnp.ndarray):
        self.params = params
        self.weights = weights

    def predict_proba(self, x: jnp.ndarray) -> jnp.ndarray:
        return sigmoid(x @ self.weights)

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        return (self.predict_proba(x) > 0.5).astype(jnp.float32)

    def loss(self, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        """Mean negative log likelihood."""
        logits = x @ self.weights
        return jnp.mean(jnp.logaddexp(0.0, logits) - y * logits)


def _make_gradient(p: LogisticRegressionParameters):
    """The paper's gradient closure (or its Pallas-kernel twin), shared by
    the resident and streaming training paths."""
    if p.use_kernel:
        from repro.kernels import ops as kops

        def gradient(vec: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
            # kernel path operates on a (1, d) block
            x = vec[1:][None, :]
            y = vec[0][None]
            return kops.logreg_grad(x, y, w)
    else:
        def gradient(vec: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
            x = vec[1:]
            g = x * (sigmoid(jnp.dot(x, w)) - vec[0])
            if p.l2:
                g = g + p.l2 * w
            return g

    return gradient


class LogisticRegressionAlgorithm(
    NumericAlgorithm[LogisticRegressionParameters, LogisticRegressionModel]
):
    @classmethod
    def default_parameters(cls) -> LogisticRegressionParameters:
        return LogisticRegressionParameters()

    @classmethod
    def train(cls, data: MLNumericTable,
              params: Optional[LogisticRegressionParameters] = None
              ) -> LogisticRegressionModel:
        p = params or cls.default_parameters()
        d = data.num_cols - 1
        gradient = _make_gradient(p)
        prox = soft_threshold(p.l1) if p.l1 else None
        w0 = jnp.zeros((d,), jnp.float32)

        if p.solver == "gd":
            opt = GradientDescent(GradientDescentParameters(
                w_init=w0, grad=gradient, learning_rate=p.learning_rate,
                max_iter=p.max_iter, schedule=p.schedule, prox=prox))
        else:
            opt = StochasticGradientDescent(StochasticGradientDescentParameters(
                w_init=w0, grad=gradient, learning_rate=p.learning_rate,
                max_iter=p.max_iter, schedule=p.schedule,
                local_batch_size=p.local_batch_size, prox=prox,
                lr_decay=p.lr_decay))
        weights = opt.apply(data, None)
        return LogisticRegressionModel(p, weights)

    @classmethod
    def train_stream(cls, stream,
                     params: Optional[LogisticRegressionParameters] = None, *,
                     num_epochs: Optional[int] = None,
                     num_features: Optional[int] = None,
                     num_shards: int = 1,
                     chunks_per_epoch: Optional[int] = None,
                     checkpoint=None, resume: bool = False
                     ) -> LogisticRegressionModel:
        """Streaming training over a :class:`repro.data.pipeline.
        BatchIterator` whose windows follow the library convention (label
        in column 0): one window per epoch, ``chunks_per_epoch`` SGD rounds
        per window, optional checkpoint/resume (see
        :meth:`repro.core.runner.DistributedRunner.run_epochs`).

        ``num_features`` may be omitted when the stream has a peekable
        ``source`` (a ``BatchIterator``); only the ``"sgd"`` solver
        streams — full-batch GD needs the whole table resident by
        definition.
        """
        p = params or cls.default_parameters()
        if p.solver != "sgd":
            raise ValueError(
                f"streaming supports solver='sgd' only, got {p.solver!r} "
                f"(full-batch GD is a resident-table method)")
        if num_features is None:
            if not hasattr(stream, "source"):
                raise ValueError("pass num_features= for non-peekable streams")
            num_features = stream.source(stream.step)["data"].shape[1] - 1
        gradient = _make_gradient(p)
        prox = soft_threshold(p.l1) if p.l1 else None
        opt = StochasticGradientDescent(StochasticGradientDescentParameters(
            w_init=jnp.zeros((num_features,), jnp.float32), grad=gradient,
            learning_rate=p.learning_rate, max_iter=p.max_iter,
            schedule=p.schedule, local_batch_size=p.local_batch_size,
            prox=prox, lr_decay=p.lr_decay))
        weights = opt.apply_stream(
            stream, num_epochs if num_epochs is not None else p.max_iter,
            num_shards=num_shards, chunks_per_epoch=chunks_per_epoch,
            checkpoint=checkpoint, resume=resume)
        return LogisticRegressionModel(p, weights)
