"""Matrix factorization via Alternating Least Squares (paper §IV-B, Fig. A9).

Faithful to ``BroadcastALS``: rows of U (users) are updated in parallel
across partitions with V broadcast to every partition, then vice versa with
the *transposed* ratings ("We distribute both the matrix M and a transposed
version of this matrix across machines in order to quickly access relevant
ratings").

Sparse representation: the paper uses CSR-compressed LocalMatrix rows with
``nonZeroIndices`` / ``nonZeroProjection``.  TPUs need static shapes, so each
ratings row is packed as ``[indices | values | mask]`` of fixed width
``max_nnz`` (see :class:`repro.core.local_matrix.PaddedCSR`), and the packed
rows form a normal MLNumericTable — which means each half-sweep is exactly
Fig. A9's ``trainData.map(localALS(_, fixedFactor, lambI))``: the pure local
function :func:`_local_als` solves the partition's rows, and
:class:`repro.core.runner.DistributedRunner` re-broadcasts the completed
factor to every partition with ``combine="concat"`` — the Fig. A9
'broadcast' step, whose wire pattern is the configured
:class:`CollectiveSchedule`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collectives import CollectiveSchedule
from repro.core.interfaces import Model, NumericAlgorithm
from repro.core.numeric_table import MLNumericTable
from repro.core.runner import DistributedRunner

__all__ = ["ALSParameters", "MatrixFactorizationModel", "BroadcastALS",
           "pack_csr_table", "unpack_csr_block"]


def pack_csr_table(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                   num_rows: int, max_nnz: int,
                   num_shards: Optional[int] = None, mesh=None) -> MLNumericTable:
    """Pack COO ratings into a (num_rows, 3*max_nnz) MLNumericTable whose row
    layout is [indices | values | mask].  Rows beyond max_nnz entries are
    truncated (dataset builders choose max_nnz ≥ max row degree)."""
    idx = np.zeros((num_rows, max_nnz), dtype=np.float32)
    val = np.zeros((num_rows, max_nnz), dtype=np.float32)
    msk = np.zeros((num_rows, max_nnz), dtype=np.float32)
    fill = np.zeros(num_rows, dtype=np.int64)
    for r, c, v in zip(rows, cols, vals):
        k = fill[r]
        if k < max_nnz:
            idx[r, k], val[r, k], msk[r, k] = float(c), float(v), 1.0
            fill[r] += 1
    packed = np.concatenate([idx, val, msk], axis=1)
    return MLNumericTable.from_numpy(packed, num_shards=num_shards, mesh=mesh)


def unpack_csr_block(block: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Inverse of pack: (indices int32, values, mask) each (rows, max_nnz)."""
    w = block.shape[1] // 3
    idx = block[:, :w].astype(jnp.int32)
    val = block[:, w : 2 * w]
    msk = block[:, 2 * w :]
    return idx, val, msk


@dataclasses.dataclass
class ALSParameters:
    rank: int = 10          # paper: rank 10
    lam: float = 0.01       # paper: lambda = .01
    max_iter: int = 10      # paper: 10 iterations
    seed: int = 0
    # wire pattern of the per-sweep factor broadcast; GATHER_BROADCAST is the
    # paper's literal schedule (gather factor rows, broadcast the whole factor)
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.GATHER_BROADCAST


class MatrixFactorizationModel(Model):
    def __init__(self, U: jnp.ndarray, V: jnp.ndarray, params: ALSParameters):
        self.U = U
        self.V = V
        self.params = params

    def predict(self, pairs: jnp.ndarray) -> jnp.ndarray:
        """pairs: (n, 2) int array of (user, item) — returns predicted rating."""
        u = jnp.take(self.U, pairs[:, 0].astype(jnp.int32), axis=0)
        v = jnp.take(self.V, pairs[:, 1].astype(jnp.int32), axis=0)
        return jnp.sum(u * v, axis=1)

    def rmse(self, rows, cols, vals) -> jnp.ndarray:
        pairs = jnp.stack([jnp.asarray(rows), jnp.asarray(cols)], axis=1)
        pred = self.predict(pairs)
        return jnp.sqrt(jnp.mean((pred - jnp.asarray(vals)) ** 2))

    @property
    def partial(self):
        return {"U": self.U, "V": self.V}


def _local_als(block: jnp.ndarray, Y: jnp.ndarray, lam: float) -> jnp.ndarray:
    """Fig. A9 ``localALS`` as a pure local function: for each packed CSR row
    of the partition, solve the regularized normal equations against the
    fixed factor Y."""
    idx, val, msk = unpack_csr_block(block)
    k = Y.shape[1]
    lambI = lam * jnp.eye(k, dtype=Y.dtype)

    def solve_row(i_row, v_row, m_row):
        Yq = jnp.take(Y, i_row, axis=0) * m_row[:, None]     # masked projection
        A = Yq.T @ Yq + lambI                                # (k, k)
        b = Yq.T @ (v_row * m_row)                           # (k,)
        return jnp.linalg.solve(A, b[:, None])[:, 0]

    return jax.vmap(solve_row)(idx, val, msk)                # (rows, k)


def _local_als_stacked(block: jnp.ndarray, Ys: jnp.ndarray,
                       lams: jnp.ndarray) -> jnp.ndarray:
    """K stacked half-sweep solves: ``Ys`` is (K, n, rank), ``lams`` (K,).
    The K normal-equation solves vmap over the trial axis; the result is
    returned **rows-major** (rows, K, rank) so the per-partition blocks
    concatenate over the row axis — ``combine="concat"`` then broadcasts
    all K completed factors with one collective, exactly as it broadcasts
    one factor in the single-model sweep."""
    out = jax.vmap(lambda Y, lam: _local_als(block, Y, lam))(Ys, lams)
    return jnp.moveaxis(out, 0, 1)                           # (rows, K, rank)


class BroadcastALS(NumericAlgorithm[ALSParameters, MatrixFactorizationModel]):
    """Instance-based Estimator: ``BroadcastALS(rank=10).fit(packed,
    data_transposed=packed_T) -> (U, V) model`` (the legacy ``train``
    classmethod is an inherited deprecation shim passing
    ``data_transposed`` through)."""

    Parameters = ALSParameters
    supervised = False

    @classmethod
    def compute_factor(cls, train_data: MLNumericTable, fixed_factor: jnp.ndarray,
                       lam: float,
                       schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.GATHER_BROADCAST,
                       ) -> jnp.ndarray:
        """Fig. A9 ``computeFactor``: one half-sweep through the same
        runner call ``train`` uses — solve the partition's factor rows
        locally, broadcast the completed factor under ``schedule``."""
        runner = DistributedRunner.for_table(train_data, schedule=schedule)
        return runner.partition_apply(train_data.data, _local_als,
                                      (fixed_factor, lam), combine="concat")

    def fit(self, data: MLNumericTable,
            data_transposed: Optional[MLNumericTable] = None,
            ) -> MatrixFactorizationModel:
        if data_transposed is None:
            raise ValueError("BroadcastALS.fit requires the transposed ratings "
                             "table (the paper distributes both M and Mᵀ)")
        p = self.params
        m, n = data.num_rows, data_transposed.num_rows
        key_u, key_v = jax.random.split(jax.random.PRNGKey(p.seed))
        # paper: LocalMatrix.rand init
        U = jax.random.uniform(key_u, (m, p.rank), jnp.float32)
        V = jax.random.uniform(key_v, (n, p.rank), jnp.float32)

        # The whole alternating loop runs as ONE jitted scan so the 2·max_iter
        # half-sweeps compile once (eager per-round dispatch would
        # retrace/recompile the shard_map every call).  Each half-sweep is
        # runner.partition_apply with combine="concat": solve the partition's
        # factor rows locally, then re-broadcast the completed factor under
        # the configured schedule (Fig. A9's broadcast).
        runner = DistributedRunner.for_table(data, schedule=p.schedule)

        @jax.jit
        def run(data_arr, dataT_arr, U0, V0):
            def body(carry, _):
                U, V = carry
                U = runner.partition_apply(data_arr, _local_als, (V, p.lam),
                                           combine="concat")
                V = runner.partition_apply(dataT_arr, _local_als, (U, p.lam),
                                           combine="concat")
                return (U, V), None

            (U1, V1), _ = jax.lax.scan(body, (U0, V0), None, length=p.max_iter)
            return U1, V1

        U, V = run(data.data, data_transposed.data, U, V)
        return MatrixFactorizationModel(U, V, p)

    def rebuild(self, partial) -> MatrixFactorizationModel:
        return MatrixFactorizationModel(jnp.asarray(partial["U"]),
                                        jnp.asarray(partial["V"]),
                                        self.params)

    @classmethod
    def train(cls, data: MLNumericTable,
              params: Optional[ALSParameters] = None,
              data_transposed: Optional[MLNumericTable] = None,
              ) -> MatrixFactorizationModel:
        """Deprecated positional-``data_transposed`` spelling; delegates to
        ``cls(params).fit(data, data_transposed=…)`` (bit-identical)."""
        from repro.core.interfaces import _warn_deprecated

        _warn_deprecated(
            f"{cls.__name__}.train(data, params, data_transposed)",
            f"{cls.__name__}(params).fit(data, data_transposed=…)")
        return cls(params).fit(data, data_transposed=data_transposed)

    @classmethod
    def train_stacked(cls, data: MLNumericTable,
                      params_list: list,
                      data_transposed: MLNumericTable,
                      ) -> list:
        """Trial-stackable ALS: factor the SAME ratings under K parameter
        configurations at once (model search over ``lam`` / ``seed``).

        The K regularizers ride as a traced (K,) vector and the factors
        carry a leading trial axis — each half-sweep runs all K
        normal-equation solves in one vmapped ``partition_apply`` and
        re-broadcasts all K completed factors with ONE ``combine="concat"``
        collective (trial axis packed behind the row axis, so the Fig. A9
        wire pattern is unchanged).  ``rank`` and ``max_iter`` must agree
        across configs (they set the compiled loop structure); ragged
        configs belong in separate calls.  Returns one
        :class:`MatrixFactorizationModel` per config, each matching its
        sequentially-trained twin to fp tolerance
        (``tests/test_tune.py``).
        """
        ps = [p or cls.default_parameters() for p in params_list]
        if not ps:
            raise ValueError("params_list must not be empty")
        for field in ("rank", "max_iter"):
            vals = {getattr(p, field) for p in ps}
            if len(vals) > 1:
                raise ValueError(
                    f"stacked ALS trials must share {field}, got {sorted(vals)}"
                    f" — run ragged configs in separate calls")
        p0 = ps[0]
        m, n = data.num_rows, data_transposed.num_rows
        lams = jnp.asarray([p.lam for p in ps], jnp.float32)
        inits = []
        for p in ps:
            key_u, key_v = jax.random.split(jax.random.PRNGKey(p.seed))
            inits.append((jax.random.uniform(key_u, (m, p0.rank), jnp.float32),
                          jax.random.uniform(key_v, (n, p0.rank), jnp.float32)))
        U0 = jnp.stack([u for u, _ in inits])                 # (K, m, rank)
        V0 = jnp.stack([v for _, v in inits])                 # (K, n, rank)

        runner = DistributedRunner.for_table(data, schedule=p0.schedule)

        def half_sweep(ratings: jnp.ndarray, fixed: jnp.ndarray) -> jnp.ndarray:
            rows_major = runner.partition_apply(
                ratings, _local_als_stacked, (fixed, lams), combine="concat")
            return jnp.moveaxis(rows_major, 1, 0)             # (K, rows, rank)

        @jax.jit
        def run(data_arr, dataT_arr, U0, V0):
            def body(carry, _):
                U, V = carry
                U = half_sweep(data_arr, V)
                V = half_sweep(dataT_arr, U)
                return (U, V), None

            (U1, V1), _ = jax.lax.scan(body, (U0, V0), None, length=p0.max_iter)
            return U1, V1

        U, V = run(data.data, data_transposed.data, U0, V0)
        return [MatrixFactorizationModel(U[i], V[i], ps[i])
                for i in range(len(ps))]
