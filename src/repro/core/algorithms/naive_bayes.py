"""Gaussian Naive Bayes through the MLI contract (beyond-paper, same
purpose as pca.py: the API extends to non-gradient algorithms).

Pattern: ONE pass of the pure local function :func:`_local_stats` emits
per-partition sufficient statistics for every class (count, Σx, Σx² as a
fixed-shape block); :class:`repro.core.runner.DistributedRunner` performs
the global sum under the configured :class:`CollectiveSchedule`; closed-form
class-conditional Gaussians follow.  Labels in column 0 as integers
0..C−1."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.collectives import CollectiveSchedule
from repro.core.interfaces import Model, NumericAlgorithm
from repro.core.numeric_table import MLNumericTable
from repro.core.runner import DistributedRunner

__all__ = ["NaiveBayesParameters", "NaiveBayesModel", "GaussianNaiveBayes"]


@dataclasses.dataclass
class NaiveBayesParameters:
    num_classes: int = 2
    var_smoothing: float = 1e-6
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE


def _local_stats(block: jnp.ndarray, num_classes: int) -> jnp.ndarray:
    """Pure local function: a partition's (C, 1+2d) block [count | Σx | Σx²]."""
    y = block[:, 0].astype(jnp.int32)
    x = block[:, 1:]
    onehot = jax.nn.one_hot(y, num_classes, dtype=x.dtype)  # (rows, C)
    cnt = jnp.sum(onehot, axis=0)[:, None]                  # (C, 1)
    s1 = onehot.T @ x                                       # (C, d)
    s2 = onehot.T @ (x * x)                                 # (C, d)
    return jnp.concatenate([cnt, s1, s2], axis=1)


class NaiveBayesModel(Model):
    def __init__(self, priors, means, variances):
        self.priors = priors          # (C,)
        self.means = means            # (C, d)
        self.variances = variances    # (C, d)

    def predict_log_proba(self, x: jnp.ndarray) -> jnp.ndarray:
        """(n, d) -> (n, C) unnormalized log posterior."""
        x = x[:, None, :]                                     # (n, 1, d)
        ll = -0.5 * (jnp.log(2 * jnp.pi * self.variances)
                     + (x - self.means) ** 2 / self.variances)
        return jnp.sum(ll, axis=-1) + jnp.log(self.priors)

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.argmax(self.predict_log_proba(x), axis=-1)

    @property
    def partial(self):
        return {"priors": self.priors, "means": self.means,
                "variances": self.variances}


class GaussianNaiveBayes(NumericAlgorithm[NaiveBayesParameters, NaiveBayesModel]):
    """Instance-based Estimator: ``GaussianNaiveBayes(num_classes=3)
    .fit(table)``."""

    Parameters = NaiveBayesParameters
    supervised = True

    def fit(self, data: MLNumericTable) -> NaiveBayesModel:
        p = self.params
        C = p.num_classes
        d = data.num_cols - 1
        n = data.num_rows

        runner = DistributedRunner.for_table(data, schedule=p.schedule)
        tot = runner.run_once(data, partial(_local_stats, num_classes=C),
                              combine="sum")                   # (C, 1+2d)
        cnt = jnp.maximum(tot[:, 0], 1.0)                      # (C,)
        mean = tot[:, 1:1 + d] / cnt[:, None]
        var = tot[:, 1 + d:] / cnt[:, None] - mean ** 2
        var = jnp.maximum(var, 0.0) + p.var_smoothing
        priors = cnt / n
        return NaiveBayesModel(priors, mean, var)

    def rebuild(self, partial) -> NaiveBayesModel:
        return NaiveBayesModel(jnp.asarray(partial["priors"]),
                               jnp.asarray(partial["means"]),
                               jnp.asarray(partial["variances"]))
