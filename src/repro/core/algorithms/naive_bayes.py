"""Gaussian Naive Bayes through the MLI contract (beyond-paper, same
purpose as pca.py: the API extends to non-gradient algorithms).

Pattern: ONE ``matrixBatchMap`` pass emits per-partition sufficient
statistics for every class (count, Σx, Σx² as a fixed-shape block), one
explicit global sum, closed-form class-conditional Gaussians.  Labels in
column 0 as integers 0..C−1."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.interfaces import Model, NumericAlgorithm
from repro.core.local_matrix import LocalMatrix
from repro.core.numeric_table import MLNumericTable

__all__ = ["NaiveBayesParameters", "NaiveBayesModel", "GaussianNaiveBayes"]


@dataclasses.dataclass
class NaiveBayesParameters:
    num_classes: int = 2
    var_smoothing: float = 1e-6


class NaiveBayesModel(Model):
    def __init__(self, priors, means, variances):
        self.priors = priors          # (C,)
        self.means = means            # (C, d)
        self.variances = variances    # (C, d)

    def predict_log_proba(self, x: jnp.ndarray) -> jnp.ndarray:
        """(n, d) -> (n, C) unnormalized log posterior."""
        x = x[:, None, :]                                     # (n, 1, d)
        ll = -0.5 * (jnp.log(2 * jnp.pi * self.variances)
                     + (x - self.means) ** 2 / self.variances)
        return jnp.sum(ll, axis=-1) + jnp.log(self.priors)

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.argmax(self.predict_log_proba(x), axis=-1)


class GaussianNaiveBayes(NumericAlgorithm[NaiveBayesParameters, NaiveBayesModel]):
    @classmethod
    def default_parameters(cls) -> NaiveBayesParameters:
        return NaiveBayesParameters()

    @classmethod
    def train(cls, data: MLNumericTable,
              params: Optional[NaiveBayesParameters] = None) -> NaiveBayesModel:
        p = params or cls.default_parameters()
        C = p.num_classes
        d = data.num_cols - 1
        n = data.num_rows

        def local_stats(m: LocalMatrix) -> LocalMatrix:
            y = m.data[:, 0].astype(jnp.int32)
            x = m.data[:, 1:]
            onehot = jax.nn.one_hot(y, C, dtype=x.dtype)       # (rows, C)
            cnt = jnp.sum(onehot, axis=0)[:, None]             # (C, 1)
            s1 = onehot.T @ x                                  # (C, d)
            s2 = onehot.T @ (x * x)                            # (C, d)
            return LocalMatrix(jnp.concatenate([cnt, s1, s2], axis=1))

        blocks = data.matrix_batch_map(local_stats)            # (P·C, 1+2d)
        stacked = blocks.data.reshape(data.num_shards, C, 1 + 2 * d)
        tot = jnp.sum(stacked, axis=0)                         # explicit sum
        cnt = jnp.maximum(tot[:, 0], 1.0)                      # (C,)
        mean = tot[:, 1:1 + d] / cnt[:, None]
        var = tot[:, 1 + d:] / cnt[:, None] - mean ** 2
        var = jnp.maximum(var, 0.0) + p.var_smoothing
        priors = cnt / n
        return NaiveBayesModel(priors, mean, var)
