"""Generalized linear models (paper §IV: '...naturally extend to a diverse
group of ML algorithms, e.g., linear SVMs, linear regression, and (L1, L2,
elastic net)-regularized variants therein, simply by changing the expression
of the gradient function (and adding a proximal operator in the case of
L1-regularization)').

This module is that sentence, executed: one GLM trainer parameterized by a
loss-gradient expression and a Regularization spec.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.collectives import CollectiveSchedule
from repro.core.interfaces import Model, NumericAlgorithm
from repro.core.numeric_table import MLNumericTable
from repro.core.optimizer import (
    StochasticGradientDescent,
    StochasticGradientDescentParameters,
    soft_threshold,
)

__all__ = [
    "Regularization",
    "GeneralizedLinearModel",
    "LinearRegressionParameters",
    "LinearRegressionAlgorithm",
    "LinearRegression",
    "LinearSVMParameters",
    "LinearSVMAlgorithm",
    "LinearSVM",
]


@dataclasses.dataclass
class Regularization:
    l1: float = 0.0
    l2: float = 0.0

    @classmethod
    def elastic_net(cls, alpha: float, l1_ratio: float) -> "Regularization":
        return cls(l1=alpha * l1_ratio, l2=alpha * (1.0 - l1_ratio))


class GeneralizedLinearModel(Model):
    def __init__(self, weights: jnp.ndarray,
                 link: Callable[[jnp.ndarray], jnp.ndarray] = lambda z: z):
        self.weights = weights
        self.link = link

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.link(x @ self.weights)

    @property
    def partial(self):
        return {"weights": self.weights}


def _train_glm(data: MLNumericTable, loss_grad, reg: Regularization,
               learning_rate: float, max_iter: int, local_batch_size: int,
               schedule) -> jnp.ndarray:
    d = data.num_cols - 1

    def gradient(vec: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        x, y = vec[1:], vec[0]
        g = loss_grad(x, y, w)
        if reg.l2:
            g = g + reg.l2 * w
        return g

    prox = soft_threshold(reg.l1) if reg.l1 else None
    opt = StochasticGradientDescent(StochasticGradientDescentParameters(
        w_init=jnp.zeros((d,), jnp.float32), grad=gradient,
        learning_rate=learning_rate, max_iter=max_iter,
        local_batch_size=local_batch_size, schedule=schedule, prox=prox))
    return opt.apply(data, None)


# --------------------------------------------------------------------------- #
# Linear regression (squared loss)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class LinearRegressionParameters:
    learning_rate: float = 0.1
    max_iter: int = 20
    reg: Regularization = dataclasses.field(default_factory=Regularization)
    local_batch_size: int = 1
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE


class LinearRegressionAlgorithm(
    NumericAlgorithm[LinearRegressionParameters, GeneralizedLinearModel]
):
    """Instance-based Estimator: ``LinearRegressionAlgorithm(
    learning_rate=0.1).fit(table)``."""

    Parameters = LinearRegressionParameters
    supervised = True

    def fit(self, data: MLNumericTable) -> GeneralizedLinearModel:
        p = self.params

        def loss_grad(x, y, w):
            return x * (jnp.dot(x, w) - y)

        w = _train_glm(data, loss_grad, p.reg, p.learning_rate, p.max_iter,
                       p.local_batch_size, p.schedule)
        return GeneralizedLinearModel(w)

    def rebuild(self, partial) -> GeneralizedLinearModel:
        return GeneralizedLinearModel(jnp.asarray(partial["weights"]))


# --------------------------------------------------------------------------- #
# Linear SVM (hinge loss subgradient)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class LinearSVMParameters:
    learning_rate: float = 0.1
    max_iter: int = 20
    reg: Regularization = dataclasses.field(default_factory=lambda: Regularization(l2=1e-3))
    local_batch_size: int = 1
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE


class LinearSVMAlgorithm(
    NumericAlgorithm[LinearSVMParameters, GeneralizedLinearModel]
):
    """Labels are expected in {-1, +1} in column 0."""

    Parameters = LinearSVMParameters
    supervised = True

    def fit(self, data: MLNumericTable) -> GeneralizedLinearModel:
        p = self.params

        def loss_grad(x, y, w):
            margin = y * jnp.dot(x, w)
            return jnp.where(margin < 1.0, -y, 0.0) * x

        w = _train_glm(data, loss_grad, p.reg, p.learning_rate, p.max_iter,
                       p.local_batch_size, p.schedule)
        return GeneralizedLinearModel(w, link=jnp.sign)

    def rebuild(self, partial) -> GeneralizedLinearModel:
        return GeneralizedLinearModel(jnp.asarray(partial["weights"]),
                                      link=jnp.sign)


#: estimator-style aliases
LinearRegression = LinearRegressionAlgorithm
LinearSVM = LinearSVMAlgorithm
