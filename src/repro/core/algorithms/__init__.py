"""Algorithm library written against the MLI API (paper §IV)."""
from repro.core.algorithms.logistic_regression import (
    LogisticRegressionAlgorithm,
    LogisticRegressionModel,
    LogisticRegressionParameters,
)
from repro.core.algorithms.linear_models import (
    LinearRegressionAlgorithm,
    LinearRegressionParameters,
    LinearSVMAlgorithm,
    LinearSVMParameters,
    GeneralizedLinearModel,
    Regularization,
)
from repro.core.algorithms.als import (
    BroadcastALS,
    ALSParameters,
    MatrixFactorizationModel,
)
from repro.core.algorithms.kmeans import KMeans, KMeansParameters, KMeansModel

__all__ = [
    "LogisticRegressionAlgorithm", "LogisticRegressionModel", "LogisticRegressionParameters",
    "LinearRegressionAlgorithm", "LinearRegressionParameters",
    "LinearSVMAlgorithm", "LinearSVMParameters",
    "GeneralizedLinearModel", "Regularization",
    "BroadcastALS", "ALSParameters", "MatrixFactorizationModel",
    "KMeans", "KMeansParameters", "KMeansModel",
]
from repro.core.algorithms.pca import PCA, PCAModel, PCAParameters
from repro.core.algorithms.naive_bayes import (
    GaussianNaiveBayes,
    NaiveBayesModel,
    NaiveBayesParameters,
)
