"""K-Means clustering (the terminal stage of the paper's Fig. A2 pipeline:
``KMeans(featurizedTable, k=50)``).

Lloyd's algorithm expressed in MLI primitives: the per-partition compute is
the pure local function :func:`_local_stats` — each partition's (per-cluster
sum, count) statistics against the current centroids — and iteration +
global combination are delegated to
:class:`repro.core.runner.DistributedRunner`: each round the runner sums the
partition statistics with the configured :class:`CollectiveSchedule` and the
``update`` step rebuilds the centroids.  Empty clusters keep their previous
centroid.  The whole loop compiles to one jitted scan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collectives import CollectiveSchedule
from repro.core.interfaces import (
    Model,
    NumericAlgorithm,
    Searchable,
    StreamFitable,
)
from repro.core.numeric_table import MLNumericTable
from repro.core.runner import CheckpointPolicy, DistributedRunner

__all__ = ["KMeansParameters", "KMeansModel", "KMeans"]


@dataclasses.dataclass
class KMeansParameters:
    k: int = 8
    max_iter: int = 20
    seed: int = 0
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE
    use_kernel: bool = False  # route assignment through the Pallas kernel


def _assign(block: jnp.ndarray, centroids: jnp.ndarray,
            use_kernel: bool = False) -> jnp.ndarray:
    """Nearest-centroid assignment — THE Lloyd hot path (O(rows·k·d) per
    round).  ``use_kernel`` routes it through the fused pairwise-distance
    Pallas kernel (``repro.kernels.kmeans_assign``: one streamed matmul,
    centroid-norm add and argmin fused into the epilogue, no (rows, k, d)
    broadcast in HBM); the default is the jnp form, which the kernel's
    oracle matches (fp-parity tested in ``tests/test_kernels.py``)."""
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.kmeans_assign(block, centroids)
    d2 = jnp.sum((block[:, None, :] - centroids[None, :, :]) ** 2, axis=-1)
    return jnp.argmin(d2, axis=-1)


class KMeansModel(Model):
    def __init__(self, centroids: jnp.ndarray, params: KMeansParameters):
        self.centroids = centroids
        self.params = params

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        return _assign(x, self.centroids,
                       getattr(self.params, "use_kernel", False))

    def inertia(self, x: jnp.ndarray) -> jnp.ndarray:
        d2 = jnp.sum((x[:, None, :] - self.centroids[None, :, :]) ** 2, axis=-1)
        return jnp.sum(jnp.min(d2, axis=-1))

    @property
    def partial(self):
        return {"centroids": self.centroids}


def _local_stats(block: jnp.ndarray, centroids: jnp.ndarray,
                 use_kernel: bool = False) -> jnp.ndarray:
    """Pure local function: per-partition (k, d+1) [cluster sums | counts]."""
    assign = _assign(block, centroids, use_kernel)                # (rows,)
    onehot = jax.nn.one_hot(assign, centroids.shape[0], dtype=block.dtype)
    sums = onehot.T @ block                                       # (k, d)
    counts = jnp.sum(onehot, axis=0)[:, None]                     # (k, 1)
    return jnp.concatenate([sums, counts], axis=1)


def _centroid_update(centroids: jnp.ndarray, tot: jnp.ndarray) -> jnp.ndarray:
    """Rebuild centroids from combined (sums | counts) statistics; empty
    clusters keep their previous centroid.  The single copy of the Lloyd
    update rule — resident, streaming, and trial-stacked paths all call
    it, so the empty-cluster policy can never diverge between them."""
    d = centroids.shape[1]
    sums, counts = tot[:, :d], tot[:, d]
    return jnp.where(counts[:, None] > 0,
                     sums / jnp.maximum(counts[:, None], 1.0),
                     centroids)


# --------------------------------------------------------------------------- #
# trial-stackable form (model search; repro.tune)
# --------------------------------------------------------------------------- #
def _trial_stats(block: jnp.ndarray, centroids: jnp.ndarray, r: jnp.ndarray,
                 hyper: dict) -> jnp.ndarray:
    """Lloyd assignment statistics in trial form — k-means has no
    continuous hyperparameters, so ``hyper`` is empty and trials differ
    only in their seeded centroid init (and ``k``, which rides in the
    stack key)."""
    return _local_stats(block, centroids)


def _trial_update(centroids: jnp.ndarray, tot: jnp.ndarray, r: jnp.ndarray,
                  hyper: dict) -> jnp.ndarray:
    return _centroid_update(centroids, tot)


def _silhouette_score(val_table, centroids, schedule):
    from repro.eval import metrics as M

    return M.silhouette_lite(val_table, centroids, schedule=schedule)


class KMeans(NumericAlgorithm[KMeansParameters, KMeansModel],
             StreamFitable, Searchable):
    """Instance-based Estimator: ``KMeans(k=4, seed=0).fit(table) ->
    KMeansModel`` (the legacy ``train`` classmethod is an inherited
    deprecation shim)."""

    Parameters = KMeansParameters
    supervised = False

    def fit(self, data: MLNumericTable) -> KMeansModel:
        p = self.params
        n = data.num_rows
        if p.k > n:
            raise ValueError("k exceeds number of rows")
        # init: k distinct rows sampled without replacement (host-side choice,
        # device-side gather)
        perm = jax.random.permutation(jax.random.PRNGKey(p.seed), n)[: p.k]
        centroids = jnp.take(data.data, perm, axis=0)

        def local_step(block, centroids, r):
            return _local_stats(block, centroids, p.use_kernel)

        def update(centroids, tot, r):
            return _centroid_update(centroids, tot)

        runner = DistributedRunner.for_table(data, schedule=p.schedule)
        centroids = runner.run_rounds(data, centroids, local_step, p.max_iter,
                                      combine="sum", update=update)
        return KMeansModel(centroids, p)

    def rebuild(self, partial) -> KMeansModel:
        return KMeansModel(jnp.asarray(partial["centroids"]), self.params)

    def stream_state_template(self, num_cols: int) -> jnp.ndarray:
        """Shape/dtype template of the streaming-training carry (the
        centroids) for a table with ``num_cols`` feature columns."""
        return jnp.zeros((self.params.k, num_cols), jnp.float32)

    @classmethod
    def trial_spec(cls, config: dict, metric: str = "silhouette"):
        """One model-search trial (see :mod:`repro.tune`): search over
        ``seed`` (restarts) and ``k``.  Same-``k`` trials share centroid
        shapes and stack into one vmapped Lloyd round; different ``k``
        configs are ragged (separate groups).  Scored with
        :func:`repro.eval.metrics.silhouette_lite` on the validation view.
        """
        import dataclasses as _dc

        from repro.tune.trials import TrialSpec

        p = _dc.replace(cls.default_parameters(), **config)
        if metric != "silhouette":
            raise ValueError(f"unknown kmeans metric {metric!r} (silhouette)")
        if p.use_kernel:
            raise ValueError("model search does not stack the Pallas-kernel "
                             "assignment (trials vmap over one jnp round)")

        def init(table) -> jnp.ndarray:
            if p.k > table.num_rows:
                raise ValueError("k exceeds rows in the training view")
            perm = jax.random.permutation(
                jax.random.PRNGKey(p.seed), table.num_rows)[: p.k]
            return jnp.take(table.data, perm, axis=0)

        return TrialSpec(
            config=dict(config), hyper={}, init=init,
            local_step=_trial_stats, combine="sum", update=_trial_update,
            stack_key=("kmeans", int(p.k)), score=_silhouette_score,
            finalize=lambda c: KMeansModel(c, p))

    def fit_stream(self, stream, *,
                   num_epochs: Optional[int] = None, num_shards: int = 1,
                   chunks_per_epoch: Optional[int] = None,
                   checkpoint: Optional[CheckpointPolicy] = None,
                   resume: bool = False,
                   init_centroids: Optional[jnp.ndarray] = None,
                   store=None, staleness: int = 0,
                   allow_resize: bool = False,
                   trace: Optional[list] = None
                   ) -> KMeansModel:
        """Streaming Lloyd rounds over minibatch windows: every round
        re-assigns one window chunk to the current centroids, sums the
        per-partition (cluster sums, counts) statistics with the configured
        schedule, and rebuilds the centroids — mini-batch k-means in MLI
        primitives.  ``checkpoint``/``resume`` make long runs
        preemption-safe (see :meth:`repro.core.runner.DistributedRunner.
        run_epochs`).

        Centroids initialize from the first ``k`` rows of the stream's
        current window (peeked without consuming it) unless
        ``init_centroids`` is given; on resume the values are overwritten
        by the snapshot, so only the shape matters.

        ``store`` (a :class:`repro.core.exchange.ParamStore`) selects the
        stale-synchronous multi-host lane: every epoch this host publishes
        its (cluster sums, counts) statistics and rebuilds centroids from
        the cross-host sum under the ``staleness`` bound — requires
        ``chunks_per_epoch`` of 1 (the exchange round IS the Lloyd round).
        ``allow_resize=True`` lets a resumed run continue on a mesh of a
        different world size (elastic restart).
        """
        p = self.params
        if init_centroids is None:
            if not hasattr(stream, "source"):
                raise ValueError("pass init_centroids= for non-peekable streams")
            first = np.asarray(stream.source(stream.step)["data"])
            if p.k > first.shape[0]:
                raise ValueError("k exceeds rows in the first window")
            init_centroids = jnp.asarray(first[: p.k])

        def local_step(block, centroids, r):
            return _local_stats(block, centroids, p.use_kernel)

        def update(centroids, tot, r):
            return _centroid_update(centroids, tot)

        runner = DistributedRunner(mesh=getattr(stream, "mesh", None),
                                   num_shards=num_shards, schedule=p.schedule)
        epochs = num_epochs if num_epochs is not None else p.max_iter
        if store is not None:
            if resume:
                if checkpoint is None:
                    raise ValueError("resume=True requires a CheckpointPolicy")
                centroids = runner.resume_ssp(
                    checkpoint.ckpt_dir, stream, init_centroids, local_step,
                    epochs, store=store, staleness=staleness, combine="sum",
                    update=update, checkpoint=checkpoint, trace=trace)
            else:
                centroids = runner.run_epochs_ssp(
                    stream, init_centroids, local_step, epochs, store=store,
                    staleness=staleness, combine="sum", update=update,
                    chunks_per_epoch=chunks_per_epoch or 1,
                    checkpoint=checkpoint, trace=trace)
        elif resume:
            if checkpoint is None:
                raise ValueError("resume=True requires a CheckpointPolicy")
            centroids = runner.resume(checkpoint.ckpt_dir, stream,
                                      init_centroids, local_step, epochs,
                                      combine="sum", update=update,
                                      chunks_per_epoch=chunks_per_epoch,
                                      checkpoint=checkpoint,
                                      allow_resize=allow_resize)
        else:
            centroids = runner.run_epochs(stream, init_centroids, local_step,
                                          epochs, combine="sum", update=update,
                                          chunks_per_epoch=chunks_per_epoch or 1,
                                          checkpoint=checkpoint)
        return KMeansModel(centroids, p)
