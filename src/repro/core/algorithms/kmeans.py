"""K-Means clustering (the terminal stage of the paper's Fig. A2 pipeline:
``KMeans(featurizedTable, k=50)``).

Lloyd's algorithm expressed in MLI primitives: the per-partition compute is
the pure local function :func:`_local_stats` — each partition's (per-cluster
sum, count) statistics against the current centroids — and iteration +
global combination are delegated to
:class:`repro.core.runner.DistributedRunner`: each round the runner sums the
partition statistics with the configured :class:`CollectiveSchedule` and the
``update`` step rebuilds the centroids.  Empty clusters keep their previous
centroid.  The whole loop compiles to one jitted scan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.collectives import CollectiveSchedule
from repro.core.interfaces import Model, NumericAlgorithm
from repro.core.numeric_table import MLNumericTable
from repro.core.runner import DistributedRunner

__all__ = ["KMeansParameters", "KMeansModel", "KMeans"]


@dataclasses.dataclass
class KMeansParameters:
    k: int = 8
    max_iter: int = 20
    seed: int = 0
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE


class KMeansModel(Model):
    def __init__(self, centroids: jnp.ndarray, params: KMeansParameters):
        self.centroids = centroids
        self.params = params

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        d2 = jnp.sum((x[:, None, :] - self.centroids[None, :, :]) ** 2, axis=-1)
        return jnp.argmin(d2, axis=-1)

    def inertia(self, x: jnp.ndarray) -> jnp.ndarray:
        d2 = jnp.sum((x[:, None, :] - self.centroids[None, :, :]) ** 2, axis=-1)
        return jnp.sum(jnp.min(d2, axis=-1))


def _local_stats(block: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """Pure local function: per-partition (k, d+1) [cluster sums | counts]."""
    d2 = jnp.sum((block[:, None, :] - centroids[None, :, :]) ** 2, axis=-1)
    assign = jnp.argmin(d2, axis=-1)                              # (rows,)
    onehot = jax.nn.one_hot(assign, centroids.shape[0], dtype=block.dtype)
    sums = onehot.T @ block                                       # (k, d)
    counts = jnp.sum(onehot, axis=0)[:, None]                     # (k, 1)
    return jnp.concatenate([sums, counts], axis=1)


class KMeans(NumericAlgorithm[KMeansParameters, KMeansModel]):
    @classmethod
    def default_parameters(cls) -> KMeansParameters:
        return KMeansParameters()

    @classmethod
    def train(cls, data: MLNumericTable,
              params: Optional[KMeansParameters] = None) -> KMeansModel:
        p = params or cls.default_parameters()
        d = data.num_cols
        n = data.num_rows
        if p.k > n:
            raise ValueError("k exceeds number of rows")
        # init: k distinct rows sampled without replacement (host-side choice,
        # device-side gather)
        perm = jax.random.permutation(jax.random.PRNGKey(p.seed), n)[: p.k]
        centroids = jnp.take(data.data, perm, axis=0)

        def local_step(block, centroids, r):
            return _local_stats(block, centroids)

        def update(centroids, tot, r):
            sums, counts = tot[:, :d], tot[:, d]
            return jnp.where(counts[:, None] > 0,
                             sums / jnp.maximum(counts[:, None], 1.0),
                             centroids)

        runner = DistributedRunner.for_table(data, schedule=p.schedule)
        centroids = runner.run_rounds(data, centroids, local_step, p.max_iter,
                                      combine="sum", update=update)
        return KMeansModel(centroids, p)
