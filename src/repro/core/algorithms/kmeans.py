"""K-Means clustering (the terminal stage of the paper's Fig. A2 pipeline:
``KMeans(featurizedTable, k=50)``).

Lloyd's algorithm expressed in MLI primitives: each round, every partition
computes its local (per-cluster sum, count) statistics against the broadcast
centroids via ``matrixBatchMap``; the global combine is an explicit sum;
centroids update outside the partition function.  Empty clusters keep their
previous centroid.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.interfaces import Model, NumericAlgorithm
from repro.core.local_matrix import LocalMatrix
from repro.core.numeric_table import MLNumericTable

__all__ = ["KMeansParameters", "KMeansModel", "KMeans"]


@dataclasses.dataclass
class KMeansParameters:
    k: int = 8
    max_iter: int = 20
    seed: int = 0


class KMeansModel(Model):
    def __init__(self, centroids: jnp.ndarray, params: KMeansParameters):
        self.centroids = centroids
        self.params = params

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        d2 = jnp.sum((x[:, None, :] - self.centroids[None, :, :]) ** 2, axis=-1)
        return jnp.argmin(d2, axis=-1)

    def inertia(self, x: jnp.ndarray) -> jnp.ndarray:
        d2 = jnp.sum((x[:, None, :] - self.centroids[None, :, :]) ** 2, axis=-1)
        return jnp.sum(jnp.min(d2, axis=-1))


def _local_stats(block: LocalMatrix, centroids: jnp.ndarray) -> LocalMatrix:
    """Per-partition (k, d+1) matrix: [cluster sums | cluster counts]."""
    x = block.data                                            # (rows, d)
    d2 = jnp.sum((x[:, None, :] - centroids[None, :, :]) ** 2, axis=-1)
    assign = jnp.argmin(d2, axis=-1)                          # (rows,)
    onehot = jax.nn.one_hot(assign, centroids.shape[0], dtype=x.dtype)  # (rows, k)
    sums = onehot.T @ x                                       # (k, d)
    counts = jnp.sum(onehot, axis=0)[:, None]                 # (k, 1)
    return LocalMatrix(jnp.concatenate([sums, counts], axis=1))


class KMeans(NumericAlgorithm[KMeansParameters, KMeansModel]):
    @classmethod
    def default_parameters(cls) -> KMeansParameters:
        return KMeansParameters()

    @classmethod
    def train(cls, data: MLNumericTable,
              params: Optional[KMeansParameters] = None) -> KMeansModel:
        p = params or cls.default_parameters()
        d = data.num_cols
        n = data.num_rows
        if p.k > n:
            raise ValueError("k exceeds number of rows")
        # init: k distinct rows sampled without replacement (host-side choice,
        # device-side gather)
        perm = jax.random.permutation(jax.random.PRNGKey(p.seed), n)[: p.k]
        centroids = jnp.take(data.data, perm, axis=0)

        for _ in range(p.max_iter):
            stats = data.matrix_batch_map(_local_stats, centroids)
            # stats table: num_shards stacked (k, d+1) blocks -> global sum
            blocks = stats.data.reshape(data.num_shards, p.k, d + 1)
            tot = jnp.sum(blocks, axis=0)
            sums, counts = tot[:, :d], tot[:, d]
            centroids = jnp.where(counts[:, None] > 0,
                                  sums / jnp.maximum(counts[:, None], 1.0),
                                  centroids)
        return KMeansModel(centroids, p)
