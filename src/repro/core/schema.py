"""Column schema for MLTable (paper §III-A).

Columns are typed String / Integer / Boolean / Scalar; any cell may be
``Empty`` (represented by a singleton sentinel).  The schema governs which
relational / numeric operations are legal and how a table is committed to the
device tier (``MLNumericTable``).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Iterable, Optional, Sequence, Tuple

__all__ = [
    "ColumnType",
    "Column",
    "Schema",
    "MLRow",
    "EMPTY",
]


class _Empty:
    """Singleton sentinel for the paper's 'Empty' cell value."""

    _instance: Optional["_Empty"] = None

    def __new__(cls) -> "_Empty":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - trivial
        return "Empty"

    def __bool__(self) -> bool:
        return False


EMPTY = _Empty()


class ColumnType(enum.Enum):
    STRING = "string"
    INTEGER = "integer"
    BOOLEAN = "boolean"
    SCALAR = "scalar"

    @classmethod
    def infer(cls, value: Any) -> "ColumnType":
        if isinstance(value, bool):
            return cls.BOOLEAN
        if isinstance(value, int):
            return cls.INTEGER
        if isinstance(value, float):
            return cls.SCALAR
        if isinstance(value, str):
            return cls.STRING
        raise TypeError(f"cannot infer MLTable column type for {value!r}")

    @property
    def is_numeric(self) -> bool:
        return self in (ColumnType.INTEGER, ColumnType.SCALAR, ColumnType.BOOLEAN)


@dataclasses.dataclass(frozen=True)
class Column:
    ctype: ColumnType
    name: Optional[str] = None

    def validate(self, value: Any) -> None:
        if value is EMPTY:
            return
        expected = ColumnType.infer(value)
        ok = expected is self.ctype or (
            # ints are acceptable in scalar columns
            self.ctype is ColumnType.SCALAR
            and expected is ColumnType.INTEGER
        )
        if not ok:
            raise TypeError(
                f"value {value!r} of type {expected} does not conform to column "
                f"{self.name or '<anon>'}:{self.ctype}"
            )


@dataclasses.dataclass(frozen=True)
class Schema:
    columns: Tuple[Column, ...]

    @classmethod
    def of(cls, *ctypes: ColumnType, names: Optional[Sequence[str]] = None) -> "Schema":
        if names is None:
            names = [None] * len(ctypes)  # type: ignore[list-item]
        if len(names) != len(ctypes):
            raise ValueError("names/ctypes length mismatch")
        return cls(tuple(Column(t, n) for t, n in zip(ctypes, names)))

    @classmethod
    def infer_from_row(cls, row: Sequence[Any], names: Optional[Sequence[str]] = None) -> "Schema":
        ctypes = [ColumnType.infer(v) if v is not EMPTY else ColumnType.SCALAR for v in row]
        return cls.of(*ctypes, names=names)

    def __len__(self) -> int:
        return len(self.columns)

    @property
    def names(self) -> Tuple[Optional[str], ...]:
        return tuple(c.name for c in self.columns)

    @property
    def is_numeric(self) -> bool:
        return all(c.ctype.is_numeric for c in self.columns)

    def index_of(self, name: str) -> int:
        for i, c in enumerate(self.columns):
            if c.name == name:
                return i
        raise KeyError(f"no column named {name!r}")

    def project(self, indices: Sequence[int]) -> "Schema":
        return Schema(tuple(self.columns[i] for i in indices))

    def validate_row(self, row: Sequence[Any]) -> None:
        if len(row) != len(self.columns):
            raise ValueError(
                f"row arity {len(row)} does not match schema arity {len(self.columns)}"
            )
        for col, v in zip(self.columns, row):
            col.validate(v)

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, Schema):
            return NotImplemented
        return tuple(c.ctype for c in self.columns) == tuple(c.ctype for c in other.columns)

    def __hash__(self) -> int:
        return hash(tuple(c.ctype for c in self.columns))


class MLRow(tuple):
    """A single table row.  Immutable; cells accessed by index or column name.

    The paper's MLRow supports positional access and conversion to feature
    vectors; we attach the schema for name-based access.
    """

    schema: Optional[Schema]

    def __new__(cls, values: Iterable[Any], schema: Optional[Schema] = None) -> "MLRow":
        obj = super().__new__(cls, tuple(values))
        obj.schema = schema
        return obj

    def get(self, key: Any) -> Any:
        if isinstance(key, str):
            if self.schema is None:
                raise KeyError("row has no schema; name-based access unavailable")
            return self[self.schema.index_of(key)]
        return self[key]

    def is_empty(self, i: int) -> bool:
        return self[i] is EMPTY

    def to_floats(self) -> Tuple[float, ...]:
        out = []
        for v in self:
            if v is EMPTY:
                out.append(float("nan"))
            elif isinstance(v, bool):
                out.append(1.0 if v else 0.0)
            elif isinstance(v, (int, float)):
                out.append(float(v))
            else:
                raise TypeError(f"non-numeric cell {v!r} cannot be converted to float")
        return tuple(out)
