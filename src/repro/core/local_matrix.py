"""LocalMatrix (paper §III-B, API table Fig. A3).

A MATLAB-style linear-algebra object over a *partition* of the data.  On the
TPU runtime the "partition" is the per-device block that shard_map hands to
the partition function, so LocalMatrix is a registered pytree wrapping a
``jnp`` array and is fully usable inside ``jax.jit`` / ``shard_map`` traces.

Design notes (hardware adaptation, see DESIGN.md §2):
  * TPU programs need static shapes, so `nonZeroIndices` returns a fixed-width
    padded index vector plus validity mask instead of a ragged Seq[Index]; the
    companion `PaddedCSR` gives ALS the CSR-style row access the paper uses.
  * `solve` uses a symmetrize-and-solve path (jnp.linalg.solve) matching the
    normal-equation usage in the paper's ALS; `svd`/`eigen`/`rank` map to
    lax-backed jnp.linalg routines.
  * Arithmetic follows Fig. A3: `+ - * /` are element-wise, `times` is matrix
    multiplication, `dot` is the scalar inner product, `on`/`then` compose
    row-wise/column-wise.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LocalMatrix", "PaddedCSR"]

ArrayLike = Union[jnp.ndarray, np.ndarray, float, int]


def _unwrap(x: Any) -> Any:
    return x.data if isinstance(x, LocalMatrix) else x


@jax.tree_util.register_pytree_node_class
class LocalMatrix:
    """Dense partition-local matrix with a MATLAB-flavoured API."""

    def __init__(self, data: ArrayLike):
        arr = jnp.asarray(_unwrap(data))
        if arr.ndim == 1:
            arr = arr[:, None]
        if arr.ndim != 2:
            raise ValueError(f"LocalMatrix must be 2-D, got shape {arr.shape}")
        self.data = arr

    # pytree protocol ---------------------------------------------------- #
    def tree_flatten(self):
        return (self.data,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        obj = object.__new__(cls)
        obj.data = children[0]
        return obj

    # constructors -------------------------------------------------------- #
    @classmethod
    def zeros(cls, m: int, n: int = 1, dtype=jnp.float32) -> "LocalMatrix":
        return cls(jnp.zeros((m, n), dtype))

    @classmethod
    def ones(cls, m: int, n: int = 1, dtype=jnp.float32) -> "LocalMatrix":
        return cls(jnp.ones((m, n), dtype))

    @classmethod
    def eye(cls, n: int, dtype=jnp.float32) -> "LocalMatrix":
        return cls(jnp.eye(n, dtype=dtype))

    @classmethod
    def rand(cls, m: int, n: int, key: jax.Array = None, dtype=jnp.float32) -> "LocalMatrix":
        if key is None:
            key = jax.random.PRNGKey(0)
        return cls(jax.random.uniform(key, (m, n), dtype))

    # shape (Fig. A3 "Shape" family) --------------------------------------- #
    @property
    def num_rows(self) -> int:
        return self.data.shape[0]

    @property
    def num_cols(self) -> int:
        return self.data.shape[1]

    numRows, numCols = num_rows, num_cols  # paper spelling

    @property
    def dims(self) -> Tuple[int, int]:
        return self.data.shape  # type: ignore[return-value]

    @property
    def shape(self) -> Tuple[int, int]:
        return self.data.shape  # type: ignore[return-value]

    @property
    def dtype(self):
        return self.data.dtype

    # composition (Fig. A3 "Composition") ---------------------------------- #
    def on(self, other: "LocalMatrix") -> "LocalMatrix":
        """Stack row-wise: ``matA on matB``."""
        return LocalMatrix(jnp.concatenate([self.data, _unwrap(other)], axis=0))

    def then(self, other: "LocalMatrix") -> "LocalMatrix":
        """Concatenate column-wise: ``matA then matB``."""
        return LocalMatrix(jnp.concatenate([self.data, _unwrap(other)], axis=1))

    # indexing (Fig. A3 "Indexing"/"Updating") ------------------------------ #
    def __getitem__(self, key) -> "LocalMatrix":
        out = self.data[key]
        if out.ndim == 0:
            return out  # scalar passthrough (paper returns Scalar)
        return LocalMatrix(out)

    def row(self, i) -> "LocalMatrix":
        return LocalMatrix(self.data[i, :][None, :])

    def col(self, j) -> "LocalMatrix":
        return LocalMatrix(self.data[:, j][:, None])

    def slice_rows(self, idx) -> "LocalMatrix":
        return LocalMatrix(jnp.take(self.data, jnp.asarray(idx), axis=0))

    def updated(self, key, value: ArrayLike) -> "LocalMatrix":
        """Functional update (JAX arrays are immutable: ``mat(1,2)=5`` becomes
        ``mat = mat.updated((1,2), 5)``)."""
        return LocalMatrix(self.data.at[key].set(_unwrap(value)))

    def non_zero_indices(self, row: int, max_nnz: int = None):
        """Padded analogue of Fig. A3 ``mat(0,??).nonZeroIndices``.

        Returns ``(indices, mask)`` where indices has static length
        ``max_nnz`` (default: num_cols); invalid slots hold 0 and mask=False.
        """
        r = self.data[row]
        if max_nnz is None:
            max_nnz = self.num_cols
        nz = r != 0
        order = jnp.argsort(~nz)  # non-zeros first, stable
        idx = order[:max_nnz]
        mask = nz[idx]
        return idx, mask

    nonZeroIndices = non_zero_indices  # paper spelling

    # arithmetic (Fig. A3 "Arithmetic") ------------------------------------- #
    def _binop(self, other: ArrayLike, op) -> "LocalMatrix":
        return LocalMatrix(op(self.data, _unwrap(other)))

    def __add__(self, o): return self._binop(o, jnp.add)
    def __radd__(self, o): return self._binop(o, lambda a, b: jnp.add(b, a))
    def __sub__(self, o): return self._binop(o, jnp.subtract)
    def __rsub__(self, o): return self._binop(o, lambda a, b: jnp.subtract(b, a))
    def __mul__(self, o): return self._binop(o, jnp.multiply)
    def __rmul__(self, o): return self._binop(o, lambda a, b: jnp.multiply(b, a))
    def __truediv__(self, o): return self._binop(o, jnp.divide)
    def __neg__(self): return LocalMatrix(-self.data)

    plus = __add__      # paper: `_ plus _` in the SGD reducer
    minus = __sub__

    # linear algebra (Fig. A3 "Linear Algebra") ------------------------------ #
    def times(self, other: "LocalMatrix") -> "LocalMatrix":
        """Matrix-matrix (or matrix-vector) product: ``matA times matB``."""
        return LocalMatrix(self.data @ _unwrap(other))

    __matmul__ = times

    def dot(self, other: "LocalMatrix"):
        """Scalar inner product of two vectors."""
        a, b = self.data.reshape(-1), jnp.asarray(_unwrap(other)).reshape(-1)
        return jnp.dot(a, b)

    @property
    def T(self) -> "LocalMatrix":
        return LocalMatrix(self.data.T)

    def transpose(self) -> "LocalMatrix":
        return self.T

    def solve(self, rhs: ArrayLike) -> "LocalMatrix":
        """Solve ``self @ x = rhs`` (paper: ``matA.solve(v)``)."""
        b = jnp.asarray(_unwrap(rhs))
        squeeze = b.ndim == 1
        if squeeze:
            b = b[:, None]
        x = jnp.linalg.solve(self.data, b)
        return LocalMatrix(x)

    def inverse(self) -> "LocalMatrix":
        return LocalMatrix(jnp.linalg.inv(self.data))

    def svd(self):
        u, s, vt = jnp.linalg.svd(self.data, full_matrices=False)
        return LocalMatrix(u), s, LocalMatrix(vt)

    def eigen(self):
        w, v = jnp.linalg.eigh(self.data)
        return w, LocalMatrix(v)

    def rank(self, tol: float = 1e-6):
        s = jnp.linalg.svd(self.data, compute_uv=False)
        return jnp.sum(s > tol * s[0])

    def norm(self, ord=None):
        return jnp.linalg.norm(self.data, ord=ord)

    # conversion ------------------------------------------------------------ #
    def to_vector(self) -> jnp.ndarray:
        return self.data.reshape(-1)

    toVector = to_vector  # paper spelling

    def to_numpy(self) -> np.ndarray:
        return np.asarray(self.data)

    def __repr__(self) -> str:  # pragma: no cover
        return f"LocalMatrix(shape={tuple(self.data.shape)}, dtype={self.data.dtype})"


@jax.tree_util.register_pytree_node_class
class PaddedCSR:
    """Fixed-width CSR-style sparse rows (TPU-static analogue of the paper's
    CSR-compressed LocalMatrix support used by ALS).

    Each of the ``m`` rows stores up to ``max_nnz`` (column-index, value)
    pairs plus a validity mask.  ``row_indices/row_values/row_mask`` give ALS
    the `nonZeroIndices` + `nonZeroProjection` access pattern from Fig. A9.
    """

    def __init__(self, indices: jnp.ndarray, values: jnp.ndarray, mask: jnp.ndarray):
        self.indices = jnp.asarray(indices)
        self.values = jnp.asarray(values)
        self.mask = jnp.asarray(mask)
        if not (self.indices.shape == self.values.shape == self.mask.shape):
            raise ValueError("indices/values/mask shapes must match")

    def tree_flatten(self):
        return (self.indices, self.values, self.mask), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        obj = object.__new__(cls)
        obj.indices, obj.values, obj.mask = children
        return obj

    @property
    def num_rows(self) -> int:
        return self.indices.shape[0]

    @property
    def max_nnz(self) -> int:
        return self.indices.shape[1]

    @classmethod
    def from_dense(cls, dense: np.ndarray, max_nnz: int = None) -> "PaddedCSR":
        dense = np.asarray(dense)
        m, _ = dense.shape
        nnz_per_row = (dense != 0).sum(axis=1)
        if max_nnz is None:
            max_nnz = int(nnz_per_row.max()) if m else 0
        idx = np.zeros((m, max_nnz), dtype=np.int32)
        val = np.zeros((m, max_nnz), dtype=dense.dtype)
        msk = np.zeros((m, max_nnz), dtype=bool)
        for i in range(m):
            nz = np.nonzero(dense[i])[0][:max_nnz]
            idx[i, : len(nz)] = nz
            val[i, : len(nz)] = dense[i, nz]
            msk[i, : len(nz)] = True
        return cls(jnp.asarray(idx), jnp.asarray(val), jnp.asarray(msk))

    @classmethod
    def from_coo(cls, rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 num_rows: int, max_nnz: int) -> "PaddedCSR":
        idx = np.zeros((num_rows, max_nnz), dtype=np.int32)
        val = np.zeros((num_rows, max_nnz), dtype=np.float32)
        msk = np.zeros((num_rows, max_nnz), dtype=bool)
        fill = np.zeros(num_rows, dtype=np.int64)
        for r, c, v in zip(rows, cols, vals):
            k = fill[r]
            if k < max_nnz:
                idx[r, k], val[r, k], msk[r, k] = c, v, True
                fill[r] += 1
        return cls(jnp.asarray(idx), jnp.asarray(val), jnp.asarray(msk))

    def to_dense(self, num_cols: int) -> LocalMatrix:
        m = self.num_rows
        dense = jnp.zeros((m, num_cols), self.values.dtype)
        rows = jnp.arange(m)[:, None].repeat(self.max_nnz, axis=1)
        dense = dense.at[rows, self.indices].add(jnp.where(self.mask, self.values, 0.0))
        return LocalMatrix(dense)

    def gather_rows_of(self, factor: jnp.ndarray, row: int):
        """Return (Yq, ratings, mask) for one sparse row — the Fig. A9 access
        pattern ``Y.getRows(tuple.nonZeroIndices)`` with static shapes."""
        cols = self.indices[row]
        yq = jnp.take(factor, cols, axis=0)           # (max_nnz, k)
        ratings = self.values[row]                    # (max_nnz,)
        mask = self.mask[row]
        return yq, ratings, mask
