"""Row partitioning of numeric data across a named mesh axis (paper §III-A).

The MLI paper's tables are *row-partitioned* collections: every algorithm
sees its partition as a local matrix and all global combination is explicit.
This module is the single place that knows how a (rows, features) array maps
onto partitions — both on a real device mesh (``NamedSharding`` over the
data axes) and in emulated mode (logical blocks on one device).

Used by :class:`repro.core.numeric_table.MLNumericTable` for placement and by
:class:`repro.core.runner.DistributedRunner` for execution, so the two layers
can never disagree about the partition layout.

See ``docs/architecture.md`` for where partitioning sits in the data flow.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "infer_data_axes",
    "data_spec",
    "num_data_shards",
    "check_rows_divisible",
    "pad_rows",
    "partition_rows",
    "unpartition_rows",
    "place_rows",
    "ResizePlan",
    "plan_resize",
]

#: Mesh axes that carry the paper's partition dimension, outermost first.
#: "pod" is the cross-pod data-parallel axis; "data" the in-pod one.
DATA_AXIS_CANDIDATES: Tuple[str, ...] = ("pod", "data")


def infer_data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The subset of ``mesh`` axes that carry data partitions, in the order
    rows are laid out (pod-major, then data)."""
    return tuple(a for a in DATA_AXIS_CANDIDATES if a in mesh.axis_names)


def data_spec(data_axes: Tuple[str, ...]) -> P:
    """PartitionSpec for a row-partitioned 2-D array: rows over the data
    axes, features replicated."""
    return P(data_axes, None)


def num_data_shards(mesh: Mesh, data_axes: Tuple[str, ...]) -> int:
    """Number of row partitions the mesh induces (product of data-axis sizes)."""
    return int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1


def check_rows_divisible(num_rows: int, num_shards: int, *, what: str = "partitions") -> None:
    """Raise if ``num_rows`` does not split evenly — MLI partitions are
    equal-sized by construction (pad first; see :func:`pad_rows`)."""
    if num_rows % num_shards != 0:
        raise ValueError(
            f"row count {num_rows} must divide evenly over {num_shards} {what} "
            f"(pad first)"
        )


def pad_rows(array: jnp.ndarray, num_shards: int) -> Tuple[jnp.ndarray, int]:
    """Zero-pad ``array`` rows up to a multiple of ``num_shards``.

    Returns ``(padded, n_pad)``; ``n_pad`` rows of zeros were appended.
    ``unpartition_rows(partition_rows(padded, s))[: rows]`` recovers the
    original — the round-trip property the tests pin down.
    """
    n = array.shape[0]
    n_pad = (-n) % num_shards
    if n_pad:
        pad = jnp.zeros((n_pad,) + array.shape[1:], array.dtype)
        array = jnp.concatenate([array, pad], axis=0)
    return array, n_pad


def partition_rows(array: jnp.ndarray, num_shards: int) -> jnp.ndarray:
    """Reshape (rows, ...) into (num_shards, rows/num_shards, ...) logical
    partition blocks.  Pure layout — works under jit; rows must divide."""
    check_rows_divisible(array.shape[0], num_shards)
    return array.reshape((num_shards, array.shape[0] // num_shards) + array.shape[1:])


def unpartition_rows(blocks: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`partition_rows`: (shards, rows, ...) -> (shards·rows, ...)."""
    return blocks.reshape((-1,) + blocks.shape[2:])


def place_rows(array: jnp.ndarray, mesh: Mesh, data_axes: Tuple[str, ...]) -> jnp.ndarray:
    """Put ``array`` on the mesh row-sharded over the data axes.

    Outside a trace this is a real ``device_put``; inside jit it becomes a
    sharding constraint so tables can be (re)built inside compiled code.
    """
    sharding = NamedSharding(mesh, data_spec(data_axes))
    if isinstance(array, jax.core.Tracer):
        return jax.lax.with_sharding_constraint(array, sharding)
    return jax.device_put(array, sharding)


# --------------------------------------------------------------------------- #
# elastic resize: repartitioning a row layout onto a different shard count
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ResizePlan:
    """How a row-partitioned layout maps onto a new shard count.

    Rows stay in global order on both sides (partitions are contiguous
    blocks), so the plan is fully described by the two block sizes; the
    derived fields quantify the data motion an elastic resize implies —
    ``moved_rows`` counts rows whose owning shard *index* changes, the wire
    cost of a live repartition.  Built by :func:`plan_resize`, consumed by
    :meth:`repro.core.runner.DistributedRunner.resume` (``allow_resize``)
    when a surviving mesh restarts from a checkpoint written at a different
    world size.
    """

    num_rows: int
    old_shards: int
    new_shards: int

    @property
    def old_rows_per_shard(self) -> int:
        return self.num_rows // self.old_shards

    @property
    def new_rows_per_shard(self) -> int:
        return self.num_rows // self.new_shards

    def owner(self, row: int, *, new: bool = True) -> int:
        """Shard index owning ``row`` under the new (or old) layout."""
        per = self.new_rows_per_shard if new else self.old_rows_per_shard
        return row // per

    @property
    def moved_rows(self) -> int:
        """Rows whose shard index changes between the layouts — the wire
        cost of a live repartition.  Zero exactly when the shard counts
        match (property-tested)."""
        return sum(1 for r in range(self.num_rows)
                   if self.owner(r, new=False) != self.owner(r, new=True))

    def describe(self) -> str:
        return (f"repartition {self.num_rows} rows: {self.old_shards} -> "
                f"{self.new_shards} shards ({self.old_rows_per_shard} -> "
                f"{self.new_rows_per_shard} rows/shard, {self.moved_rows} "
                f"rows change owner)")


def plan_resize(num_rows: int, old_shards: int, new_shards: int) -> ResizePlan:
    """Validate and describe an elastic resize of the row partition layout.

    Raises when the rows cannot split evenly over either side — the same
    equal-partition invariant as initial placement (pad first).
    """
    if old_shards < 1 or new_shards < 1:
        raise ValueError(
            f"shard counts must be >= 1, got {old_shards} -> {new_shards}")
    check_rows_divisible(num_rows, old_shards, what="old partitions")
    check_rows_divisible(num_rows, new_shards, what="new partitions")
    return ResizePlan(num_rows=num_rows, old_shards=old_shards,
                      new_shards=new_shards)
