"""Row partitioning of numeric data across a named mesh axis (paper §III-A).

The MLI paper's tables are *row-partitioned* collections: every algorithm
sees its partition as a local matrix and all global combination is explicit.
This module is the single place that knows how a (rows, features) array maps
onto partitions — both on a real device mesh (``NamedSharding`` over the
data axes) and in emulated mode (logical blocks on one device).

Used by :class:`repro.core.numeric_table.MLNumericTable` for placement and by
:class:`repro.core.runner.DistributedRunner` for execution, so the two layers
can never disagree about the partition layout.

See ``docs/architecture.md`` for where partitioning sits in the data flow.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "infer_data_axes",
    "data_spec",
    "num_data_shards",
    "check_rows_divisible",
    "pad_rows",
    "partition_rows",
    "unpartition_rows",
    "place_rows",
]

#: Mesh axes that carry the paper's partition dimension, outermost first.
#: "pod" is the cross-pod data-parallel axis; "data" the in-pod one.
DATA_AXIS_CANDIDATES: Tuple[str, ...] = ("pod", "data")


def infer_data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The subset of ``mesh`` axes that carry data partitions, in the order
    rows are laid out (pod-major, then data)."""
    return tuple(a for a in DATA_AXIS_CANDIDATES if a in mesh.axis_names)


def data_spec(data_axes: Tuple[str, ...]) -> P:
    """PartitionSpec for a row-partitioned 2-D array: rows over the data
    axes, features replicated."""
    return P(data_axes, None)


def num_data_shards(mesh: Mesh, data_axes: Tuple[str, ...]) -> int:
    """Number of row partitions the mesh induces (product of data-axis sizes)."""
    return int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1


def check_rows_divisible(num_rows: int, num_shards: int, *, what: str = "partitions") -> None:
    """Raise if ``num_rows`` does not split evenly — MLI partitions are
    equal-sized by construction (pad first; see :func:`pad_rows`)."""
    if num_rows % num_shards != 0:
        raise ValueError(
            f"row count {num_rows} must divide evenly over {num_shards} {what} "
            f"(pad first)"
        )


def pad_rows(array: jnp.ndarray, num_shards: int) -> Tuple[jnp.ndarray, int]:
    """Zero-pad ``array`` rows up to a multiple of ``num_shards``.

    Returns ``(padded, n_pad)``; ``n_pad`` rows of zeros were appended.
    ``unpartition_rows(partition_rows(padded, s))[: rows]`` recovers the
    original — the round-trip property the tests pin down.
    """
    n = array.shape[0]
    n_pad = (-n) % num_shards
    if n_pad:
        pad = jnp.zeros((n_pad,) + array.shape[1:], array.dtype)
        array = jnp.concatenate([array, pad], axis=0)
    return array, n_pad


def partition_rows(array: jnp.ndarray, num_shards: int) -> jnp.ndarray:
    """Reshape (rows, ...) into (num_shards, rows/num_shards, ...) logical
    partition blocks.  Pure layout — works under jit; rows must divide."""
    check_rows_divisible(array.shape[0], num_shards)
    return array.reshape((num_shards, array.shape[0] // num_shards) + array.shape[1:])


def unpartition_rows(blocks: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`partition_rows`: (shards, rows, ...) -> (shards·rows, ...)."""
    return blocks.reshape((-1,) + blocks.shape[2:])


def place_rows(array: jnp.ndarray, mesh: Mesh, data_axes: Tuple[str, ...]) -> jnp.ndarray:
    """Put ``array`` on the mesh row-sharded over the data axes.

    Outside a trace this is a real ``device_put``; inside jit it becomes a
    sharding constraint so tables can be (re)built inside compiled code.
    """
    sharding = NamedSharding(mesh, data_spec(data_axes))
    if isinstance(array, jax.core.Tracer):
        return jax.lax.with_sharding_constraint(array, sharding)
    return jax.device_put(array, sharding)
