"""Host-tier MLTable (paper §III-A, API table Fig. A1).

This is the ETL / feature-extraction tier: rows live in host memory (numpy
object storage), partitioned into ``num_partitions`` chunks that model the
distributed partitioning of the Spark implementation.  Once featurized, a
table whose schema is fully numeric is committed to the device tier with
:meth:`MLTable.to_numeric`, producing an :class:`~repro.core.numeric_table.
MLNumericTable` sharded over the mesh ``data`` axis — from that point on all
compute is JAX/XLA.

Supported operations follow Fig. A1 of the paper:

    project, union, filter, join, map, flatMap, reduce, reduceByKey,
    matrixBatchMap (on the numeric tier), numRows, numCols
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.schema import EMPTY, ColumnType, MLRow, Schema

__all__ = ["MLTable"]


def _chunk(rows: List[MLRow], num_partitions: int) -> List[List[MLRow]]:
    """Split rows into contiguous, nearly-equal partitions (Spark-style)."""
    n = len(rows)
    num_partitions = max(1, num_partitions)
    base, extra = divmod(n, num_partitions)
    out, start = [], 0
    for p in range(num_partitions):
        size = base + (1 if p < extra else 0)
        out.append(rows[start : start + size])
        start += size
    return out


class MLTable:
    """A schema'd collection of rows, partitioned for data-local operation."""

    def __init__(
        self,
        partitions: Sequence[Sequence[MLRow]],
        schema: Schema,
        validate: bool = False,
    ) -> None:
        self._partitions: List[List[MLRow]] = [list(p) for p in partitions]
        self.schema = schema
        if validate:
            for row in self.rows():
                schema.validate_row(row)

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @classmethod
    def from_rows(
        cls,
        rows: Iterable[Sequence[Any]],
        schema: Optional[Schema] = None,
        names: Optional[Sequence[str]] = None,
        num_partitions: int = 4,
    ) -> "MLTable":
        materialized = [tuple(r) for r in rows]
        if not materialized and schema is None:
            raise ValueError("cannot infer schema from an empty table")
        if schema is None:
            schema = Schema.infer_from_row(materialized[0], names=names)
        mlrows = [MLRow(r, schema) for r in materialized]
        return cls(_chunk(mlrows, num_partitions), schema, validate=True)

    @classmethod
    def from_numpy(cls, array: np.ndarray, num_partitions: int = 4,
                   names: Optional[Sequence[str]] = None) -> "MLTable":
        if array.ndim != 2:
            raise ValueError("from_numpy expects a 2-D array")
        schema = Schema.of(*([ColumnType.SCALAR] * array.shape[1]), names=names)
        rows = [MLRow(tuple(float(v) for v in row), schema) for row in array]
        return cls(_chunk(rows, num_partitions), schema)

    @classmethod
    def from_text(cls, lines: Iterable[str], num_partitions: int = 4) -> "MLTable":
        """The paper's ``mc.textFile`` entry point: one STRING column per line."""
        schema = Schema.of(ColumnType.STRING, names=["text"])
        rows = [MLRow((ln.rstrip("\n"),), schema) for ln in lines]
        return cls(_chunk(rows, num_partitions), schema)

    # ------------------------------------------------------------------ #
    # introspection
    # ------------------------------------------------------------------ #
    @property
    def num_partitions(self) -> int:
        return len(self._partitions)

    @property
    def partitions(self) -> List[List[MLRow]]:
        return self._partitions

    def rows(self) -> Iterable[MLRow]:
        return itertools.chain.from_iterable(self._partitions)

    def collect(self) -> List[MLRow]:
        return list(self.rows())

    @property
    def num_rows(self) -> int:
        return sum(len(p) for p in self._partitions)

    @property
    def num_cols(self) -> int:
        return len(self.schema)

    # Fig A1 spells these numRows/numCols; keep aliases for API fidelity.
    numRows = num_rows
    numCols = num_cols

    # ------------------------------------------------------------------ #
    # relational operations (Fig. A1)
    # ------------------------------------------------------------------ #
    def project(self, indices: Sequence[Any]) -> "MLTable":
        """Select a subset of columns (by index or name)."""
        idx = [self.schema.index_of(i) if isinstance(i, str) else int(i) for i in indices]
        schema = self.schema.project(idx)
        parts = [[MLRow((r[i] for i in idx), schema) for r in p] for p in self._partitions]
        return MLTable(parts, schema)

    def union(self, other: "MLTable") -> "MLTable":
        if self.schema != other.schema:
            raise TypeError("union requires identical schemas")
        return MLTable(self._partitions + other._partitions, self.schema)

    def filter(self, pred: Callable[[MLRow], bool]) -> "MLTable":
        parts = [[r for r in p if pred(r)] for p in self._partitions]
        return MLTable(parts, self.schema)

    def join(self, other: "MLTable", on: Sequence[Any]) -> "MLTable":
        """Inner hash-join on shared columns (paper: join(MLTable, Seq[Index]))."""
        left_idx = [self.schema.index_of(i) if isinstance(i, str) else int(i) for i in on]
        right_idx = [other.schema.index_of(i) if isinstance(i, str) else int(i) for i in on]
        right_keep = [j for j in range(len(other.schema)) if j not in right_idx]
        schema = Schema(
            tuple(self.schema.columns) + tuple(other.schema.columns[j] for j in right_keep)
        )
        table: Dict[Tuple[Any, ...], List[MLRow]] = {}
        for r in other.rows():
            table.setdefault(tuple(r[j] for j in right_idx), []).append(r)
        parts: List[List[MLRow]] = []
        for p in self._partitions:
            out = []
            for r in p:
                for match in table.get(tuple(r[i] for i in left_idx), ()):  # inner join
                    out.append(MLRow(tuple(r) + tuple(match[j] for j in right_keep), schema))
            parts.append(out)
        return MLTable(parts, schema)

    # ------------------------------------------------------------------ #
    # MapReduce operations (Fig. A1)
    # ------------------------------------------------------------------ #
    def map(self, fn: Callable[[MLRow], Sequence[Any]],
            schema: Optional[Schema] = None) -> "MLTable":
        parts: List[List[MLRow]] = []
        for p in self._partitions:
            out = []
            for r in p:
                v = fn(r)
                if schema is None:
                    schema = Schema.infer_from_row(tuple(v))
                out.append(MLRow(tuple(v), schema))
            parts.append(out)
        if schema is None:  # empty table
            schema = self.schema
        return MLTable(parts, schema)

    def flat_map(self, fn: Callable[[MLRow], Iterable[Sequence[Any]]],
                 schema: Optional[Schema] = None) -> "MLTable":
        parts: List[List[MLRow]] = []
        for p in self._partitions:
            out = []
            for r in p:
                for v in fn(r):
                    if schema is None:
                        schema = Schema.infer_from_row(tuple(v))
                    out.append(MLRow(tuple(v), schema))
            parts.append(out)
        if schema is None:
            schema = self.schema
        return MLTable(parts, schema)

    flatMap = flat_map  # paper spelling

    def reduce(self, fn: Callable[[MLRow, MLRow], Sequence[Any]]) -> MLRow:
        """Tree-combine all rows with an associative, commutative function.

        Mirrors the distributed semantics: reduce within each partition first,
        then across partition results.
        """
        partials = []
        for p in self._partitions:
            if not p:
                continue
            acc = p[0]
            for r in p[1:]:
                acc = MLRow(tuple(fn(acc, r)), self.schema)
            partials.append(acc)
        if not partials:
            raise ValueError("reduce of empty table")
        acc = partials[0]
        for r in partials[1:]:
            acc = MLRow(tuple(fn(acc, r)), self.schema)
        return acc

    def reduce_by_key(self, key_col: Any,
                      fn: Callable[[MLRow, MLRow], Sequence[Any]]) -> "MLTable":
        key_idx = self.schema.index_of(key_col) if isinstance(key_col, str) else int(key_col)
        groups: Dict[Any, MLRow] = {}
        for r in self.rows():
            k = r[key_idx]
            if k in groups:
                groups[k] = MLRow(tuple(fn(groups[k], r)), self.schema)
            else:
                groups[k] = r
        rows = list(groups.values())
        return MLTable(_chunk(rows, self.num_partitions), self.schema)

    reduceByKey = reduce_by_key  # paper spelling

    # ------------------------------------------------------------------ #
    # commit to device tier
    # ------------------------------------------------------------------ #
    def to_numeric(self, num_shards: Optional[int] = None, mesh=None,
                   dtype=np.float32):
        """Cast to MLNumericTable (paper §III-A), sharded over the data axis.

        Every column must be numeric; Empty cells become NaN (algorithms are
        expected to impute/filter first — matching the paper's convention that
        Empty is represented by a special value).
        """
        if not self.schema.is_numeric:
            bad = [c for c in self.schema.columns if not c.ctype.is_numeric]
            raise TypeError(f"non-numeric columns present: {bad}")
        from repro.core.numeric_table import MLNumericTable  # local import, avoids cycle

        data = np.asarray([r.to_floats() for r in self.rows()], dtype=dtype)
        if data.size == 0:
            data = data.reshape(0, len(self.schema))
        return MLNumericTable.from_numpy(
            data, num_shards=num_shards, mesh=mesh, names=self.schema.names
        )

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"MLTable(rows={self.num_rows}, cols={self.num_cols}, "
            f"partitions={self.num_partitions}, schema={[c.ctype.value for c in self.schema.columns]})"
        )
