"""DistributedRunner — the shared execution layer for every MLI algorithm.

The paper's claim (§III, §IV) is that one uniform contract —
``Algorithm.train(data, params) -> Model`` over a row-partitioned table —
expresses many distributed ML algorithms.  The seed code had the contract
but each algorithm wired its own ``shard_map`` loop.  This module is the
single place that owns distributed execution:

  * **mesh + partition layout** — delegated to :mod:`repro.core.partition`,
    shared with :class:`repro.core.numeric_table.MLNumericTable` so table
    placement and execution can never disagree;
  * **per-round combine** — :mod:`repro.core.collectives` with
    :class:`CollectiveSchedule` as a pluggable parameter, so the paper's
    §IV-A schedule comparison is a knob every algorithm exposes;
  * **iteration** — one jitted ``lax.scan`` over rounds with the carry
    donated on accelerators, so per-round parameter buffers are reused
    instead of reallocated.

Algorithms express their per-partition compute as *pure local functions*
``f(block, state, round) -> partial`` (or ``f(block, *broadcast) ->
partial`` for one-shot passes) and delegate everything else here:

    runner = DistributedRunner.for_table(table, schedule=params.schedule)
    final = runner.run_rounds(table, init, local_step, num_rounds,
                              combine="mean")

Both execution modes of the table are supported transparently: **mesh mode**
(shard_map over the data axes; collectives lower to real HLO) and
**emulated mode** (logical partitions on one device; the combine is the
algebraically-equal local reduction).  See ``docs/architecture.md`` for the
data-flow diagram and ``docs/api.md`` for the full surface.

Beyond the paper's resident-table loop, the runner has a **streaming
mode** (:meth:`DistributedRunner.run_epochs`): each epoch consumes one
host window from a :class:`repro.data.pipeline.BatchIterator` (placed on
the mesh by ``shard_batch``) and runs a chunked, jitted ``lax.scan`` of
minibatch rounds over the device-resident window — so training is not
bounded by device memory.  Paired with :class:`CheckpointPolicy` (periodic
snapshots of state + epoch + stream position + rng through
:mod:`repro.checkpoint.store`) and :meth:`DistributedRunner.resume`, a
run killed mid-flight restarts bit-for-bit on the same mesh — the
checkpoint-and-restart fault-tolerance story that replaces the paper's
Spark lineage.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Iterator, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import partition as pt
from repro.core.compat import shard_map
from repro.core.collectives import (
    CollectiveSchedule,
    combine_concat,
    combine_mean,
    combine_sum,
)

__all__ = ["CheckpointPolicy", "DistributedRunner"]

# local_step(block, state, round_index) -> per-partition partial result
LocalStep = Callable[[jnp.ndarray, Any, jnp.ndarray], Any]
# update(state, combined, round_index) -> next state (defaults to `combined`)
UpdateFn = Callable[[Any, Any, jnp.ndarray], Any]

_COMBINERS = {
    "mean": combine_mean,
    "sum": combine_sum,
    "concat": combine_concat,
}


@dataclasses.dataclass
class CheckpointPolicy:
    """When and where the streaming loop snapshots its state.

    Every ``every_epochs`` completed epochs, :meth:`DistributedRunner.
    run_epochs` writes one atomic checkpoint through
    :mod:`repro.checkpoint.store` carrying the state pytree **and** the
    host-side loop counters (epoch, stream step, rng key, chunk layout,
    schedule) — everything :meth:`DistributedRunner.resume` needs to
    restart the run bit-for-bit.  ``keep`` bounds disk usage by pruning all
    but the newest ``keep`` snapshots after each publish.
    """

    ckpt_dir: str
    every_epochs: int = 1
    keep: Optional[int] = None

    def __post_init__(self) -> None:
        if self.every_epochs < 1:
            raise ValueError(f"every_epochs must be >= 1, got {self.every_epochs}")
        if self.keep is not None and self.keep < 1:
            raise ValueError(f"keep must be >= 1, got {self.keep}")


def _emulated_combine(stacked: Any, combine: str) -> Any:
    """Combine a (shards, ...) stacked tree without a mesh — the
    algebraically-equal local form of each collective."""
    if combine == "mean":
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), stacked)
    if combine == "sum":
        return jax.tree.map(lambda x: jnp.sum(x, axis=0), stacked)
    if combine == "concat":
        return jax.tree.map(pt.unpartition_rows, stacked)
    raise ValueError(f"unknown combine {combine!r}")


@dataclasses.dataclass
class DistributedRunner:
    """Owns mesh construction, data partitioning, and the per-round combine.

    Parameters
    ----------
    mesh:
        Device mesh, or ``None`` for emulated partitions on one device.
    num_shards:
        Partition count in emulated mode (ignored when a mesh is given —
        then it is derived from the data-axis sizes).
    data_axes:
        Mesh axes carrying the row partitions; inferred from the mesh when
        omitted (``("pod", "data")`` subset, outermost first).
    schedule:
        The :class:`CollectiveSchedule` used for every global combine.
    donate:
        Donate the carry buffers of the round loop to the jitted scan so
        parameter memory is reused across rounds.  ``None`` (default) turns
        donation on exactly when the backend supports it (not CPU, where XLA
        would warn and ignore it).
    """

    mesh: Optional[Mesh] = None
    num_shards: int = 1
    data_axes: Optional[Tuple[str, ...]] = None
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE
    donate: Optional[bool] = None

    def __post_init__(self) -> None:
        self.schedule = CollectiveSchedule.parse(self.schedule)
        if self.mesh is not None:
            if self.data_axes is None:
                self.data_axes = pt.infer_data_axes(self.mesh)
            self.num_shards = pt.num_data_shards(self.mesh, self.data_axes)
        else:
            self.data_axes = ()
        if self.donate is None:
            self.donate = jax.default_backend() != "cpu"

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #
    @classmethod
    def for_table(cls, table: Any,
                  schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE,
                  donate: Optional[bool] = None) -> "DistributedRunner":
        """Build a runner matching a table's mesh / partition layout.

        Accepts anything with ``mesh``, ``num_shards`` and (when meshed)
        ``data_axes`` attributes — i.e. an :class:`MLNumericTable`."""
        return cls(mesh=table.mesh, num_shards=table.num_shards,
                   data_axes=getattr(table, "data_axes", None) or None,
                   schedule=schedule, donate=donate)

    # ------------------------------------------------------------------ #
    # primitive: one pass over partitions (trace-safe)
    # ------------------------------------------------------------------ #
    def partition_apply(self, data: jnp.ndarray, fn: Callable,
                        broadcast: Sequence[Any] = (),
                        combine: Optional[str] = None) -> Any:
        """Run ``fn(block, *broadcast)`` on every partition of ``data``.

        ``combine=None`` returns the stacked per-partition results with a
        leading ``(num_shards, ...)`` axis; ``"mean" | "sum" | "concat"``
        combines them across partitions with the configured schedule.
        Callable inside ``jax.jit`` — algorithms with bespoke outer loops
        (ALS) build on this directly.
        """
        broadcast = tuple(broadcast)
        if self.mesh is not None:
            axes = self.data_axes

            def spmd(block: jnp.ndarray, *args: Any) -> Any:
                out = fn(block, *args)
                if combine is None:
                    return jax.tree.map(lambda x: x[None], out)
                return _COMBINERS[combine](out, axes, self.schedule)

            mapped = shard_map(
                spmd,
                mesh=self.mesh,
                in_specs=(pt.data_spec(axes),) + tuple(P() for _ in broadcast),
                out_specs=P(axes) if combine is None else P(),
            )
            return mapped(data, *broadcast)

        blocks = pt.partition_rows(data, self.num_shards)
        outs = [fn(blocks[i], *broadcast) for i in range(self.num_shards)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *outs)
        if combine is None:
            return stacked
        return _emulated_combine(stacked, combine)

    # ------------------------------------------------------------------ #
    # one-shot sufficient-statistics pass
    # ------------------------------------------------------------------ #
    def run_once(self, table: Any, local_fn: Callable, *broadcast: Any,
                 combine: str = "sum") -> Any:
        """One combined pass: ``local_fn(block, *broadcast)`` per partition,
        then one global combine.  The pattern of the closed-form algorithms
        (PCA moments, naive Bayes counts)."""
        return self.partition_apply(table.data, local_fn, broadcast, combine)

    # ------------------------------------------------------------------ #
    # the paper's iterate-and-combine loop
    # ------------------------------------------------------------------ #
    def run_rounds(self, table: Any, init_state: Any, local_step: LocalStep,
                   num_rounds: int, *, combine: str = "mean",
                   update: Optional[UpdateFn] = None) -> Any:
        """Run ``num_rounds`` of: per-partition ``local_step(block, state,
        r)`` → global combine (configured schedule) → ``update(state,
        combined, r)``.

        This is the paper's main loop (Fig. A4 middle: localSGD +
        avgWeights) generalized: parameter-averaging methods pass
        ``combine="mean"`` and no ``update``; sufficient-statistics methods
        (k-means) pass ``combine="sum"`` and an ``update`` that rebuilds the
        state.  The whole loop compiles to one jitted ``lax.scan``; the
        state carry is donated when the backend supports it.
        """
        upd: UpdateFn = update or (lambda state, combined, r: combined)
        rounds = jnp.arange(num_rounds)
        donate_argnums = (0,) if self.donate else ()
        if self.donate:
            # donate a private copy, never the caller's buffer: init_state is
            # typically a params field (w_init) the caller may reuse
            init_state = jax.tree.map(jnp.copy, init_state)

        if self.mesh is not None:
            axes = self.data_axes
            data = table.data

            def round_body(state, r):
                def spmd(block, state):
                    part = local_step(block, state, r)
                    return _COMBINERS[combine](part, axes, self.schedule)

                combined = shard_map(
                    spmd,
                    mesh=self.mesh,
                    in_specs=(pt.data_spec(axes), P()),
                    out_specs=P(),
                )(data, state)
                return upd(state, combined, r), None

            @partial(jax.jit, donate_argnums=donate_argnums)
            def run(state0):
                final, _ = jax.lax.scan(round_body, state0, rounds)
                return final

            return run(init_state)

        num_shards = self.num_shards

        @partial(jax.jit, donate_argnums=donate_argnums)
        def run(state0, data):
            blocks = pt.partition_rows(data, num_shards)

            def round_body(state, r):
                parts = jax.vmap(lambda b: local_step(b, state, r))(blocks)
                combined = _emulated_combine(parts, combine)
                return upd(state, combined, r), None

            final, _ = jax.lax.scan(round_body, state0, rounds)
            return final

        return run(init_state, table.data)

    # ------------------------------------------------------------------ #
    # streaming mode: epochs over minibatch windows (beyond the paper)
    # ------------------------------------------------------------------ #
    def _check_window(self, window: jnp.ndarray, chunks_per_epoch: int) -> None:
        pt.check_rows_divisible(window.shape[0], self.num_shards,
                                what="stream partitions")
        per_shard = window.shape[0] // self.num_shards
        if per_shard % chunks_per_epoch != 0:
            raise ValueError(
                f"rows-per-shard {per_shard} must divide into "
                f"chunks_per_epoch={chunks_per_epoch}")

    def _epoch_fn(self, local_step: LocalStep, upd: UpdateFn, combine: str,
                  chunks: int) -> Callable:
        """Build the jitted one-epoch function ``(state, window, rounds) ->
        state``: a ``lax.scan`` over the window's ``chunks`` minibatches.
        ``rounds`` carries the global round ids (epoch·chunks + chunk), so
        round-indexed local steps (lr decay, rotating slices) see a
        monotone counter across epochs and the compiled function is reused
        for every epoch."""
        donate = (0,) if self.donate else ()

        if self.mesh is not None:
            axes = self.data_axes

            def round_body(window, state, r):
                def spmd(wblock, state, r):
                    cr = wblock.shape[0] // chunks
                    c = r % chunks
                    block = jax.lax.dynamic_slice_in_dim(wblock, c * cr, cr, axis=0)
                    part = local_step(block, state, r)
                    return _COMBINERS[combine](part, axes, self.schedule)

                return shard_map(
                    spmd,
                    mesh=self.mesh,
                    in_specs=(pt.data_spec(axes), P(), P()),
                    out_specs=P(),
                )(window, state, r)

            @partial(jax.jit, donate_argnums=donate)
            def epoch(state, window, rounds):
                def body(state, r):
                    combined = round_body(window, state, r)
                    return upd(state, combined, r), None

                final, _ = jax.lax.scan(body, state, rounds)
                return final

            return epoch

        num_shards = self.num_shards

        @partial(jax.jit, donate_argnums=donate)
        def epoch(state, window, rounds):
            blocks = pt.partition_rows(window, num_shards)
            cr = blocks.shape[1] // chunks

            def body(state, r):
                c = r % chunks
                chunk = jax.lax.dynamic_slice_in_dim(blocks, c * cr, cr, axis=1)
                parts = jax.vmap(lambda b: local_step(b, state, r))(chunk)
                combined = _emulated_combine(parts, combine)
                return upd(state, combined, r), None

            final, _ = jax.lax.scan(body, state, rounds)
            return final

        return epoch

    def run_epochs(self, stream: Iterator, init_state: Any,
                   local_step: LocalStep, num_epochs: int, *,
                   combine: str = "mean", update: Optional[UpdateFn] = None,
                   chunks_per_epoch: int = 1,
                   checkpoint: Optional[CheckpointPolicy] = None,
                   rng: Optional[jnp.ndarray] = None,
                   start_epoch: int = 0) -> Any:
        """Streaming variant of :meth:`run_rounds` for data larger than
        device memory: each epoch pulls ONE window of rows from ``stream``
        (a :class:`repro.data.pipeline.BatchIterator` yielding ``{"data":
        (rows, features)}`` host batches, already mesh-placed by
        ``shard_batch``) and runs ``chunks_per_epoch`` rounds of
        local-step → combine → update over it as a single jitted
        ``lax.scan`` with the state carry donated.  Round ``r`` of epoch
        ``e`` sees the window's ``r % chunks_per_epoch``-th row chunk and
        the global round index ``e * chunks_per_epoch + r``.

        With a :class:`CheckpointPolicy`, every ``every_epochs`` epochs the
        ``(state, epoch, stream.step, rng)`` tuple is snapshotted
        atomically via :mod:`repro.checkpoint.store`; :meth:`resume`
        restarts from the newest snapshot bit-for-bit.  ``rng`` is an
        optional uint32 key carried for stochastic pipelines (fold per
        epoch with ``jax.random.fold_in(rng, epoch)``); it rides in the
        checkpoint so a resumed run re-derives identical per-epoch keys.
        """
        if num_epochs < start_epoch:
            raise ValueError(f"num_epochs {num_epochs} < start_epoch {start_epoch}")
        upd: UpdateFn = update or (lambda state, combined, r: combined)
        chunks = int(chunks_per_epoch)
        if chunks < 1:
            raise ValueError(f"chunks_per_epoch must be >= 1, got {chunks}")
        epoch_fn = self._epoch_fn(local_step, upd, combine, chunks)

        state = init_state
        if self.donate:
            # donate a private copy, never the caller's buffer
            state = jax.tree.map(jnp.copy, state)

        last_saved = None
        for e in range(start_epoch, num_epochs):
            batch = next(stream)
            window = batch["data"] if isinstance(batch, dict) else batch
            self._check_window(window, chunks)
            rounds = jnp.arange(e * chunks, (e + 1) * chunks, dtype=jnp.int32)
            state = epoch_fn(state, window, rounds)
            if checkpoint is not None and (e + 1) % checkpoint.every_epochs == 0:
                self._save_snapshot(checkpoint, stream, state, e + 1, chunks, rng)
                last_saved = e + 1
        if checkpoint is not None and last_saved != num_epochs:
            self._save_snapshot(checkpoint, stream, state, num_epochs, chunks, rng)
        return state

    def _save_snapshot(self, policy: CheckpointPolicy, stream: Any, state: Any,
                       epoch: int, chunks: int, rng: Optional[jnp.ndarray]) -> None:
        from repro.checkpoint.store import save_checkpoint

        stream_step = getattr(stream, "step", None)
        if stream_step is None:
            raise TypeError(
                "checkpointing requires a stream exposing its position as "
                ".step (a BatchIterator) — resume could not replay an "
                "unpositioned stream")
        meta = {
            "epoch": epoch,
            "stream_step": int(stream_step),
            "rng": None if rng is None else np.asarray(rng).tolist(),
            "chunks_per_epoch": chunks,
            "schedule": self.schedule.value,
            "num_shards": self.num_shards,
            "every_epochs": policy.every_epochs,
            "keep": policy.keep,
        }
        save_checkpoint(policy.ckpt_dir, epoch, state, metadata=meta,
                        keep=policy.keep)

    def resume(self, ckpt_dir: str, stream: Any, init_state: Any,
               local_step: LocalStep, num_epochs: int, *,
               combine: str = "mean", update: Optional[UpdateFn] = None,
               chunks_per_epoch: Optional[int] = None,
               checkpoint: Optional[CheckpointPolicy] = None,
               step: Optional[int] = None) -> Any:
        """Restart a killed :meth:`run_epochs` run from its newest (or
        ``step``-selected) checkpoint and continue to ``num_epochs``.

        ``init_state`` is only the structure template for the restore — its
        values are replaced by the snapshot.  The stream is fast-forwarded
        with ``seek`` to the checkpointed position, the rng key restored,
        and the chunk layout / schedule / shard count cross-checked against
        the snapshot so a mismatched relaunch fails loudly instead of
        silently diverging.  On the same mesh the resumed run replays the
        identical compiled computation, so the final state matches an
        uninterrupted run bit-for-bit (asserted in
        ``tests/test_streaming_resume.py``).
        """
        from repro.checkpoint.store import restore_with_metadata

        state, ck_step, meta = restore_with_metadata(ckpt_dir, init_state, step)
        if meta is None:
            raise ValueError(
                f"checkpoint step {ck_step} under {ckpt_dir} carries no "
                f"resume metadata — was it written by run_epochs?")
        for name, have in (("schedule", self.schedule.value),
                           ("num_shards", self.num_shards)):
            want = meta.get(name)
            if want is not None and want != have:
                raise ValueError(
                    f"cannot resume: checkpoint was written with "
                    f"{name}={want!r} but this runner has {name}={have!r}")
        chunks = int(meta.get("chunks_per_epoch", 1))
        if chunks_per_epoch is not None and chunks_per_epoch != chunks:
            raise ValueError(
                f"cannot resume: checkpoint used chunks_per_epoch={chunks}, "
                f"got {chunks_per_epoch}")
        if not hasattr(stream, "seek"):
            raise TypeError("resume requires a seekable stream "
                            "(BatchIterator or anything with .seek(step))")
        stream.seek(meta["stream_step"])
        rng = (jnp.asarray(meta["rng"], jnp.uint32)
               if meta.get("rng") is not None else None)
        epoch = int(meta["epoch"])
        if checkpoint is None and meta.get("every_epochs"):
            checkpoint = CheckpointPolicy(ckpt_dir, meta["every_epochs"],
                                          meta.get("keep"))
        if epoch >= num_epochs:
            return state
        return self.run_epochs(stream, state, local_step, num_epochs,
                               combine=combine, update=update,
                               chunks_per_epoch=chunks, checkpoint=checkpoint,
                               rng=rng, start_epoch=epoch)

    def __repr__(self) -> str:  # pragma: no cover
        where = (f"mesh{tuple(self.mesh.shape.items())}" if self.mesh is not None
                 else f"emulated[{self.num_shards}]")
        return (f"DistributedRunner({where}, schedule={self.schedule.value}, "
                f"donate={self.donate})")
