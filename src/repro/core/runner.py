"""DistributedRunner — the shared execution layer for every MLI algorithm.

The paper's claim (§III, §IV) is that one uniform contract —
``Algorithm.train(data, params) -> Model`` over a row-partitioned table —
expresses many distributed ML algorithms.  The seed code had the contract
but each algorithm wired its own ``shard_map`` loop.  This module is the
single place that owns distributed execution:

  * **mesh + partition layout** — delegated to :mod:`repro.core.partition`,
    shared with :class:`repro.core.numeric_table.MLNumericTable` so table
    placement and execution can never disagree;
  * **per-round combine** — :mod:`repro.core.collectives` with
    :class:`CollectiveSchedule` as a pluggable parameter, so the paper's
    §IV-A schedule comparison is a knob every algorithm exposes;
  * **iteration** — one jitted ``lax.scan`` over rounds with the carry
    donated on accelerators, so per-round parameter buffers are reused
    instead of reallocated.

Algorithms express their per-partition compute as *pure local functions*
``f(block, state, round) -> partial`` (or ``f(block, *broadcast) ->
partial`` for one-shot passes) and delegate everything else here:

    runner = DistributedRunner.for_table(table, schedule=params.schedule)
    final = runner.run_rounds(table, init, local_step, num_rounds,
                              combine="mean")

Both execution modes of the table are supported transparently: **mesh mode**
(shard_map over the data axes; collectives lower to real HLO) and
**emulated mode** (logical partitions on one device; the combine is the
algebraically-equal local reduction).  See ``docs/architecture.md`` for the
data-flow diagram and ``docs/api.md`` for the full surface.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import partition as pt
from repro.core.compat import shard_map
from repro.core.collectives import (
    CollectiveSchedule,
    combine_concat,
    combine_mean,
    combine_sum,
)

__all__ = ["DistributedRunner"]

# local_step(block, state, round_index) -> per-partition partial result
LocalStep = Callable[[jnp.ndarray, Any, jnp.ndarray], Any]
# update(state, combined, round_index) -> next state (defaults to `combined`)
UpdateFn = Callable[[Any, Any, jnp.ndarray], Any]

_COMBINERS = {
    "mean": combine_mean,
    "sum": combine_sum,
    "concat": combine_concat,
}


def _emulated_combine(stacked: Any, combine: str) -> Any:
    """Combine a (shards, ...) stacked tree without a mesh — the
    algebraically-equal local form of each collective."""
    if combine == "mean":
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), stacked)
    if combine == "sum":
        return jax.tree.map(lambda x: jnp.sum(x, axis=0), stacked)
    if combine == "concat":
        return jax.tree.map(pt.unpartition_rows, stacked)
    raise ValueError(f"unknown combine {combine!r}")


@dataclasses.dataclass
class DistributedRunner:
    """Owns mesh construction, data partitioning, and the per-round combine.

    Parameters
    ----------
    mesh:
        Device mesh, or ``None`` for emulated partitions on one device.
    num_shards:
        Partition count in emulated mode (ignored when a mesh is given —
        then it is derived from the data-axis sizes).
    data_axes:
        Mesh axes carrying the row partitions; inferred from the mesh when
        omitted (``("pod", "data")`` subset, outermost first).
    schedule:
        The :class:`CollectiveSchedule` used for every global combine.
    donate:
        Donate the carry buffers of the round loop to the jitted scan so
        parameter memory is reused across rounds.  ``None`` (default) turns
        donation on exactly when the backend supports it (not CPU, where XLA
        would warn and ignore it).
    """

    mesh: Optional[Mesh] = None
    num_shards: int = 1
    data_axes: Optional[Tuple[str, ...]] = None
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE
    donate: Optional[bool] = None

    def __post_init__(self) -> None:
        self.schedule = CollectiveSchedule.parse(self.schedule)
        if self.mesh is not None:
            if self.data_axes is None:
                self.data_axes = pt.infer_data_axes(self.mesh)
            self.num_shards = pt.num_data_shards(self.mesh, self.data_axes)
        else:
            self.data_axes = ()
        if self.donate is None:
            self.donate = jax.default_backend() != "cpu"

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #
    @classmethod
    def for_table(cls, table: Any,
                  schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE,
                  donate: Optional[bool] = None) -> "DistributedRunner":
        """Build a runner matching a table's mesh / partition layout.

        Accepts anything with ``mesh``, ``num_shards`` and (when meshed)
        ``data_axes`` attributes — i.e. an :class:`MLNumericTable`."""
        return cls(mesh=table.mesh, num_shards=table.num_shards,
                   data_axes=getattr(table, "data_axes", None) or None,
                   schedule=schedule, donate=donate)

    # ------------------------------------------------------------------ #
    # primitive: one pass over partitions (trace-safe)
    # ------------------------------------------------------------------ #
    def partition_apply(self, data: jnp.ndarray, fn: Callable,
                        broadcast: Sequence[Any] = (),
                        combine: Optional[str] = None) -> Any:
        """Run ``fn(block, *broadcast)`` on every partition of ``data``.

        ``combine=None`` returns the stacked per-partition results with a
        leading ``(num_shards, ...)`` axis; ``"mean" | "sum" | "concat"``
        combines them across partitions with the configured schedule.
        Callable inside ``jax.jit`` — algorithms with bespoke outer loops
        (ALS) build on this directly.
        """
        broadcast = tuple(broadcast)
        if self.mesh is not None:
            axes = self.data_axes

            def spmd(block: jnp.ndarray, *args: Any) -> Any:
                out = fn(block, *args)
                if combine is None:
                    return jax.tree.map(lambda x: x[None], out)
                return _COMBINERS[combine](out, axes, self.schedule)

            mapped = shard_map(
                spmd,
                mesh=self.mesh,
                in_specs=(pt.data_spec(axes),) + tuple(P() for _ in broadcast),
                out_specs=P(axes) if combine is None else P(),
            )
            return mapped(data, *broadcast)

        blocks = pt.partition_rows(data, self.num_shards)
        outs = [fn(blocks[i], *broadcast) for i in range(self.num_shards)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *outs)
        if combine is None:
            return stacked
        return _emulated_combine(stacked, combine)

    # ------------------------------------------------------------------ #
    # one-shot sufficient-statistics pass
    # ------------------------------------------------------------------ #
    def run_once(self, table: Any, local_fn: Callable, *broadcast: Any,
                 combine: str = "sum") -> Any:
        """One combined pass: ``local_fn(block, *broadcast)`` per partition,
        then one global combine.  The pattern of the closed-form algorithms
        (PCA moments, naive Bayes counts)."""
        return self.partition_apply(table.data, local_fn, broadcast, combine)

    # ------------------------------------------------------------------ #
    # the paper's iterate-and-combine loop
    # ------------------------------------------------------------------ #
    def run_rounds(self, table: Any, init_state: Any, local_step: LocalStep,
                   num_rounds: int, *, combine: str = "mean",
                   update: Optional[UpdateFn] = None) -> Any:
        """Run ``num_rounds`` of: per-partition ``local_step(block, state,
        r)`` → global combine (configured schedule) → ``update(state,
        combined, r)``.

        This is the paper's main loop (Fig. A4 middle: localSGD +
        avgWeights) generalized: parameter-averaging methods pass
        ``combine="mean"`` and no ``update``; sufficient-statistics methods
        (k-means) pass ``combine="sum"`` and an ``update`` that rebuilds the
        state.  The whole loop compiles to one jitted ``lax.scan``; the
        state carry is donated when the backend supports it.
        """
        upd: UpdateFn = update or (lambda state, combined, r: combined)
        rounds = jnp.arange(num_rounds)
        donate_argnums = (0,) if self.donate else ()
        if self.donate:
            # donate a private copy, never the caller's buffer: init_state is
            # typically a params field (w_init) the caller may reuse
            init_state = jax.tree.map(jnp.copy, init_state)

        if self.mesh is not None:
            axes = self.data_axes
            data = table.data

            def round_body(state, r):
                def spmd(block, state):
                    part = local_step(block, state, r)
                    return _COMBINERS[combine](part, axes, self.schedule)

                combined = shard_map(
                    spmd,
                    mesh=self.mesh,
                    in_specs=(pt.data_spec(axes), P()),
                    out_specs=P(),
                )(data, state)
                return upd(state, combined, r), None

            @partial(jax.jit, donate_argnums=donate_argnums)
            def run(state0):
                final, _ = jax.lax.scan(round_body, state0, rounds)
                return final

            return run(init_state)

        num_shards = self.num_shards

        @partial(jax.jit, donate_argnums=donate_argnums)
        def run(state0, data):
            blocks = pt.partition_rows(data, num_shards)

            def round_body(state, r):
                parts = jax.vmap(lambda b: local_step(b, state, r))(blocks)
                combined = _emulated_combine(parts, combine)
                return upd(state, combined, r), None

            final, _ = jax.lax.scan(round_body, state0, rounds)
            return final

        return run(init_state, table.data)

    def __repr__(self) -> str:  # pragma: no cover
        where = (f"mesh{tuple(self.mesh.shape.items())}" if self.mesh is not None
                 else f"emulated[{self.num_shards}]")
        return (f"DistributedRunner({where}, schedule={self.schedule.value}, "
                f"donate={self.donate})")
