"""DistributedRunner — the shared execution layer for every MLI algorithm.

The paper's claim (§III, §IV) is that one uniform contract —
``Algorithm.train(data, params) -> Model`` over a row-partitioned table —
expresses many distributed ML algorithms.  The seed code had the contract
but each algorithm wired its own ``shard_map`` loop.  This module is the
single place that owns distributed execution:

  * **mesh + partition layout** — delegated to :mod:`repro.core.partition`,
    shared with :class:`repro.core.numeric_table.MLNumericTable` so table
    placement and execution can never disagree;
  * **per-round combine** — :mod:`repro.core.collectives` with
    :class:`CollectiveSchedule` as a pluggable parameter, so the paper's
    §IV-A schedule comparison is a knob every algorithm exposes;
  * **iteration** — one jitted ``lax.scan`` over rounds with the carry
    donated on accelerators, so per-round parameter buffers are reused
    instead of reallocated.

Algorithms express their per-partition compute as *pure local functions*
``f(block, state, round) -> partial`` (or ``f(block, *broadcast) ->
partial`` for one-shot passes) and delegate everything else here:

    runner = DistributedRunner.for_table(table, schedule=params.schedule)
    final = runner.run_rounds(table, init, local_step, num_rounds,
                              combine="mean")

Both execution modes of the table are supported transparently: **mesh mode**
(shard_map over the data axes; collectives lower to real HLO) and
**emulated mode** (logical partitions on one device; the combine is the
algebraically-equal local reduction).  See ``docs/architecture.md`` for the
data-flow diagram and ``docs/api.md`` for the full surface.

Beyond the paper's resident-table loop, the runner has a **streaming
mode** (:meth:`DistributedRunner.run_epochs`): each epoch consumes one
host window from a :class:`repro.data.pipeline.BatchIterator` (placed on
the mesh by ``shard_batch``) and runs a chunked, jitted ``lax.scan`` of
minibatch rounds over the device-resident window — so training is not
bounded by device memory.  Paired with :class:`CheckpointPolicy` (periodic
snapshots of state + epoch + stream position + rng through
:mod:`repro.checkpoint.store`) and :meth:`DistributedRunner.resume`, a
run killed mid-flight restarts bit-for-bit on the same mesh — the
checkpoint-and-restart fault-tolerance story that replaces the paper's
Spark lineage.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Iterator, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import partition as pt
from repro.core.compat import shard_map
from repro.core.collectives import (
    CollectiveSchedule,
    SyncPolicy,
    combine_concat,
    combine_mean,
    combine_sum,
    ssp_read_round,
)

__all__ = ["CheckpointPolicy", "DistributedRunner"]

# local_step(block, state, round_index) -> per-partition partial result
LocalStep = Callable[[jnp.ndarray, Any, jnp.ndarray], Any]
# update(state, combined, round_index) -> next state (defaults to `combined`)
UpdateFn = Callable[[Any, Any, jnp.ndarray], Any]
# trial_step(block, state, round_index, hyper) -> per-partition partial for ONE
# trial; the stacked entry points vmap it over the trial axis
TrialStep = Callable[[jnp.ndarray, Any, jnp.ndarray, Any], Any]
# trial_update(state, combined, round_index, hyper) -> next state for ONE trial
TrialUpdateFn = Callable[[Any, Any, jnp.ndarray, Any], Any]

_COMBINERS = {
    "mean": combine_mean,
    "sum": combine_sum,
    "concat": combine_concat,
}


@dataclasses.dataclass
class CheckpointPolicy:
    """When and where the streaming loop snapshots its state.

    Every ``every_epochs`` completed epochs, :meth:`DistributedRunner.
    run_epochs` writes one atomic checkpoint through
    :mod:`repro.checkpoint.store` carrying the state pytree **and** the
    host-side loop counters (epoch, stream step, rng key, chunk layout,
    schedule) — everything :meth:`DistributedRunner.resume` needs to
    restart the run bit-for-bit.  ``keep`` bounds disk usage by pruning all
    but the newest ``keep`` snapshots after each publish.

    ``extra_state`` (a pytree of arrays) and ``extra_metadata`` (a
    JSON-able dict) ride in every snapshot *alongside* the training carry —
    one atomic file, so a composite artifact (a fitted pipeline's
    featurizer statistics + model state + stream position) can never be
    torn apart by a crash.  On resume the restored extra tree replaces
    ``extra_state`` in place, so the caller reads the snapshot's values
    back off the policy.
    """

    ckpt_dir: str
    every_epochs: int = 1
    keep: Optional[int] = None
    extra_state: Any = None
    extra_metadata: Optional[dict] = None

    def __post_init__(self) -> None:
        if self.every_epochs < 1:
            raise ValueError(f"every_epochs must be >= 1, got {self.every_epochs}")
        if self.keep is not None and self.keep < 1:
            raise ValueError(f"keep must be >= 1, got {self.keep}")


def _default_update(state: Any, combined: Any, r: jnp.ndarray) -> Any:
    """The default ``update``: the combined value becomes the next state.

    A module-level function (not a per-call lambda) so repeated
    ``run_epochs`` calls with the default update share one jit cache entry.
    """
    return combined


def _mask_tree(active: jnp.ndarray, new: Any, old: Any) -> Any:
    """Per-leaf ``where`` with the (K,) trial mask broadcast over each
    leaf's trailing dims — stopped trials keep their frozen state."""
    def leaf(n, o):
        m = active.reshape(active.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)

    return jax.tree.map(leaf, new, old)


def _stacked_callback_shim(cb: Callable) -> Callable:
    """Adapt a trial-level callback to the stacked carry: the env the
    callback sees carries the (K, …) trial tree / hyper / host active
    mask instead of the raw carry dict, and any ``state``/``hyper``/
    ``active`` swap it returns is folded back into the carry.  Dispatch
    attributes (``order``, ``before_epoch``) are preserved."""
    import dataclasses as _dc

    def shim(env):
        carry = env.state
        tenv = _dc.replace(env, state=carry["trial"], hyper=carry["hyper"],
                           active=np.asarray(carry["active"]))
        out = cb(tenv)
        if not out:
            return None
        new = dict(carry)
        if "state" in out:
            new["trial"] = out["state"]
        if "hyper" in out:
            new["hyper"] = out["hyper"]
        if "active" in out:
            new["active"] = jnp.asarray(out["active"])
        return {"state": new}

    shim.order = getattr(cb, "order", 10)
    shim.before_epoch = getattr(cb, "before_epoch", False)
    return shim


def _emulated_combine(stacked: Any, combine: str) -> Any:
    """Combine a (shards, ...) stacked tree without a mesh — the
    algebraically-equal local form of each collective."""
    if combine == "mean":
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), stacked)
    if combine == "sum":
        return jax.tree.map(lambda x: jnp.sum(x, axis=0), stacked)
    if combine == "concat":
        return jax.tree.map(pt.unpartition_rows, stacked)
    raise ValueError(f"unknown combine {combine!r}")


@dataclasses.dataclass
class DistributedRunner:
    """Owns mesh construction, data partitioning, and the per-round combine.

    Parameters
    ----------
    mesh:
        Device mesh, or ``None`` for emulated partitions on one device.
    num_shards:
        Partition count in emulated mode (ignored when a mesh is given —
        then it is derived from the data-axis sizes).
    data_axes:
        Mesh axes carrying the row partitions; inferred from the mesh when
        omitted (``("pod", "data")`` subset, outermost first).
    schedule:
        The :class:`CollectiveSchedule` used for every global combine.
    donate:
        Donate the carry buffers of the round loop to the jitted scan so
        parameter memory is reused across rounds.  ``None`` (default) turns
        donation on exactly when the backend supports it (not CPU, where XLA
        would warn and ignore it).
    """

    mesh: Optional[Mesh] = None
    num_shards: int = 1
    data_axes: Optional[Tuple[str, ...]] = None
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE
    donate: Optional[bool] = None

    def __post_init__(self) -> None:
        self.schedule = CollectiveSchedule.parse(self.schedule)
        if self.mesh is not None:
            if self.data_axes is None:
                self.data_axes = pt.infer_data_axes(self.mesh)
            self.num_shards = pt.num_data_shards(self.mesh, self.data_axes)
        else:
            self.data_axes = ()
        if self.donate is None:
            self.donate = jax.default_backend() != "cpu"
        # jitted one-epoch functions, keyed by (local_step, update, combine,
        # chunks): repeated run_epochs calls with the SAME function objects
        # (the tune layer's rung loop, resume continuations) reuse the
        # compiled epoch instead of retracing.  Callers that build a fresh
        # closure per call simply miss; the cache is capped so a long-lived
        # runner fed per-call closures cannot leak dead executables.
        self._epoch_cache: dict = {}
        self._epoch_cache_max = 16

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #
    @classmethod
    def for_table(cls, table: Any,
                  schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE,
                  donate: Optional[bool] = None) -> "DistributedRunner":
        """Build a runner matching a table's mesh / partition layout.

        Accepts anything with ``mesh``, ``num_shards`` and (when meshed)
        ``data_axes`` attributes — i.e. an :class:`MLNumericTable`."""
        return cls(mesh=table.mesh, num_shards=table.num_shards,
                   data_axes=getattr(table, "data_axes", None) or None,
                   schedule=schedule, donate=donate)

    # ------------------------------------------------------------------ #
    # primitive: one pass over partitions (trace-safe)
    # ------------------------------------------------------------------ #
    def partition_apply(self, data: jnp.ndarray, fn: Callable,
                        broadcast: Sequence[Any] = (),
                        combine: Optional[str] = None) -> Any:
        """Run ``fn(block, *broadcast)`` on every partition of ``data``.

        ``combine=None`` returns the stacked per-partition results with a
        leading ``(num_shards, ...)`` axis; ``"mean" | "sum" | "concat"``
        combines them across partitions with the configured schedule.
        Callable inside ``jax.jit`` — algorithms with bespoke outer loops
        (ALS) build on this directly.
        """
        broadcast = tuple(broadcast)
        if self.mesh is not None:
            axes = self.data_axes

            def spmd(block: jnp.ndarray, *args: Any) -> Any:
                out = fn(block, *args)
                if combine is None:
                    return jax.tree.map(lambda x: x[None], out)
                return _COMBINERS[combine](out, axes, self.schedule)

            mapped = shard_map(
                spmd,
                mesh=self.mesh,
                in_specs=(pt.data_spec(axes),) + tuple(P() for _ in broadcast),
                out_specs=P(axes) if combine is None else P(),
            )
            return mapped(data, *broadcast)

        blocks = pt.partition_rows(data, self.num_shards)
        outs = [fn(blocks[i], *broadcast) for i in range(self.num_shards)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *outs)
        if combine is None:
            return stacked
        return _emulated_combine(stacked, combine)

    # ------------------------------------------------------------------ #
    # one-shot sufficient-statistics pass
    # ------------------------------------------------------------------ #
    def run_once(self, table: Any, local_fn: Callable, *broadcast: Any,
                 combine: str = "sum") -> Any:
        """One combined pass: ``local_fn(block, *broadcast)`` per partition,
        then one global combine.  The pattern of the closed-form algorithms
        (PCA moments, naive Bayes counts)."""
        return self.partition_apply(table.data, local_fn, broadcast, combine)

    # ------------------------------------------------------------------ #
    # the paper's iterate-and-combine loop
    # ------------------------------------------------------------------ #
    def run_rounds(self, table: Any, init_state: Any, local_step: LocalStep,
                   num_rounds: int, *, combine: str = "mean",
                   update: Optional[UpdateFn] = None,
                   start_round: int = 0) -> Any:
        """Run ``num_rounds`` of: per-partition ``local_step(block, state,
        r)`` → global combine (configured schedule) → ``update(state,
        combined, r)``.

        This is the paper's main loop (Fig. A4 middle: localSGD +
        avgWeights) generalized: parameter-averaging methods pass
        ``combine="mean"`` and no ``update``; sufficient-statistics methods
        (k-means) pass ``combine="sum"`` and an ``update`` that rebuilds the
        state.  The whole loop compiles to one jitted ``lax.scan``; the
        state carry is donated when the backend supports it.

        ``start_round`` offsets the round indices ``local_step`` sees —
        callers that split one logical run into segments (the tune layer's
        early-stopping rungs) keep lr decay and rotating slices monotone
        across segments.
        """
        upd: UpdateFn = update or _default_update
        rounds = jnp.asarray(np.arange(start_round,
                                       start_round + num_rounds,
                                       dtype=np.int32))
        donate_argnums = (0,) if self.donate else ()
        init_state = self._canonical_state(init_state)
        if self.donate:
            # donate a private copy, never the caller's buffer: init_state is
            # typically a params field (w_init) the caller may reuse
            init_state = jax.tree.map(jnp.copy, init_state)

        if self.mesh is not None:
            axes = self.data_axes
            data = table.data

            def round_body(state, r):
                def spmd(block, state):
                    part = local_step(block, state, r)
                    return _COMBINERS[combine](part, axes, self.schedule)

                combined = shard_map(
                    spmd,
                    mesh=self.mesh,
                    in_specs=(pt.data_spec(axes), P()),
                    out_specs=P(),
                )(data, state)
                return upd(state, combined, r), None

            @partial(jax.jit, donate_argnums=donate_argnums)
            def run(state0):
                final, _ = jax.lax.scan(round_body, state0, rounds)
                return final

            return run(init_state)

        num_shards = self.num_shards

        @partial(jax.jit, donate_argnums=donate_argnums)
        def run(state0, data):
            blocks = pt.partition_rows(data, num_shards)

            def round_body(state, r):
                parts = jax.vmap(lambda b: local_step(b, state, r))(blocks)
                combined = _emulated_combine(parts, combine)
                return upd(state, combined, r), None

            final, _ = jax.lax.scan(round_body, state0, rounds)
            return final

        return run(init_state, table.data)

    # ------------------------------------------------------------------ #
    # streaming mode: epochs over minibatch windows (beyond the paper)
    # ------------------------------------------------------------------ #
    def _check_window(self, window: jnp.ndarray, chunks_per_epoch: int) -> None:
        pt.check_rows_divisible(window.shape[0], self.num_shards,
                                what="stream partitions")
        per_shard = window.shape[0] // self.num_shards
        if per_shard % chunks_per_epoch != 0:
            raise ValueError(
                f"rows-per-shard {per_shard} must divide into "
                f"chunks_per_epoch={chunks_per_epoch}")

    def _canonical_state(self, state: Any) -> Any:
        """Replicate the state carry onto the mesh (no-op when emulated or
        already placed).  Segmented callers alternate host-built carries
        (first segment) with device outputs of the previous segment;
        without one canonical input sharding the jitted epoch compiles
        TWICE for the same shapes — the exact miss
        ``repro.analysis.assert_no_retrace`` flags on the rung loop."""
        if self.mesh is None or not jax.core.trace_state_clean():
            # placement is a host-side concern; under an outer trace the
            # caller governs placement and a staged device_put would read
            # as a per-step transfer in the jaxpr audit
            return state
        sharding = jax.sharding.NamedSharding(self.mesh, P())

        def place(x):
            if getattr(x, "sharding", None) == sharding:
                return x
            return jax.device_put(x, sharding)

        return jax.tree.map(place, state)

    def _epoch_fn(self, local_step: LocalStep, upd: UpdateFn, combine: str,
                  chunks: int) -> Callable:
        """Build the jitted one-epoch function ``(state, window, rounds) ->
        state``: a ``lax.scan`` over the window's ``chunks`` minibatches.
        ``rounds`` carries the global round ids (epoch·chunks + chunk), so
        round-indexed local steps (lr decay, rotating slices) see a
        monotone counter across epochs and the compiled function is reused
        for every epoch."""
        donate = (0,) if self.donate else ()

        if self.mesh is not None:
            axes = self.data_axes

            def round_body(window, state, r):
                def spmd(wblock, state, r):
                    cr = wblock.shape[0] // chunks
                    c = r % chunks
                    block = jax.lax.dynamic_slice_in_dim(wblock, c * cr, cr, axis=0)
                    part = local_step(block, state, r)
                    return _COMBINERS[combine](part, axes, self.schedule)

                return shard_map(
                    spmd,
                    mesh=self.mesh,
                    in_specs=(pt.data_spec(axes), P(), P()),
                    out_specs=P(),
                )(window, state, r)

            @partial(jax.jit, donate_argnums=donate)
            def epoch(state, window, rounds):
                def body(state, r):
                    combined = round_body(window, state, r)
                    return upd(state, combined, r), None

                final, _ = jax.lax.scan(body, state, rounds)
                return final

            return epoch

        num_shards = self.num_shards

        @partial(jax.jit, donate_argnums=donate)
        def epoch(state, window, rounds):
            blocks = pt.partition_rows(window, num_shards)
            cr = blocks.shape[1] // chunks

            def body(state, r):
                c = r % chunks
                chunk = jax.lax.dynamic_slice_in_dim(blocks, c * cr, cr, axis=1)
                parts = jax.vmap(lambda b: local_step(b, state, r))(chunk)
                combined = _emulated_combine(parts, combine)
                return upd(state, combined, r), None

            final, _ = jax.lax.scan(body, state, rounds)
            return final

        return epoch

    def epoch_fn(self, local_step: LocalStep,
                 update: Optional[UpdateFn] = None, *,
                 combine: str = "mean", chunks_per_epoch: int = 1) -> Callable:
        """The cached jitted one-epoch function ``(state, window, rounds)
        -> state`` that :meth:`run_epochs` drives.

        Public so callers can warm it ahead of a latency-sensitive stream
        and so :mod:`repro.analysis` can audit the exact jaxpr the epoch
        loop executes (same cache, same donation flags — not a
        reconstruction)."""
        upd: UpdateFn = update or _default_update
        chunks = int(chunks_per_epoch)
        if chunks < 1:
            raise ValueError(f"chunks_per_epoch must be >= 1, got {chunks}")
        cache_key = (local_step, upd, combine, chunks)
        fn = self._epoch_cache.get(cache_key)
        if fn is None:
            fn = self._epoch_fn(local_step, upd, combine, chunks)
            self._cache_put(cache_key, fn)
        return fn

    def run_epochs(self, stream: Iterator, init_state: Any,
                   local_step: LocalStep, num_epochs: int, *,
                   combine: str = "mean", update: Optional[UpdateFn] = None,
                   chunks_per_epoch: int = 1,
                   checkpoint: Optional[CheckpointPolicy] = None,
                   rng: Optional[jnp.ndarray] = None,
                   start_epoch: int = 0,
                   callbacks: Sequence[Callable] = (),
                   eval_fn: Optional[Callable] = None) -> Any:
        """Streaming variant of :meth:`run_rounds` for data larger than
        device memory: each epoch pulls ONE window of rows from ``stream``
        (a :class:`repro.data.pipeline.BatchIterator` yielding ``{"data":
        (rows, features)}`` host batches, already mesh-placed by
        ``shard_batch``) and runs ``chunks_per_epoch`` rounds of
        local-step → combine → update over it as a single jitted
        ``lax.scan`` with the state carry donated.  Round ``r`` of epoch
        ``e`` sees the window's ``r % chunks_per_epoch``-th row chunk and
        the global round index ``e * chunks_per_epoch + r``.

        With a :class:`CheckpointPolicy`, every ``every_epochs`` epochs the
        ``(state, epoch, stream.step, rng)`` tuple is snapshotted
        atomically via :mod:`repro.checkpoint.store`; :meth:`resume`
        restarts from the newest snapshot bit-for-bit.  ``rng`` is an
        optional uint32 key carried for stochastic pipelines (fold per
        epoch with ``jax.random.fold_in(rng, epoch)``); it rides in the
        checkpoint so a resumed run re-derives identical per-epoch keys.

        ``callbacks`` are host-side hooks fired *between* compiled epochs
        (the :mod:`repro.tune.callback` protocol): before-epoch callbacks
        may return ``{"state": ...}`` swaps the next epoch trains on
        (hyper schedules), after-epoch callbacks see ``eval_fn(state,
        epoch) -> [EvalEntry, ...]`` results and may raise
        :class:`repro.tune.callback.EarlyStopException` to end the loop —
        the tail checkpoint is still written, so an early-stopped run
        resumes/inspects like a completed one.  Hooks never change the
        compiled round structure.
        """
        if num_epochs < start_epoch:
            raise ValueError(f"num_epochs {num_epochs} < start_epoch {start_epoch}")
        chunks = int(chunks_per_epoch)
        epoch_fn = self.epoch_fn(local_step, update, combine=combine,
                                 chunks_per_epoch=chunks)

        before = after = ()
        if callbacks:
            from repro.tune.callback import (CallbackEnv, EarlyStopException,
                                             fire_callbacks, split_callbacks)
            before, after = split_callbacks(callbacks)

        state = self._canonical_state(init_state)
        if self.donate:
            # donate a private copy, never the caller's buffer
            state = jax.tree.map(jnp.copy, state)

        last_saved = None
        rows = None
        done = num_epochs
        for e in range(start_epoch, num_epochs):
            stopped = False
            if before:
                env = CallbackEnv(epoch=e, begin_epoch=start_epoch,
                                  end_epoch=num_epochs, round=e * chunks,
                                  state=state)
                try:
                    swaps = fire_callbacks(before, env)
                except EarlyStopException:
                    done = e
                    break
                if set(swaps) - {"state"}:
                    raise ValueError(
                        f"run_epochs carries only 'state' — a callback "
                        f"returned {sorted(set(swaps) - {'state'})} (hyper/"
                        f"active swaps need the stacked loop)")
                if "state" in swaps:
                    # swapped states come from host callbacks: re-place them
                    # so the compiled epoch's input sharding never drifts
                    state = self._canonical_state(swaps["state"])
                    if self.donate:
                        state = jax.tree.map(jnp.copy, state)
            batch = next(stream)
            window = batch["data"] if isinstance(batch, dict) else batch
            self._check_window(window, chunks)
            rows = int(window.shape[0])
            # numpy-built + device_put: jnp.arange(start, ...) compiles a
            # different tiny program for zero vs nonzero starts, so the
            # first post-resume/rung epoch would trip the retrace sentinel
            rounds = jnp.asarray(np.arange(e * chunks, (e + 1) * chunks,
                                           dtype=np.int32))
            state = epoch_fn(state, window, rounds)
            done = e + 1
            if after:
                evals = tuple(eval_fn(state, done)) if eval_fn else ()
                env = CallbackEnv(epoch=done, begin_epoch=start_epoch,
                                  end_epoch=num_epochs, round=done * chunks,
                                  state=state, evals=evals)
                try:
                    swaps = fire_callbacks(after, env)
                except EarlyStopException:
                    stopped = True
                    swaps = {}
                if set(swaps) - {"state"}:
                    raise ValueError(
                        f"run_epochs carries only 'state' — a callback "
                        f"returned {sorted(set(swaps) - {'state'})} (hyper/"
                        f"active swaps need the stacked loop)")
                if "state" in swaps:
                    # swapped states come from host callbacks: re-place them
                    # so the compiled epoch's input sharding never drifts
                    state = self._canonical_state(swaps["state"])
                    if self.donate:
                        state = jax.tree.map(jnp.copy, state)
            if checkpoint is not None and done % checkpoint.every_epochs == 0:
                self._save_snapshot(checkpoint, stream, state, done, chunks,
                                    rng, rows=rows)
                last_saved = done
            if stopped:
                break
        if checkpoint is not None and last_saved != done:
            self._save_snapshot(checkpoint, stream, state, done, chunks,
                                rng, rows=rows)
        return state

    def _save_snapshot(self, policy: CheckpointPolicy, stream: Any, state: Any,
                       epoch: int, chunks: int, rng: Optional[jnp.ndarray], *,
                       rows: Optional[int] = None,
                       extra_meta: Optional[dict] = None) -> None:
        from repro.checkpoint.store import save_checkpoint

        if jax.process_count() > 1 and jax.process_index() != 0:
            # one writer per global mesh: every host computes the identical
            # replicated state, process 0 persists it (shared filesystem);
            # the SSP exchange lane never reaches here multi-process — its
            # hosts are independent single-process programs with their own
            # checkpoint dirs.
            return
        stream_step = getattr(stream, "step", None)
        if stream_step is None:
            raise TypeError(
                "checkpointing requires a stream exposing its position as "
                ".step (a BatchIterator) — resume could not replay an "
                "unpositioned stream")
        meta = {
            "epoch": epoch,
            "stream_step": int(stream_step),
            "rng": None if rng is None else np.asarray(rng).tolist(),
            "chunks_per_epoch": chunks,
            "schedule": self.schedule.value,
            "num_shards": self.num_shards,
            "num_hosts": jax.process_count(),
            "rows_per_epoch": rows,
            "every_epochs": policy.every_epochs,
            "keep": policy.keep,
            "wrapped": policy.extra_state is not None,
        }
        if extra_meta:
            meta.update(extra_meta)
        tree = state
        if policy.extra_state is not None:
            # one atomic unit: the training carry plus the caller's extra
            # state (e.g. a pipeline's fitted featurizer statistics)
            tree = {"state": state, "extra": policy.extra_state}
        if policy.extra_metadata is not None:
            meta["extra"] = policy.extra_metadata
        save_checkpoint(policy.ckpt_dir, epoch, tree, metadata=meta,
                        keep=policy.keep)

    def resume(self, ckpt_dir: str, stream: Any, init_state: Any,
               local_step: LocalStep, num_epochs: int, *,
               combine: str = "mean", update: Optional[UpdateFn] = None,
               chunks_per_epoch: Optional[int] = None,
               checkpoint: Optional[CheckpointPolicy] = None,
               step: Optional[int] = None,
               allow_resize: bool = False) -> Any:
        """Restart a killed :meth:`run_epochs` run from its newest (or
        ``step``-selected) checkpoint and continue to ``num_epochs``.

        ``init_state`` is only the structure template for the restore — its
        values are replaced by the snapshot.  The stream is fast-forwarded
        with ``seek`` to the checkpointed position, the rng key restored,
        and the chunk layout / schedule / shard count cross-checked against
        the snapshot so a mismatched relaunch fails loudly instead of
        silently diverging.  On the same mesh the resumed run replays the
        identical compiled computation, so the final state matches an
        uninterrupted run bit-for-bit (asserted in
        ``tests/test_streaming_resume.py``).

        ``allow_resize=True`` is the elastic path: the shard-count
        cross-check is replaced by a :func:`repro.core.partition.plan_resize`
        validation (rows must still split evenly over the new layout), so a
        surviving mesh of a different world size can pick the run up from
        the same snapshot — live migration as checkpoint-and-restart.  The
        state pytree itself is layout-free (combines produce replicated
        trees), so only the stream's row partitioning changes.
        """
        from repro.checkpoint.store import load_metadata, \
            restore_with_metadata

        peek = load_metadata(ckpt_dir, step) or {}
        wrapped = bool(peek.get("wrapped"))
        template = init_state
        if wrapped:
            if checkpoint is None or checkpoint.extra_state is None:
                raise ValueError(
                    f"checkpoint under {ckpt_dir} carries extra (pipeline) "
                    f"state — resume needs the CheckpointPolicy with an "
                    f"extra_state template to restore it atomically")
            template = {"state": init_state, "extra": checkpoint.extra_state}
        state, ck_step, meta = restore_with_metadata(ckpt_dir, template, step)
        if wrapped:
            # hand the restored extra tree back through the policy (and
            # keep re-saving it with every later snapshot)
            checkpoint.extra_state = state["extra"]
            state = state["state"]
        if meta is None:
            raise ValueError(
                f"checkpoint step {ck_step} under {ckpt_dir} carries no "
                f"resume metadata — was it written by run_epochs?")
        for name, have in (("schedule", self.schedule.value),
                           ("num_shards", self.num_shards)):
            want = meta.get(name)
            if want is None or want == have:
                continue
            if name == "num_shards" and allow_resize:
                rows = meta.get("rows_per_epoch")
                if rows:
                    # validates the new layout and quantifies the motion;
                    # raises before any state is touched when the rows
                    # cannot split evenly over the surviving shards
                    pt.plan_resize(int(rows), int(want), int(have))
                continue
            raise ValueError(
                f"cannot resume: checkpoint was written with "
                f"{name}={want!r} but this runner has {name}={have!r}"
                + ("" if name != "num_shards" else
                   " (pass allow_resize=True to repartition onto the "
                   "surviving mesh)"))
        chunks = int(meta.get("chunks_per_epoch", 1))
        if chunks_per_epoch is not None and chunks_per_epoch != chunks:
            raise ValueError(
                f"cannot resume: checkpoint used chunks_per_epoch={chunks}, "
                f"got {chunks_per_epoch}")
        if not hasattr(stream, "seek"):
            raise TypeError("resume requires a seekable stream "
                            "(BatchIterator or anything with .seek(step))")
        stream.seek(meta["stream_step"])
        rng = (jnp.asarray(meta["rng"], jnp.uint32)
               if meta.get("rng") is not None else None)
        epoch = int(meta["epoch"])
        if checkpoint is None and meta.get("every_epochs"):
            checkpoint = CheckpointPolicy(ckpt_dir, meta["every_epochs"],
                                          meta.get("keep"))
        if epoch >= num_epochs:
            return state
        return self.run_epochs(stream, state, local_step, num_epochs,
                               combine=combine, update=update,
                               chunks_per_epoch=chunks, checkpoint=checkpoint,
                               rng=rng, start_epoch=epoch)

    # ------------------------------------------------------------------ #
    # stale-synchronous parallel lane: independent hosts, bounded clocks
    # ------------------------------------------------------------------ #
    def _ssp_merge(self, entries, combine: str) -> Any:
        """Combine ``[(host_id, tree), ...]`` across hosts in host-id order.

        Canonical ordering is the determinism contract: every participant
        stacks the same trees in the same order and reduces along the new
        axis, so the arithmetic (and therefore the bits) is identical on
        every host and in the in-process reference simulator the chaos
        tests compare against.
        """
        trees = [t for _, t in sorted(entries, key=lambda kv: kv[0])]
        if combine == "mean":
            return jax.tree.map(
                lambda *xs: jnp.mean(jnp.stack(xs, axis=0), axis=0), *trees)
        if combine == "sum":
            return jax.tree.map(
                lambda *xs: jnp.sum(jnp.stack(xs, axis=0), axis=0), *trees)
        raise ValueError(f"SSP lane supports combine='mean'|'sum', "
                         f"got {combine!r}")

    def run_epochs_ssp(self, stream: Iterator, init_state: Any,
                       local_step: LocalStep, num_epochs: int, *,
                       store: Any, staleness: int = 0,
                       combine: str = "mean",
                       update: Optional[UpdateFn] = None,
                       chunks_per_epoch: int = 1,
                       checkpoint: Optional[CheckpointPolicy] = None,
                       rng: Optional[jnp.ndarray] = None,
                       start_epoch: int = 0,
                       trace: Optional[list] = None) -> Any:
        """Streaming epochs with **stale-synchronous** cross-host exchange.

        The second execution mode the multi-host work adds: hosts are
        *independent* single-process programs (each with its own local mesh
        or emulated partitions) that exchange through a shared
        :class:`repro.core.exchange.ParamStore` instead of lock-step
        collectives.  Each exchange round (one epoch) host ``h``:

          1. computes its local contribution for round ``e`` and
             **publishes** it (atomic, crash-safe — the same file machinery
             as checkpoints);
          2. **waits** until every live peer has published round
             ``>= e - staleness`` — the SSP bound: a host may run at most
             ``staleness`` rounds ahead of the slowest peer;
          3. **reads** each peer's freshest publication capped at its own
             round (:func:`repro.core.collectives.ssp_read_round`) and
             merges in canonical host-id order (:meth:`_ssp_merge`).

        ``staleness=0`` degenerates to lock-step BSP *by construction*:
        step 2 blocks until every peer published round ``e`` exactly, step
        3 reads exactly round ``e`` from everyone — every host merges the
        identical entry set in the identical order, bit-for-bit equal to
        the sequential reference simulator (asserted in
        ``tests/chaos/``).  With ``staleness=s>0`` a straggler no longer
        stalls the cohort: fast hosts keep computing on contributions up
        to ``s`` rounds stale (the Petuum trade-off the benchmark
        ``benchmarks/elastic_ssp.py`` quantifies).

        Two algorithm shapes map onto the lane through ``combine``:

        * ``"mean"`` (parameter averaging, e.g. logistic SGD): the local
          contribution is the host's **post-epoch state** (a local
          ``chunks_per_epoch``-round epoch via the normal jitted epoch
          scan); the merge averages states across hosts — local SGD with
          bounded-staleness averaging.
        * ``"sum"`` + ``update`` (sufficient statistics, e.g. k-means):
          the local contribution is the host's **statistics tree** for the
          round; the merge sums them and ``update`` rebuilds the state.
          Requires ``chunks_per_epoch == 1`` so rounds and exchange rounds
          coincide.

        Departed peers (``store.mark_left()``, the ``drop`` chaos action)
        are excluded as soon as their last in-bound contribution ages out;
        the cohort shrinks without restarting — in-place elastic resize
        for the exchange lane.  ``trace``, when given a list, receives one
        ``{"epoch", "reads", "wait_seconds"}`` record per exchange round —
        the raw material of the staleness-bound assertions in
        ``tests/chaos/test_ssp_property.py``.

        Checkpoints are **per host** (each host snapshots its own state to
        its own directory, with ``staleness`` and the store's world size in
        the metadata); :meth:`resume_ssp` restarts a killed host against
        the *same* store — surviving publications are still on disk, so
        the cohort only blocks for the restart gap, bounded by the store
        timeout.
        """
        import time as _time

        if num_epochs < start_epoch:
            raise ValueError(f"num_epochs {num_epochs} < start_epoch {start_epoch}")
        staleness = int(staleness)
        if staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {staleness}")
        chunks = int(chunks_per_epoch)
        if chunks < 1:
            raise ValueError(f"chunks_per_epoch must be >= 1, got {chunks}")
        upd: UpdateFn = update or _default_update
        stats_lane = combine == "sum"
        if stats_lane:
            if update is None:
                raise ValueError(
                    "SSP combine='sum' is the sufficient-statistics lane — "
                    "it needs an update(state, merged_stats, r) to rebuild "
                    "the state from the cross-host sum")
            if chunks != 1:
                raise ValueError(
                    "SSP combine='sum' requires chunks_per_epoch=1 so "
                    "exchange rounds and algorithm rounds coincide")
        elif combine != "mean":
            raise ValueError(f"SSP lane supports combine='mean'|'sum', "
                             f"got {combine!r}")

        epoch_fn = None
        if not stats_lane:
            cache_key = (local_step, upd, combine, chunks)
            epoch_fn = self._epoch_cache.get(cache_key)
            if epoch_fn is None:
                epoch_fn = self._epoch_fn(local_step, upd, combine, chunks)
                self._cache_put(cache_key, epoch_fn)

        state = init_state
        if self.donate and not stats_lane:
            state = jax.tree.map(jnp.copy, state)

        rows = None
        last_saved = None
        for e in range(start_epoch, num_epochs):
            batch = next(stream)
            window = batch["data"] if isinstance(batch, dict) else batch
            self._check_window(window, chunks)
            rows = int(window.shape[0])
            if stats_lane:
                r = jnp.asarray(e, jnp.int32)
                mine = self.partition_apply(
                    window, local_step, broadcast=(state, r), combine="sum")
            else:
                rounds = jnp.asarray(np.arange(e * chunks, (e + 1) * chunks,
                                               dtype=np.int32))
                mine = epoch_fn(state, window, rounds)
            mine = jax.tree.map(np.asarray, jax.device_get(mine))
            store.publish(e, mine)

            entries = [(store.host_id, mine)]
            reads = {}
            waited = 0.0
            for p in store.peers():
                t0 = _time.monotonic()
                clock = store.wait_clock(p, e - staleness + 1)
                waited += _time.monotonic() - t0
                if clock <= e - staleness:
                    # departed peer whose last word is out of bound: it has
                    # aged out of the cohort (in-place shrink)
                    continue
                tau = ssp_read_round(e, clock, staleness)
                got = store.read_at_most(p, tau, mine)
                if got is None:
                    continue
                entries.append((p, got[0]))
                reads[p] = got[1]
            merged = self._ssp_merge(entries, combine)
            state = upd(state, merged, jnp.asarray(e, jnp.int32)) \
                if stats_lane else merged
            if trace is not None:
                trace.append({"epoch": e, "reads": reads,
                              "wait_seconds": waited})
            if checkpoint is not None and (e + 1) % checkpoint.every_epochs == 0:
                self._save_snapshot(
                    checkpoint, stream, state, e + 1, chunks, rng, rows=rows,
                    extra_meta={"staleness": staleness,
                                "ssp_hosts": store.num_hosts,
                                "ssp_host_id": store.host_id})
                last_saved = e + 1
        if checkpoint is not None and last_saved != num_epochs:
            self._save_snapshot(
                checkpoint, stream, state, num_epochs, chunks, rng, rows=rows,
                extra_meta={"staleness": staleness,
                            "ssp_hosts": store.num_hosts,
                            "ssp_host_id": store.host_id})
        return state

    def resume_ssp(self, ckpt_dir: str, stream: Any, init_state: Any,
                   local_step: LocalStep, num_epochs: int, *,
                   store: Any, staleness: Optional[int] = None,
                   combine: str = "mean", update: Optional[UpdateFn] = None,
                   checkpoint: Optional[CheckpointPolicy] = None,
                   step: Optional[int] = None,
                   trace: Optional[list] = None) -> Any:
        """Restart one killed SSP host from its own checkpoint and rejoin
        the cohort on the *same* store.

        Peers' publications survive a host's death on disk, so the
        restarted host replays from its snapshot (identical bits — same
        mesh, same compiled epoch) and re-publishes the rounds it had
        already shared; peers consumed the originals, the replays are
        byte-identical, and the clocks re-converge.  ``staleness`` defaults
        to the checkpointed value.
        """
        from repro.checkpoint.store import restore_with_metadata

        state, ck_step, meta = restore_with_metadata(ckpt_dir, init_state, step)
        if meta is None:
            raise ValueError(
                f"checkpoint step {ck_step} under {ckpt_dir} carries no "
                f"resume metadata — was it written by run_epochs_ssp?")
        if staleness is None:
            staleness = int(meta.get("staleness", 0))
        chunks = int(meta.get("chunks_per_epoch", 1))
        if not hasattr(stream, "seek"):
            raise TypeError("resume requires a seekable stream "
                            "(BatchIterator or anything with .seek(step))")
        stream.seek(meta["stream_step"])
        rng = (jnp.asarray(meta["rng"], jnp.uint32)
               if meta.get("rng") is not None else None)
        epoch = int(meta["epoch"])
        if checkpoint is None and meta.get("every_epochs"):
            checkpoint = CheckpointPolicy(ckpt_dir, meta["every_epochs"],
                                          meta.get("keep"))
        if epoch >= num_epochs:
            return state
        return self.run_epochs_ssp(
            stream, state, local_step, num_epochs, store=store,
            staleness=staleness, combine=combine, update=update,
            chunks_per_epoch=chunks, checkpoint=checkpoint, rng=rng,
            start_epoch=epoch, trace=trace)

    # ------------------------------------------------------------------ #
    # device-stacked trials: K models per round (model search; repro.tune)
    # ------------------------------------------------------------------ #
    def _stacked_carry(self, trial_states: Any, trial_hyper: Any,
                       active: Optional[jnp.ndarray],
                       offsets: Optional[jnp.ndarray] = None) -> dict:
        """Assemble the carry of a stacked run: ``trial`` (every leaf has a
        leading (K, …) trial axis), ``hyper`` (per-trial scalar
        hyperparameters, leading (K,)), ``active`` (the (K,) bool mask
        early stopping freezes trials with), and ``offset`` (per-trial
        round offsets: lane ``j`` sees trial-local round ``r - offset[j]``,
        so a trial backfilled into a freed slot mid-search trains on the
        same round indices — lr decay, rotating slices — as a solo run
        from round 0)."""
        leaves = jax.tree.leaves(trial_states)
        if not leaves:
            raise ValueError("trial_states must have at least one array leaf")
        k = leaves[0].shape[0]
        for leaf in leaves + jax.tree.leaves(trial_hyper):
            if leaf.shape[:1] != (k,):
                raise ValueError(
                    f"every stacked leaf needs leading trial axis {k}, got "
                    f"shape {leaf.shape}")
        if active is None:
            active = jnp.ones((k,), bool)
        if offsets is None:
            offsets = jnp.zeros((k,), jnp.int32)
        else:
            offsets = jnp.asarray(offsets, jnp.int32)
            if offsets.shape != (k,):
                raise ValueError(
                    f"round offsets must be shape ({k},), got {offsets.shape}")
        return {"trial": trial_states, "hyper": trial_hyper,
                "active": jnp.asarray(active), "offset": offsets}

    def _cache_put(self, key: Any, value: Any) -> None:
        """Insert into the bounded epoch cache, evicting oldest-first."""
        while len(self._epoch_cache) >= self._epoch_cache_max:
            self._epoch_cache.pop(next(iter(self._epoch_cache)))
        self._epoch_cache[key] = value

    def _stacked_fns(self, trial_step: TrialStep,
                     trial_update: Optional[TrialUpdateFn]
                     ) -> Tuple[LocalStep, UpdateFn]:
        """vmap one trial's step/update over the trial axis.  Memoized per
        (trial_step, trial_update) so rung-segmented searches hit the
        jitted-epoch cache instead of retracing every segment."""
        key = ("stacked", trial_step, trial_update)
        if key in self._epoch_cache:
            return self._epoch_cache[key]

        def local_step(block: jnp.ndarray, carry: dict, r: jnp.ndarray) -> Any:
            # lane j sees its trial-local round r - offset[j]: a trial
            # admitted into a freed slot at a later global round trains on
            # the identical round sequence as a solo run from round 0
            return jax.vmap(lambda s, h, o: trial_step(block, s, r - o, h))(
                carry["trial"], carry["hyper"], carry["offset"])

        def upd(carry: dict, combined: Any, r: jnp.ndarray) -> dict:
            trial, hyper = carry["trial"], carry["hyper"]
            if trial_update is None:
                new = combined
            else:
                new = jax.vmap(lambda s, c, h, o: trial_update(s, c, r - o, h))(
                    trial, combined, hyper, carry["offset"])
            return {"trial": _mask_tree(carry["active"], new, trial),
                    "hyper": hyper, "active": carry["active"],
                    "offset": carry["offset"]}

        self._cache_put(key, (local_step, upd))
        return local_step, upd

    def run_stacked_rounds(self, table: Any, trial_states: Any,
                           trial_hyper: Any, trial_step: TrialStep,
                           num_rounds: int, *, combine: str = "mean",
                           update: Optional[TrialUpdateFn] = None,
                           active: Optional[jnp.ndarray] = None,
                           start_round: int = 0) -> Any:
        """Advance K device-stacked trials together over a resident table.

        ``trial_states`` is a pytree whose every leaf carries a leading
        (K, …) trial axis (see :func:`repro.tune.trials.tree_stack`);
        ``trial_hyper`` holds the per-trial scalar hyperparameters as (K,)
        leaves, so learning rates / regularizers are *traced* values and
        one compiled round advances all K candidates — K model-search
        trials for one jit and one collective per round instead of K.
        ``trial_step(block, state, r, hyper)`` and ``update(state,
        combined, r, hyper)`` describe ONE trial; this entry point vmaps
        them over the trial axis.  ``active`` masks trials stopped by the
        median rule: their states freeze but the round shape stays static.
        Returns the final stacked trial states.
        """
        carry = self._stacked_carry(trial_states, trial_hyper, active)
        step, upd = self._stacked_fns(trial_step, update)
        out = self.run_rounds(table, carry, step, num_rounds, combine=combine,
                              update=upd, start_round=start_round)
        return out["trial"]

    def run_stacked_epochs(self, stream: Iterator, trial_states: Any,
                           trial_hyper: Any, trial_step: TrialStep,
                           num_epochs: int, *, combine: str = "mean",
                           update: Optional[TrialUpdateFn] = None,
                           active: Optional[jnp.ndarray] = None,
                           chunks_per_epoch: int = 1,
                           checkpoint: Optional[CheckpointPolicy] = None,
                           rng: Optional[jnp.ndarray] = None,
                           start_epoch: int = 0,
                           round_offsets: Optional[jnp.ndarray] = None,
                           callbacks: Sequence[Callable] = (),
                           eval_fn: Optional[Callable] = None) -> Any:
        """Streaming twin of :meth:`run_stacked_rounds`: every epoch pulls
        ONE window from ``stream`` (shared by all K trials — the window
        crosses the host→device boundary once, not K times) and advances
        the stacked trial states through the PR-2 epoch scan, so searches
        inherit streaming's checkpoint/resume story unchanged.  Segmented
        callers (early-stopping rungs) pass ``start_epoch``/``active`` per
        segment; the compiled epoch function is cached across segments.

        ``round_offsets`` (K,) gives each lane a private round origin:
        lane ``j`` computes with trial-local round ``r - round_offsets[j]``
        — the mechanism slot-backfilling searches (ASHA) use to admit a
        fresh trial into a freed lane mid-run with its lr decay starting
        from zero.  Offsets must be multiples of ``chunks_per_epoch`` so
        the minibatch-chunk phase (``r % chunks``) is preserved.

        ``callbacks``/``eval_fn`` are the host-side hooks of
        :meth:`run_epochs`, presented at the trial level: each callback's
        env carries ``state`` = the stacked (K, …) trial tree, ``hyper``,
        and a host copy of ``active``; ``{"state"|"hyper"|"active": ...}``
        returns swap the matching carry component.  ``eval_fn(trial_states,
        epoch)`` returns the ``EvalEntry`` list for the boundary.
        Returns the final stacked trial states.
        """
        carry = self._stacked_carry(trial_states, trial_hyper, active,
                                    round_offsets)
        step, upd = self._stacked_fns(trial_step, update)
        run_callbacks: Sequence[Callable] = ()
        run_eval = None
        if callbacks:
            run_callbacks = [_stacked_callback_shim(cb) for cb in callbacks]
        if eval_fn is not None:
            run_eval = lambda carry, epoch: eval_fn(carry["trial"], epoch)  # noqa: E731
        out = self.run_epochs(stream, carry, step, num_epochs, combine=combine,
                              update=upd, chunks_per_epoch=chunks_per_epoch,
                              checkpoint=checkpoint, rng=rng,
                              start_epoch=start_epoch,
                              callbacks=run_callbacks, eval_fn=run_eval)
        return out["trial"]

    def __repr__(self) -> str:  # pragma: no cover
        where = (f"mesh{tuple(self.mesh.shape.items())}" if self.mesh is not None
                 else f"emulated[{self.num_shards}]")
        return (f"DistributedRunner({where}, schedule={self.schedule.value}, "
                f"donate={self.donate})")
