"""File-based parameter exchange between hosts (the SSP transport).

Stale-synchronous rounds cannot ride the jitted collectives: a gloo/XLA
collective is a barrier by construction — every participant must enter it —
which is exactly the BSP discipline SSP exists to relax.  So the SSP lane
exchanges partials through a shared directory instead: every host publishes
its per-round partial as one atomic file (the same fsync + atomic-rename
machinery as :mod:`repro.checkpoint.store`, so a SIGKILL mid-publish can
never corrupt what peers read), and reads its peers' freshest publishes
under the staleness bound of :class:`repro.core.collectives.SyncPolicy`.

The layout under ``root`` is one subdirectory per host::

    root/h0/step_0.npz  step_1.npz ...   # host 0's per-round partials
    root/h1/...
    root/h1/LEFT                          # host 1 left the mesh gracefully

A host's *clock* is simply its newest published step — crash-safe by the
same argument as checkpoint recovery: a killed host's clock freezes, a
straggler's clock lags, and peers observe both through ordinary directory
scans.  ``LEFT`` markers make graceful departure (the chaos harness's
``drop`` fault, an elastic scale-down) distinguishable from death: peers
stop waiting for a departed host immediately instead of timing out.

This is deliberately a *bulletin board*, not a message queue: publishes are
idempotent, reads are repeatable, and there is no connection state to lose
— which is what lets the chaos tests SIGKILL hosts at arbitrary points.
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional

from repro.checkpoint.store import (
    atomic_write_text,
    _STEP_RE,
    latest_step,
    prune_checkpoints,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = ["PeerTimeout", "ParamStore"]

#: name of the graceful-departure marker inside a host's directory
_LEFT_MARKER = "LEFT"


class PeerTimeout(TimeoutError):
    """A peer failed to publish within the deadline — it is presumed dead.

    Carries the peer id and the round being waited for so chaos tests (and
    an elastic controller) can assert *which* host stalled the mesh.
    """

    def __init__(self, peer: int, wanted_round: int, timeout: float):
        self.peer = peer
        self.wanted_round = wanted_round
        super().__init__(
            f"host {peer} has not published round {wanted_round} after "
            f"{timeout:.1f}s — presumed dead (SSP can absorb a straggler, "
            f"not a corpse; an elastic controller should resize the mesh)")


class ParamStore:
    """One host's handle on the shared exchange directory.

    Parameters
    ----------
    root:
        Shared directory (one per training run / generation).
    host_id, num_hosts:
        This host's id and the mesh's host count.
    timeout:
        How long :meth:`wait_clock` polls before declaring a peer dead.
    poll:
        Sleep between directory scans while waiting.
    keep:
        Publishes retained per host.  Must exceed the staleness bound so a
        peer reading ``s`` rounds back never races pruning; the executor
        passes ``staleness + 2``.
    """

    def __init__(self, root: str, host_id: int, num_hosts: int, *,
                 timeout: float = 60.0, poll: float = 0.002,
                 keep: Optional[int] = None):
        if not 0 <= host_id < num_hosts:
            raise ValueError(f"host_id {host_id} not in [0, {num_hosts})")
        self.root = root
        self.host_id = int(host_id)
        self.num_hosts = int(num_hosts)
        self.timeout = float(timeout)
        self.poll = float(poll)
        self.keep = keep
        os.makedirs(self._host_dir(host_id), exist_ok=True)

    def _host_dir(self, host: int) -> str:
        return os.path.join(self.root, f"h{host}")

    # ------------------------------------------------------------------ #
    # publishing
    # ------------------------------------------------------------------ #
    def publish(self, round_index: int, tree: Any) -> None:
        """Atomically publish this host's partial for ``round_index``; the
        publish *is* the clock tick peers observe."""
        save_checkpoint(self._host_dir(self.host_id), round_index, tree,
                        metadata={"round": round_index, "host": self.host_id},
                        keep=self.keep)

    def mark_left(self) -> None:
        """Graceful departure: peers stop waiting for this host as soon as
        they next scan (the ``drop`` fault / an elastic scale-down)."""
        d = self._host_dir(self.host_id)
        # atomic publish: a peer scanning mid-write must see either no
        # marker or a complete one, and the rename makes the departure
        # durable before has_left() can observe it
        atomic_write_text(os.path.join(d, _LEFT_MARKER), "left")

    # ------------------------------------------------------------------ #
    # observing peers
    # ------------------------------------------------------------------ #
    def clock(self, host: int) -> int:
        """Number of rounds ``host`` has published (0 = nothing yet)."""
        step = latest_step(self._host_dir(host))
        return 0 if step is None else step + 1

    def has_left(self, host: int) -> bool:
        return os.path.exists(os.path.join(self._host_dir(host), _LEFT_MARKER))

    def peers(self) -> List[int]:
        """Every other host that has not marked itself departed."""
        return [h for h in range(self.num_hosts)
                if h != self.host_id and not self.has_left(h)]

    def wait_clock(self, host: int, min_clock: int) -> int:
        """Block until ``host``'s clock reaches ``min_clock`` (or it marks
        itself departed — returns its final clock).  Raises
        :class:`PeerTimeout` after ``timeout`` seconds of *zero observed
        progress*: every time the peer's clock advances the deadline
        resets, so a slow-but-alive straggler that keeps publishing — but
        needs longer than ``timeout`` to cover the whole gap to
        ``min_clock`` — is waited out, while a corpse (frozen clock) still
        times out after exactly ``timeout`` seconds."""
        last = self.clock(host)
        deadline = time.monotonic() + self.timeout
        while True:
            c = self.clock(host)
            if c >= min_clock or self.has_left(host):
                return c
            if c > last:
                last = c
                deadline = time.monotonic() + self.timeout
            if time.monotonic() >= deadline:
                raise PeerTimeout(host, min_clock - 1, self.timeout)
            time.sleep(self.poll)

    def read(self, host: int, round_index: int, template: Any) -> Any:
        """Restore ``host``'s published partial for ``round_index`` into the
        structure of ``template``."""
        restored, _ = restore_checkpoint(self._host_dir(host), template,
                                         step=round_index)
        return restored

    def rounds(self, host: int) -> List[int]:
        """Every round ``host`` currently has on the board, ascending."""
        d = self._host_dir(host)
        if not os.path.isdir(d):
            return []
        out = []
        for fn in os.listdir(d):
            m = _STEP_RE.match(fn)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def read_at_most(self, host: int, round_index: int, template: Any
                     ) -> Optional[tuple]:
        """Freshest publish of ``host`` not newer than ``round_index``.

        Returns ``(tree, round)`` or ``None`` when nothing that old is on
        the board (a freshly-restarted generation whose peers resumed
        ahead, or a departed host whose contributions aged out).  This is
        the read the SSP executor performs after :func:`repro.core.
        collectives.ssp_read_round` caps the target — the wanted round is
        guaranteed in-bound, but after a world restart the exact file may
        be gone, in which case the nearest older one (still within the
        bound, since the peer's clock passed the wait) is the right value.

        Listing and reading are two separate directory operations, and the
        peer's own ``keep=`` pruning runs concurrently — a file listed by
        ``rounds()`` can be deleted before ``read()`` opens it.  A pruned
        miss is retried against a fresh scan (pruning only ever deletes
        *older* publishes, so each retry targets a newer round and the
        loop terminates); ``None`` is returned only when a rescan shows
        nothing ≤ the bound remains.
        """
        while True:
            have = [r for r in self.rounds(host) if r <= round_index]
            if not have:
                return None
            r = have[-1]
            try:
                return self.read(host, r, template), r
            except FileNotFoundError:
                continue  # pruned between the scan and the open — rescan

    def clocks(self) -> Dict[int, int]:
        return {h: self.clock(h) for h in range(self.num_hosts)}

    def prune(self, keep: int) -> None:
        prune_checkpoints(self._host_dir(self.host_id), keep)
