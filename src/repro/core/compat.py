"""Version compatibility for the handful of jax APIs whose spelling moved.

The repo targets current jax (``jax.shard_map``, ``check_vma``,
``jax.make_mesh(..., axis_types=...)``, ``jax.lax.axis_size``) but must also
run on the 0.4.x line where those live under older names.  Everything that
touches a mesh goes through these three wrappers so the rest of the codebase
can use one spelling.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax

__all__ = ["shard_map", "make_mesh", "axis_size", "tpu_compiler_params"]


def tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params; current jax spells the class
    ``pltpu.CompilerParams``, 0.4.x spells it ``pltpu.TPUCompilerParams``."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


_SHARD_MAP_IMPL = None  # (callable, check_kwarg_name), resolved lazily once


def _resolve_shard_map():
    global _SHARD_MAP_IMPL
    if _SHARD_MAP_IMPL is None:
        import inspect

        sm = getattr(jax, "shard_map", None)
        if sm is None:
            from jax.experimental.shard_map import shard_map as sm
        # the public promotion (jax.shard_map) and the flag rename
        # (check_rep -> check_vma) landed in different releases, so feature-
        # test the signature instead of inferring one from the other
        flag = ("check_vma" if "check_vma" in inspect.signature(sm).parameters
                else "check_rep")
        _SHARD_MAP_IMPL = (sm, flag)
    return _SHARD_MAP_IMPL


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off (our collectives
    intentionally produce replicated outputs from sharded inputs).

    Current jax spells the flag ``check_vma``; older lines spell it
    ``check_rep`` and may keep shard_map under ``jax.experimental`` — both
    moves are feature-tested independently.
    """
    sm, flag = _resolve_shard_map()
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{flag: False})


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]) -> Any:
    """``jax.make_mesh`` with explicit-collective (Auto) axis types where the
    installed jax supports them; plain mesh otherwise."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axis_names)))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def axis_size(name: str) -> int:
    """Static size of a named mesh axis, callable inside a shard_map body."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    from jax._src.core import get_axis_env

    return get_axis_env().axis_size(name)
