"""Multi-host (multi-controller) mesh bootstrap and data placement.

One process per host, every process running the same SPMD program — the
standard jax multi-controller model.  On CPU (the CI container) cross-host
collectives go through gloo over TCP, which must be selected *before* the
backend initializes; :func:`initialize` owns that ordering, and
:func:`initialize_from_env` makes it a one-liner for subprocess-simulated
hosts (the chaos harness and ``launch/fit.py --hosts`` both launch children
with the ``REPRO_*`` variables below).

After initialization the existing single-process code is almost unchanged:
``jax.devices()`` spans every host, :func:`repro.core.compat.make_mesh`
builds the global mesh, and ``shard_map`` collectives lower to real
cross-host wire traffic.  The two genuinely multi-host concerns live here:

  * **placement** — a host can only ``device_put`` to its own devices, so
    globally-sharded arrays are assembled from per-process row slices with
    :func:`place_global_rows` (each host contributes exactly the rows its
    local devices own — no scatter through a driver, same property as the
    single-host ``shard_batch``);
  * **fetching** — fully-replicated outputs (the runner's combines produce
    them) are addressable everywhere, :func:`fetch` asserts that before
    converting so a non-replicated array fails loudly instead of hanging.

Environment contract (set by the launcher/harness for every host process):

    REPRO_COORDINATOR   host:port of process 0's coordination service
    REPRO_NUM_HOSTS     total host processes in the mesh
    REPRO_HOST_ID       this process's id in [0, num_hosts)
"""
from __future__ import annotations

import dataclasses
import os
import socket
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core import partition as pt

__all__ = [
    "HostInfo",
    "free_port",
    "initialize",
    "initialize_from_env",
    "is_multihost",
    "host_id",
    "num_hosts",
    "local_row_slice",
    "place_global_rows",
    "fetch",
]


@dataclasses.dataclass(frozen=True)
class HostInfo:
    """What a host process knows about its place in the mesh."""

    host_id: int
    num_hosts: int
    coordinator: Optional[str] = None

    @property
    def multihost(self) -> bool:
        return self.num_hosts > 1


def free_port() -> int:
    """An OS-assigned free TCP port (for a generation's coordinator)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def initialize(coordinator: str, num_hosts: int, host_id: int) -> HostInfo:
    """Join the multi-controller mesh.  Must run before anything touches the
    jax backend (device queries included) — gloo collectives can only be
    selected pre-initialization.
    """
    if num_hosts < 2:
        return HostInfo(host_id=0, num_hosts=1)
    try:
        # CPU cross-process collectives need the gloo implementation; it
        # must be selected before the backend initializes.  TPU/GPU ignore
        # it in favor of the native interconnect.
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # pragma: no cover - newer jax always has the option
        pass
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=int(num_hosts),
                               process_id=int(host_id))
    return HostInfo(host_id=int(host_id), num_hosts=int(num_hosts),
                    coordinator=coordinator)


def initialize_from_env() -> HostInfo:
    """Bootstrap from the ``REPRO_*`` launcher contract; a no-op single-host
    :class:`HostInfo` when the variables are absent, so programs can call
    this unconditionally as their first line.

    ``REPRO_COORDINATOR`` is deliberately separate from ``REPRO_NUM_HOSTS``:
    the SSP exchange lane launches N *independent* hosts (id + world size,
    no global mesh), so its launcher sets the ids but no coordinator and
    this stays a no-op — only the BSP gang, which needs real cross-host
    collectives, gets a coordinator."""
    n = int(os.environ.get("REPRO_NUM_HOSTS", "1"))
    coordinator = os.environ.get("REPRO_COORDINATOR")
    if n < 2 or not coordinator:
        return HostInfo(host_id=int(os.environ.get("REPRO_HOST_ID", "0")),
                        num_hosts=1)
    return initialize(coordinator, n, int(os.environ["REPRO_HOST_ID"]))


def is_multihost() -> bool:
    return jax.process_count() > 1


def host_id() -> int:
    return jax.process_index()


def num_hosts() -> int:
    return jax.process_count()


def local_row_slice(num_rows: int, mesh: Mesh,
                    data_axes: Tuple[str, ...]) -> slice:
    """The contiguous row range of a ``(num_rows, ...)`` globally-sharded
    array owned by this process's devices.

    Row partitions follow global device order (process-major), so process
    ``p`` of ``P`` owns rows ``[p * num_rows / P, (p + 1) * num_rows / P)``
    — every process must hold equally many of the mesh's data shards
    (true for subprocess-simulated hosts and for real pods).
    """
    procs = jax.process_count()
    shards = pt.num_data_shards(mesh, data_axes)
    if shards % procs != 0:
        raise ValueError(
            f"{shards} data shards do not divide over {procs} host "
            f"processes — every host must carry equally many shards")
    pt.check_rows_divisible(num_rows, shards, what="global row partitions")
    per = num_rows // procs
    p = jax.process_index()
    return slice(p * per, (p + 1) * per)


def place_global_rows(host_rows: np.ndarray, num_rows: int, mesh: Mesh,
                      data_axes: Tuple[str, ...]) -> jax.Array:
    """Assemble a globally row-sharded array from this process's row slice.

    ``host_rows`` is exactly the slice :func:`local_row_slice` describes;
    every process calls this with its own slice and receives a handle on
    the one global array.  The multi-host twin of
    :func:`repro.core.partition.place_rows`.
    """
    sharding = NamedSharding(mesh, pt.data_spec(data_axes))
    global_shape = (num_rows,) + tuple(host_rows.shape[1:])
    return jax.make_array_from_process_local_data(
        sharding, np.asarray(host_rows), global_shape)


def fetch(array) -> np.ndarray:
    """Bring a fully-replicated global array to the host as numpy.

    Every combine the runner performs produces replicated outputs
    (``out_specs=P()``), so results are addressable on every host; anything
    else reaching here is a programming error worth failing loudly on
    (converting a non-replicated global array would otherwise hang or
    fetch garbage on a multi-host mesh).
    """
    if isinstance(array, jax.Array) and not array.is_fully_replicated:
        raise ValueError(
            f"array with sharding {array.sharding} is not fully replicated "
            f"— only replicated results can be fetched on every host")
    return np.asarray(jax.device_get(array))
