"""Device-tier MLNumericTable (paper §III-A).

An MLNumericTable is the all-numeric table most algorithms consume: each row
is one feature vector.  Here it is a 2-D ``jnp`` array partitioned row-wise.
Two execution modes:

  * **mesh mode** — the array is placed with a ``NamedSharding`` over the mesh
    ``data`` axis and ``matrixBatchMap`` runs the partition function through
    ``shard_map``: each device sees its block as a :class:`LocalMatrix`,
    exactly the paper's "batch operation on a partition".
  * **emulated mode** (no mesh, e.g. unit tests on one CPU device) — the array
    is split into ``num_shards`` logical partitions and the partition function
    is applied per block inside one jit trace.  Semantics are identical; this
    mirrors running the Spark implementation with `local[n]`.

Global combination is *explicit* (reduce / matrixBatchMap + reduce), keeping
the paper's shared-nothing principle: no hidden distributed linalg.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import partition as pt
from repro.core.local_matrix import LocalMatrix

__all__ = ["MLNumericTable"]


def _tree_fold_rows(rows: jnp.ndarray, fn: Callable, identity: jnp.ndarray) -> jnp.ndarray:
    """Log-depth tree reduction of (n, d) rows with an associative,
    commutative ``fn((d,), (d,)) -> (d,)`` — the device-tier analogue of the
    paper's ``reduce``."""
    n = rows.shape[0]
    if n == 0:
        return identity
    pow2 = 1 << (n - 1).bit_length()
    if pow2 != n:
        pad = jnp.broadcast_to(identity, (pow2 - n,) + rows.shape[1:])
        rows = jnp.concatenate([rows, pad], axis=0)
    while rows.shape[0] > 1:
        half = rows.shape[0] // 2
        rows = jax.vmap(fn)(rows[:half], rows[half:])
    return rows[0]


class MLNumericTable:
    """Row-partitioned numeric table; the input type of MLI algorithms."""

    DATA_AXIS = "data"

    def __init__(
        self,
        data: jnp.ndarray,
        num_shards: int,
        mesh: Optional[Mesh] = None,
        names: Optional[Sequence[Optional[str]]] = None,
        data_axes: Optional[Tuple[str, ...]] = None,
    ) -> None:
        if data.ndim != 2:
            raise ValueError("MLNumericTable holds a 2-D (rows, features) array")
        self.mesh = mesh
        self.names = tuple(names) if names is not None else None
        if mesh is not None:
            if data_axes is None:
                data_axes = pt.infer_data_axes(mesh)
            self.data_axes: Tuple[str, ...] = data_axes
            num_shards = pt.num_data_shards(mesh, self.data_axes)
            pt.check_rows_divisible(
                data.shape[0], num_shards,
                what=f"devices on axes {self.data_axes}")
            data = pt.place_rows(data, mesh, self.data_axes)
        else:
            self.data_axes = ()
            pt.check_rows_divisible(data.shape[0], num_shards)
        self.data = data
        self.num_shards = int(num_shards)

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @classmethod
    def from_numpy(cls, array: np.ndarray, num_shards: Optional[int] = None,
                   mesh: Optional[Mesh] = None,
                   names: Optional[Sequence[Optional[str]]] = None) -> "MLNumericTable":
        arr = jnp.asarray(array)
        if mesh is None and num_shards is None:
            num_shards = 1
        return cls(arr, num_shards=num_shards or 1, mesh=mesh, names=names)

    # ------------------------------------------------------------------ #
    # introspection
    # ------------------------------------------------------------------ #
    @property
    def num_rows(self) -> int:
        return self.data.shape[0]

    @property
    def num_cols(self) -> int:
        return self.data.shape[1]

    numRows, numCols = num_rows, num_cols  # paper spelling

    @property
    def rows_per_shard(self) -> int:
        return self.num_rows // self.num_shards

    def to_numpy(self) -> np.ndarray:
        return np.asarray(self.data)

    def to_local_matrix(self) -> LocalMatrix:
        """Materialize the *whole* table as one LocalMatrix (small tables /
        final factors only — deliberately explicit, per the paper's refusal
        to hide global operations)."""
        return LocalMatrix(self.data)

    toLocalMatrix = to_local_matrix

    @property
    def context(self):  # parity with the paper's ``trainData.context``
        return self

    def broadcast(self, value):
        """Paper's ``ctx.broadcast``: in SPMD the replicated value IS the
        broadcast; returned as-is so reference code reads identically."""
        return value

    # ------------------------------------------------------------------ #
    # row-wise ops (device tier)
    # ------------------------------------------------------------------ #
    def map_rows(self, fn: Callable[[jnp.ndarray], jnp.ndarray]) -> "MLNumericTable":
        out = jax.vmap(fn)(self.data)
        if out.ndim == 1:
            out = out[:, None]
        return MLNumericTable(out, num_shards=self.num_shards, mesh=self.mesh,
                              data_axes=self.data_axes or None)

    def filter_mask(self, pred: Callable[[jnp.ndarray], jnp.ndarray]) -> jnp.ndarray:
        """Static-shape filter: returns the row validity mask (TPU cannot drop
        rows dynamically; downstream ops take the mask)."""
        return jax.vmap(pred)(self.data)

    def reduce(self, fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
               identity: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Combine all rows with an associative+commutative fn (Fig. A1).

        Reduces within each partition, then across partitions — matching the
        distributed execution order."""
        if identity is None:
            identity = jnp.zeros((self.num_cols,), self.data.dtype)

        def shard_reduce(block: jnp.ndarray) -> jnp.ndarray:
            return _tree_fold_rows(block, fn, identity)

        partials = self._per_shard(shard_reduce)          # (num_shards, d)
        return _tree_fold_rows(partials, fn, identity)

    def sum_rows(self) -> jnp.ndarray:
        return jnp.sum(self.data, axis=0)

    def mean_rows(self) -> jnp.ndarray:
        return jnp.mean(self.data, axis=0)

    # ------------------------------------------------------------------ #
    # matrixBatchMap — the heart of the MLI API (Fig. A1)
    # ------------------------------------------------------------------ #
    def matrix_batch_map(
        self,
        fn: Callable[..., LocalMatrix],
        *broadcast_args: Any,
        out_rows_per_shard: Optional[int] = None,
    ) -> "MLNumericTable":
        """Execute ``fn(local_partition, *broadcast_args)`` on every partition
        and concatenate the output matrices row-wise into a new table.

        ``broadcast_args`` are replicated to every partition (the paper's
        driver-side broadcast).  ``fn`` receives a LocalMatrix and must return
        a LocalMatrix (or array) with a fixed number of rows per shard.
        """
        def block_fn(block: jnp.ndarray, *args: Any) -> jnp.ndarray:
            out = fn(LocalMatrix(block), *args)
            out = out.data if isinstance(out, LocalMatrix) else jnp.asarray(out)
            if out.ndim == 1:
                out = out[:, None]
            return out

        stacked = self._per_shard(block_fn, *broadcast_args)  # (shards, r, c)
        flat = stacked.reshape((-1, stacked.shape[-1]))
        if self.mesh is not None:
            flat = pt.place_rows(flat, self.mesh, self.data_axes)
        return MLNumericTable(flat, num_shards=self.num_shards, mesh=self.mesh,
                              data_axes=self.data_axes or None)

    matrixBatchMap = matrix_batch_map  # paper spelling

    # ------------------------------------------------------------------ #
    # execution engine
    # ------------------------------------------------------------------ #
    def _per_shard(self, block_fn: Callable, *broadcast_args: Any) -> jnp.ndarray:
        """Run ``block_fn`` on every partition; return stacked results
        (num_shards, ...).  Execution is delegated to the shared
        :class:`repro.core.runner.DistributedRunner` engine (shard_map when
        a mesh is attached, a partition loop otherwise)."""
        from repro.core.runner import DistributedRunner

        runner = DistributedRunner.for_table(self)
        return runner.partition_apply(self.data, block_fn, broadcast_args)

    def __repr__(self) -> str:  # pragma: no cover
        where = f"mesh{tuple(self.mesh.shape.items())}" if self.mesh is not None else "local"
        return (f"MLNumericTable(rows={self.num_rows}, cols={self.num_cols}, "
                f"shards={self.num_shards}, {where})")
