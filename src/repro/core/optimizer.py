"""Optimizers as first-class citizens (paper §III-C, reference impl Fig. A4).

The paper's reference optimizer is *partition-local SGD with global parameter
averaging each round* — their approximation of Vowpal Wabbit.  The local pass
is a sequential fold over the partition's rows; the global combine is a mean
over partitions whose wire schedule is selectable (see
:mod:`repro.core.collectives`).

We provide:
  * ``StochasticGradientDescent`` — Fig. A4 faithful: per-row local SGD +
    averaging; supports an optional proximal operator (the paper notes L1
    needs one) and a ``local_batch_size`` to vectorize the local pass
    (beyond-paper throughput knob; ``1`` reproduces the paper exactly).
  * ``GradientDescent`` — the MATLAB reference (Fig. A4 top): full-batch
    vectorized gradient, global sum, single update.
  * ``MinibatchSGD`` — per-round minibatch per partition (the paper's
    "matrix/vector multiplication in the case of mini-batch SGD").

All three run the same code path on one CPU device (emulated partitions) and
on a pod mesh (shard_map over the data axes): iteration, partitioning, and
the collective schedule are owned by
:class:`repro.core.runner.DistributedRunner` — the optimizers only supply
the partition-local step (see docs/architecture.md).
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.collectives import CollectiveSchedule
from repro.core.local_matrix import LocalMatrix
from repro.core.numeric_table import MLNumericTable
from repro.core.runner import CheckpointPolicy, DistributedRunner

__all__ = [
    "Optimizer",
    "StochasticGradientDescentParameters",
    "StochasticGradientDescent",
    "GradientDescentParameters",
    "GradientDescent",
    "MinibatchSGDParameters",
    "MinibatchSGD",
    "soft_threshold",
    "sgd_trial_round",
]

# grad_fn(row_including_label, weights) -> gradient wrt weights  (paper Fig A4)
GradFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
# prox_fn(weights, step) -> weights  (proximal operator, e.g. L1 soft-threshold)
ProxFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
# hyper_grad_fn(row, weights, hyper) -> gradient, reading traced
# hyperparameters (e.g. hyper["l2"]) instead of baked-in Python constants
HyperGradFn = Callable[[jnp.ndarray, jnp.ndarray, dict], jnp.ndarray]


def soft_threshold(lam: float) -> ProxFn:
    """Proximal operator of ``lam * ||w||_1`` (paper §IV: 'adding a proximal
    operator in the case of L1-regularization')."""

    def prox(w: jnp.ndarray, step: jnp.ndarray) -> jnp.ndarray:
        t = lam * step
        return jnp.sign(w) * jnp.maximum(jnp.abs(w) - t, 0.0)

    return prox


class Optimizer(abc.ABC):
    """MLOpt: optimize parameters against an MLNumericTable."""

    @abc.abstractmethod
    def apply(self, data: MLNumericTable, params) -> jnp.ndarray:
        ...

    def __call__(self, data: MLNumericTable, params) -> jnp.ndarray:
        return self.apply(data, params)


# --------------------------------------------------------------------------- #
# shared machinery
# --------------------------------------------------------------------------- #
def _spmd_rounds(
    data: MLNumericTable,
    w_init: jnp.ndarray,
    num_rounds: int,
    local_round: Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray],
    schedule: CollectiveSchedule,
    combine: str = "mean",
    update=None,
) -> jnp.ndarray:
    """Run ``num_rounds`` of: local_round(block, weights, round) per partition
    → global combine → next round.  This is the paper's main SGD loop
    (Fig. A4 middle); iteration, partitioning, and the combine schedule all
    live in the shared :class:`DistributedRunner`."""
    runner = DistributedRunner.for_table(data, schedule=schedule)
    return runner.run_rounds(data, w_init, local_round, num_rounds,
                             combine=combine, update=update)


def _stream_fit(
    stream,
    w_init: jnp.ndarray,
    num_epochs: int,
    local_round: Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray],
    schedule: CollectiveSchedule,
    *,
    num_shards: int = 1,
    chunks_per_epoch: Optional[int] = None,
    checkpoint: Optional[CheckpointPolicy] = None,
    resume: bool = False,
    store=None,
    staleness: int = 0,
    allow_resize: bool = False,
    trace: Optional[list] = None,
) -> jnp.ndarray:
    """Streaming counterpart of :func:`_spmd_rounds`: one window per epoch
    from ``stream`` (a :class:`repro.data.pipeline.BatchIterator`), iterated
    by :meth:`DistributedRunner.run_epochs` with mean-combined weights.
    With ``resume=True`` the run restarts from ``checkpoint.ckpt_dir``;
    ``chunks_per_epoch=None`` then inherits the checkpointed layout, while
    an explicit value is cross-checked against it (mismatch raises).

    ``store`` (a :class:`repro.core.exchange.ParamStore`) switches to the
    stale-synchronous lane: independent hosts exchanging post-epoch weights
    under the ``staleness`` bound (:meth:`DistributedRunner.run_epochs_ssp`).
    ``allow_resize`` lets a resume repartition onto a different world size
    (the elastic path)."""
    runner = DistributedRunner(mesh=getattr(stream, "mesh", None),
                               num_shards=num_shards, schedule=schedule)
    if store is not None:
        if resume:
            if checkpoint is None:
                raise ValueError("resume=True requires a CheckpointPolicy")
            return runner.resume_ssp(checkpoint.ckpt_dir, stream, w_init,
                                     local_round, num_epochs, store=store,
                                     staleness=staleness, combine="mean",
                                     checkpoint=checkpoint, trace=trace)
        return runner.run_epochs_ssp(stream, w_init, local_round, num_epochs,
                                     store=store, staleness=staleness,
                                     combine="mean",
                                     chunks_per_epoch=chunks_per_epoch or 1,
                                     checkpoint=checkpoint, trace=trace)
    if resume:
        if checkpoint is None:
            raise ValueError("resume=True requires a CheckpointPolicy")
        return runner.resume(checkpoint.ckpt_dir, stream, w_init, local_round,
                             num_epochs, combine="mean",
                             chunks_per_epoch=chunks_per_epoch,
                             checkpoint=checkpoint, allow_resize=allow_resize)
    return runner.run_epochs(stream, w_init, local_round, num_epochs,
                             combine="mean",
                             chunks_per_epoch=chunks_per_epoch or 1,
                             checkpoint=checkpoint)


# --------------------------------------------------------------------------- #
# trial-stackable SGD round (model search; repro.tune)
# --------------------------------------------------------------------------- #
def sgd_trial_round(grad: HyperGradFn, local_batch_size: int = 1):
    """Trial-stackable twin of the Fig. A4 partition-local SGD pass.

    Identical fold-over-rows structure to
    :meth:`StochasticGradientDescent._local_round`, but every
    hyperparameter is read from a *traced* ``hyper`` pytree instead of
    being baked into the jit as a Python constant:

      * ``hyper["lr"]`` / ``hyper["decay"]`` — per-round step size
        ``lr * decay**r``;
      * ``hyper["l1"]`` — L1 soft-threshold applied after every update
        (``l1 = 0`` is the exact identity, so unregularized configs stack
        with regularized ones);
      * anything ``grad(vec, w, hyper)`` reads (e.g. ``hyper["l2"]``).

    Because nothing config-specific is a compile-time constant, K configs
    differing only in these values share ONE compiled round — the
    device-stacked trial executor vmaps this function over the trial axis
    (see :meth:`repro.core.runner.DistributedRunner.run_stacked_rounds`).
    Returns ``local_round(block, w, r, hyper) -> w``.
    """
    bs = int(local_batch_size)

    def local_round(block: jnp.ndarray, w: jnp.ndarray, r: jnp.ndarray,
                    hyper: dict) -> jnp.ndarray:
        rows = block.shape[0]
        if rows % bs != 0:
            raise ValueError(
                f"rows-per-shard {rows} must be divisible by "
                f"local_batch_size {bs}")
        lr = hyper["lr"] * hyper["decay"] ** r
        chunks = block.reshape(rows // bs, bs, block.shape[1])

        def step(w, chunk):
            g = jnp.mean(jax.vmap(grad, in_axes=(0, None, None))(chunk, w, hyper),
                         axis=0)
            w = w - lr * g
            t = hyper["l1"] * lr
            w = jnp.sign(w) * jnp.maximum(jnp.abs(w) - t, 0.0)
            return w, None

        w, _ = jax.lax.scan(step, w, chunks)
        return w

    return local_round


# --------------------------------------------------------------------------- #
# StochasticGradientDescent (paper Fig. A4)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class StochasticGradientDescentParameters:
    w_init: jnp.ndarray
    grad: GradFn
    learning_rate: float = 0.1
    max_iter: int = 10
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.GATHER_BROADCAST
    local_batch_size: int = 1      # 1 == per-point SGD, exactly the paper
    prox: Optional[ProxFn] = None
    lr_decay: float = 1.0          # multiplicative per-round decay

    # paper spelling
    @property
    def wInit(self):
        return self.w_init

    @property
    def learningRate(self):
        return self.learning_rate


class StochasticGradientDescent(Optimizer):
    """Partition-local SGD + global parameter averaging (paper Fig. A4).

    Each round, every partition folds over its rows sequentially (in chunks of
    ``local_batch_size``) updating a private copy of the weights; the copies
    are then averaged with the configured collective schedule.  This is the
    algorithm the paper describes as 'identical to VW with one meaningful
    difference, namely aggregating results across worker nodes after each
    round'.
    """

    def __init__(self, params: StochasticGradientDescentParameters):
        self.params = params

    @staticmethod
    def _local_round(p: StochasticGradientDescentParameters):
        """Build the partition-local pass (paper Fig. A4 ``localSGD``):
        a sequential fold over the block's rows in sub-batches of
        ``local_batch_size``.  Shared by the resident (:meth:`apply`) and
        streaming (:meth:`apply_stream`) paths — same compute, different
        data motion."""
        bs = int(p.local_batch_size)

        def local_sgd(block: jnp.ndarray, w: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
            rows = block.shape[0]
            if rows % bs != 0:
                raise ValueError(
                    f"rows-per-shard {rows} must be divisible by local_batch_size {bs}"
                )
            lr = p.learning_rate * (p.lr_decay ** r)
            chunks = block.reshape(rows // bs, bs, block.shape[1])

            def step(w, chunk):
                g = jnp.mean(jax.vmap(p.grad, in_axes=(0, None))(chunk, w), axis=0)
                w = w - lr * g
                if p.prox is not None:
                    w = p.prox(w, lr)
                return w, None

            w, _ = jax.lax.scan(step, w, chunks)
            return w

        return local_sgd

    def apply(self, data: MLNumericTable, params=None) -> jnp.ndarray:
        p = params or self.params
        schedule = CollectiveSchedule.parse(p.schedule)
        return _spmd_rounds(data, p.w_init, p.max_iter, self._local_round(p),
                            schedule, "mean")

    def apply_stream(self, stream, num_epochs: int, *, num_shards: int = 1,
                     chunks_per_epoch: Optional[int] = None,
                     checkpoint: Optional[CheckpointPolicy] = None,
                     resume: bool = False, params=None, store=None,
                     staleness: int = 0, allow_resize: bool = False,
                     trace: Optional[list] = None) -> jnp.ndarray:
        """Streaming fit: each epoch's window is split into
        ``chunks_per_epoch`` rounds; every round each partition folds over
        its chunk rows exactly as the resident path folds over its
        partition, then weights are mean-combined with the configured
        schedule.  ``checkpoint``/``resume`` make the run preemption-safe
        (see :class:`repro.core.runner.CheckpointPolicy`).  ``store`` +
        ``staleness`` select the stale-synchronous multi-host lane;
        ``allow_resize`` permits an elastic resume on a resized mesh."""
        p = params or self.params
        return _stream_fit(stream, p.w_init, num_epochs, self._local_round(p),
                           CollectiveSchedule.parse(p.schedule),
                           num_shards=num_shards,
                           chunks_per_epoch=chunks_per_epoch,
                           checkpoint=checkpoint, resume=resume, store=store,
                           staleness=staleness, allow_resize=allow_resize,
                           trace=trace)


# --------------------------------------------------------------------------- #
# GradientDescent (the MATLAB reference, vectorized full-batch)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class GradientDescentParameters:
    w_init: jnp.ndarray
    grad: GradFn
    learning_rate: float = 0.1
    max_iter: int = 10
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE
    prox: Optional[ProxFn] = None


class GradientDescent(Optimizer):
    """Full-batch GD: each partition computes the vectorized sum of row
    gradients; partitions combine with a global sum; one update per round."""

    def __init__(self, params: GradientDescentParameters):
        self.params = params

    def apply(self, data: MLNumericTable, params=None) -> jnp.ndarray:
        p = params or self.params
        schedule = CollectiveSchedule.parse(p.schedule)

        # The weight update needs the *summed* gradient, so the per-round
        # combine is a global sum and the update happens after the combine.
        def local_grad(block: jnp.ndarray, w: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
            return jnp.sum(jax.vmap(p.grad, in_axes=(0, None))(block, w), axis=0)

        def update(w, g, r):
            w = w - p.learning_rate * g
            if p.prox is not None:
                w = p.prox(w, p.learning_rate)
            return w

        return _spmd_rounds(data, p.w_init, p.max_iter, local_grad, schedule,
                            "sum", update=update)


# --------------------------------------------------------------------------- #
# MinibatchSGD
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class MinibatchSGDParameters:
    w_init: jnp.ndarray
    grad: GradFn
    learning_rate: float = 0.1
    max_iter: int = 100
    batch_per_shard: int = 32
    schedule: Union[str, CollectiveSchedule] = CollectiveSchedule.ALLREDUCE
    prox: Optional[ProxFn] = None


class MinibatchSGD(Optimizer):
    """Each round every partition takes one contiguous rotating minibatch,
    computes its mean gradient, partitions average, single update."""

    def __init__(self, params: MinibatchSGDParameters):
        self.params = params

    def apply(self, data: MLNumericTable, params=None) -> jnp.ndarray:
        p = params or self.params
        schedule = CollectiveSchedule.parse(p.schedule)
        bs = int(p.batch_per_shard)
        rows = data.rows_per_shard
        if rows < bs:
            raise ValueError(f"batch_per_shard {bs} exceeds rows-per-shard {rows}")
        n_batches = rows // bs

        def local_round(block: jnp.ndarray, w: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
            start = (r % n_batches) * bs
            mb = jax.lax.dynamic_slice_in_dim(block, start, bs, axis=0)
            g = jnp.mean(jax.vmap(p.grad, in_axes=(0, None))(mb, w), axis=0)
            w = w - p.learning_rate * g
            if p.prox is not None:
                w = p.prox(w, p.learning_rate)
            return w

        return _spmd_rounds(data, p.w_init, p.max_iter, local_round, schedule, "mean")

    @staticmethod
    def _streaming_round(p: MinibatchSGDParameters):
        """Streaming local round: the window chunk IS the minibatch — no
        rotating slice needed, because every round sees fresh rows from the
        stream (``batch_per_shard`` is implied by the window size and
        ``chunks_per_epoch``)."""

        def local_round(chunk: jnp.ndarray, w: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
            g = jnp.mean(jax.vmap(p.grad, in_axes=(0, None))(chunk, w), axis=0)
            w = w - p.learning_rate * g
            if p.prox is not None:
                w = p.prox(w, p.learning_rate)
            return w

        return local_round

    def apply_stream(self, stream, num_epochs: int, *, num_shards: int = 1,
                     chunks_per_epoch: Optional[int] = None,
                     checkpoint: Optional[CheckpointPolicy] = None,
                     resume: bool = False, params=None, store=None,
                     staleness: int = 0, allow_resize: bool = False,
                     trace: Optional[list] = None) -> jnp.ndarray:
        """Streaming minibatch SGD: each of the window's
        ``chunks_per_epoch`` chunks is one per-partition minibatch — mean
        gradient, local update, mean-combined weights.  Preemption-safe via
        ``checkpoint``/``resume``; ``store`` + ``staleness`` select the
        stale-synchronous multi-host lane, ``allow_resize`` the elastic
        resume."""
        p = params or self.params
        return _stream_fit(stream, p.w_init, num_epochs,
                           self._streaming_round(p),
                           CollectiveSchedule.parse(p.schedule),
                           num_shards=num_shards,
                           chunks_per_epoch=chunks_per_epoch,
                           checkpoint=checkpoint, resume=resume, store=store,
                           staleness=staleness, allow_resize=allow_resize,
                           trace=trace)
