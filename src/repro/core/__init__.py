"""MLI core API (the paper's contribution): MLTable, LocalMatrix,
Optimizer/Algorithm/Model, the collective schedules that make global
combination explicit, and the DistributedRunner execution layer every
algorithm delegates to (see docs/architecture.md)."""
from repro.core.schema import EMPTY, Column, ColumnType, MLRow, Schema
from repro.core.mltable import MLTable
from repro.core.numeric_table import MLNumericTable
from repro.core.local_matrix import LocalMatrix, PaddedCSR
from repro.core.collectives import (
    CollectiveSchedule,
    combine_concat,
    combine_mean,
    combine_sum,
)
from repro.core.runner import CheckpointPolicy, DistributedRunner
from repro.core.optimizer import (
    GradientDescent,
    GradientDescentParameters,
    MinibatchSGD,
    MinibatchSGDParameters,
    Optimizer,
    StochasticGradientDescent,
    StochasticGradientDescentParameters,
    soft_threshold,
)
from repro.core.interfaces import (
    Algorithm,
    Estimator,
    FittedEstimator,
    FittedTransformer,
    Model,
    NumericAlgorithm,
    Searchable,
    StreamFitable,
    Transformer,
)

__all__ = [
    "EMPTY", "Column", "ColumnType", "MLRow", "Schema",
    "MLTable", "MLNumericTable", "LocalMatrix", "PaddedCSR",
    "CollectiveSchedule", "combine_mean", "combine_sum", "combine_concat",
    "CheckpointPolicy", "DistributedRunner",
    "Optimizer",
    "StochasticGradientDescent", "StochasticGradientDescentParameters",
    "GradientDescent", "GradientDescentParameters",
    "MinibatchSGD", "MinibatchSGDParameters",
    "soft_threshold",
    "Algorithm", "Model", "NumericAlgorithm",
    "Estimator", "FittedEstimator", "Transformer", "FittedTransformer",
    "StreamFitable", "Searchable",
]
