"""Run every benchmark (one per paper table/figure + beyond-paper):

    PYTHONPATH=src python -m benchmarks.run [--fast]

  loc_table             Fig 2a / 3a   lines of code
  logreg_scaling        Fig 2b/2c, A5/A6  weak+strong scaling
  als_scaling           Fig 3b/3c, A7/A8  weak+strong scaling
  collective_schedules  §IV-A  MLI gather-broadcast vs VW allreduce
  kernel_bench          (beyond paper)  kernel traffic models
  roofline              (beyond paper)  per-arch dry-run roofline table
  model_search          (beyond paper)  stacked vs sequential trials/sec
  serving_throughput    (beyond paper)  continuous vs static batching
  pipeline_e2e          (beyond paper)  Fig. A2 pipeline fit+serve rows/sec
  elastic_ssp           (beyond paper)  BSP vs SSP under a straggler +
                                        elastic host-kill recovery timing
  shardlint_bench       (beyond paper)  lint + hot-path jaxpr audit cost
                                        vs the 30s CI budget

(streaming_throughput, model_search, serving_throughput, and elastic_ssp
can also run standalone: ``python -m benchmarks.<name>``.)
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer device counts for the scaling benches")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (als_scaling, collective_schedules, elastic_ssp,
                            kernel_bench, loc_table, logreg_scaling,
                            model_search, pipeline_e2e, roofline,
                            serving_throughput, shardlint_bench)

    devices = "1,2,4" if args.fast else "1,2,4,8"
    jobs = [
        ("loc_table", loc_table.main, []),
        ("logreg_scaling", logreg_scaling.main, ["--devices", devices]),
        ("als_scaling", als_scaling.main, ["--devices", devices]),
        ("collective_schedules", collective_schedules.main, []),
        ("kernel_bench", kernel_bench.main, []),
        ("roofline", roofline.main, []),
        ("model_search", model_search.main, []),
        ("serving_throughput", serving_throughput.main, []),
        ("pipeline_e2e", pipeline_e2e.main, []),
        ("elastic_ssp", elastic_ssp.main, []),
        ("shardlint_bench", shardlint_bench.main, ["--check"]),
    ]
    failures = 0
    for name, fn, argv in jobs:
        if args.only and args.only != name:
            continue
        print(f"### {name}")
        sys.argv = [name] + argv
        t0 = time.time()
        try:
            fn()
            print(f"### {name} done in {time.time()-t0:.1f}s\n")
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"### {name} FAILED\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
