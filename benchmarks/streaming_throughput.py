"""Resident rounds vs streaming epochs through the shared DistributedRunner
(see docs/benchmarks.md).

Both modes run the same partition-local SGD workload over the same number
of rows on a real multi-device mesh (subprocess, since the device count
must be fixed before jax initializes):

  * **resident** — the paper's §IV loop: the whole table lives on the
    mesh, ``run_rounds`` scans full-table rounds inside one jit.
  * **streaming** — ``run_epochs``: each epoch's window crosses the
    host→device boundary (``shard_batch`` placement) and is scanned in
    chunks; this is the mode that scales past device memory and pairs with
    checkpoint/resume.

The delta between the two rows is the streaming tax: host batch
generation + device placement + one jit dispatch per epoch, amortized
over the window.  Swept across all three collective schedules so the wire
pattern and the data motion can be read off independently.
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks._util import emit, run_with_devices

DEVICES = 8
ROWS = 4096          # rows per pass (window size in streaming mode)
D = 128
PASSES = 5           # rounds (resident) == epochs (streaming)
CHUNKS = 4           # streaming minibatch chunks per window


def _worker() -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks._util import timeit
    from repro.core.collectives import CollectiveSchedule
    from repro.core.compat import make_mesh
    from repro.core.numeric_table import MLNumericTable
    from repro.core.runner import DistributedRunner
    from repro.data import BatchIterator, synth_classification

    devices = len(jax.devices())
    mesh = make_mesh((devices,), ("data",))

    X, y, _ = synth_classification(ROWS, D, seed=0)
    data = np.concatenate([y[:, None], X], 1).astype(np.float32)
    table = MLNumericTable.from_numpy(data, mesh=mesh)

    def source(step: int) -> dict:
        rng = np.random.default_rng(step)
        Xs = rng.normal(size=(ROWS, D)).astype(np.float32)
        ys = (Xs @ np.linspace(-1, 1, D) > 0).astype(np.float32)
        return {"data": np.concatenate([ys[:, None], Xs], 1).astype(np.float32)}

    def grad(vec, w):
        x = vec[1:]
        return x * (jax.nn.sigmoid(jnp.dot(x, w)) - vec[0])

    def local_step(block, w, r):
        g = jnp.mean(jax.vmap(grad, in_axes=(0, None))(block, w), axis=0)
        return w - 0.3 * g

    total_rows = ROWS * PASSES
    rows_out = []
    for sched in CollectiveSchedule:
        runner = DistributedRunner(mesh=mesh, schedule=sched)

        def resident():
            return runner.run_rounds(table, jnp.zeros(D, jnp.float32),
                                     local_step, PASSES, combine="mean")

        def streaming():
            stream = BatchIterator(source, mesh=mesh)
            return runner.run_epochs(stream, jnp.zeros(D, jnp.float32),
                                     local_step, PASSES, combine="mean",
                                     chunks_per_epoch=CHUNKS)

        for mode, fn in (("resident", resident), ("streaming", streaming)):
            t = timeit(fn, warmup=1, iters=3)
            rows_out.append({"mode": mode, "schedule": sched.value,
                             "seconds": round(t, 4),
                             "rows_per_sec": int(total_rows / t)})
    print(json.dumps({"devices": devices, "rows": rows_out}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--_worker", action="store_true")
    args = ap.parse_args()
    if args._worker:
        _worker()
        return

    res = run_with_devices("benchmarks.streaming_throughput", DEVICES, {})
    emit("streaming_throughput", res["rows"])


if __name__ == "__main__":
    main()
