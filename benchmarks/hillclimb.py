"""§Perf hillclimb driver: measure one named variant of a target
(arch × shape) pair through the same dry-run machinery as the baseline
(lower + compile + probe-extrapolated roofline terms) and save JSON.

    PYTHONPATH=src python -m benchmarks.hillclimb --list
    PYTHONPATH=src python -m benchmarks.hillclimb --run h1a_granite_decode_serve_rules

Each entry is one hypothesis→change→measure cycle; the log narrative lives
in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys


def _experiments():
    # imported lazily — repro.launch.dryrun sets XLA_FLAGS on import
    from repro.configs import get_config
    from repro.sharding.rules import DEFAULT_RULES, SERVE_RULES

    g = get_config

    def cfg(arch, **kw):
        return dataclasses.replace(g(arch), **kw)

    return {
        # --- H1: granite-3-8b decode_32k (most collective-bound pair) ----
        "h1a_granite_decode_serve_rules": dict(
            arch="granite-3-8b", shape="decode_32k", rules=SERVE_RULES),
        "h1b_granite_decode_serve_rules_multipod": dict(
            arch="granite-3-8b", shape="decode_32k", rules=SERVE_RULES,
            multi_pod=True),
        "h1c_granite_decode_seqpar_cache": dict(
            arch="granite-3-8b", shape="decode_32k",
            rules=DEFAULT_RULES.replace(kv_seq=("data", "model"))),
        # H1d: serving-tuned mesh factorization — kv_heads(8) must divide the
        # model axis for the cache IO layout to match GSPMD's head-parallel
        # attention; (32, 8) removes the per-step cache all-gather entirely.
        "h1d_granite_decode_mesh32x8": dict(
            arch="granite-3-8b", shape="decode_32k", mesh_shape=(32, 8)),
        "h1e_granite_decode_mesh32x8_serve_rules": dict(
            arch="granite-3-8b", shape="decode_32k", mesh_shape=(32, 8),
            rules=SERVE_RULES),
        # --- H2: gemma3-1b train_4k (worst memory-bound; V=262144) -------
        "h2a_gemma3_train_chunked_xent": dict(
            arch="gemma3-1b", shape="train_4k",
            cfg=cfg("gemma3-1b", loss_vocab_chunk=16384)),
        "h2b_gemma3_train_chunked_xent_8k": dict(
            arch="gemma3-1b", shape="train_4k",
            cfg=cfg("gemma3-1b", loss_vocab_chunk=8192)),
        "h2c_gemma3_train_no_remat": dict(
            arch="gemma3-1b", shape="train_4k",
            cfg=cfg("gemma3-1b", remat=False)),
        # H2e: local-attention window waste — q_chunk 1024 pads the key span
        # to C + roundup(W, C) = 2048 for a 512 window; q_chunk 512 halves
        # the true score traffic (probe metric sees one chunk body).
        "h2e_gemma3_train_qchunk512": dict(
            arch="gemma3-1b", shape="train_4k",
            cfg=cfg("gemma3-1b", q_chunk=512)),
        "h2f_gemma3_train_qchunk512_chunked_xent": dict(
            arch="gemma3-1b", shape="train_4k",
            cfg=cfg("gemma3-1b", q_chunk=512, loss_vocab_chunk=16384)),
        # H2g: gemma3 has 4 q heads -> replicated attention on any model
        # axis > 4; (64, 4) factorization shards all 4 heads.
        "h2g_gemma3_train_mesh64x4_qchunk512": dict(
            arch="gemma3-1b", shape="train_4k", mesh_shape=(64, 4),
            cfg=cfg("gemma3-1b", q_chunk=512)),
        "h2d_gemma3_train_chunked_xent_no_remat": dict(
            arch="gemma3-1b", shape="train_4k",
            cfg=cfg("gemma3-1b", loss_vocab_chunk=16384, remat=False)),
        # --- H3: llama4-scout train_4k (paper-technique representative:
        #         expert-parallel MoE + data-parallel gradient combine) ----
        "h3a_llama4_train_gather_dispatch": dict(
            arch="llama4-scout-17b-16e", shape="train_4k",
            cfg=cfg("llama4-scout-17b-16e", moe_dispatch="gather")),
        # H3b': 40 heads % 16 != 0 -> attention replicated over the model
        # axis (16x redundant).  (32, 8) factorization: 40 % 8 == 0.
        "h3d_llama4_train_mesh32x8": dict(
            arch="llama4-scout-17b-16e", shape="train_4k", mesh_shape=(32, 8)),
        "h3e_llama4_train_mesh32x8_chunked_xent": dict(
            arch="llama4-scout-17b-16e", shape="train_4k", mesh_shape=(32, 8),
            cfg=cfg("llama4-scout-17b-16e", loss_vocab_chunk=12628)),
        "h3f_llama4_train_mesh32x8_gather": dict(
            arch="llama4-scout-17b-16e", shape="train_4k", mesh_shape=(32, 8),
            cfg=cfg("llama4-scout-17b-16e", moe_dispatch="gather")),
        # H1f: int8 KV cache on the serving mesh (memory term is now the
        # decode bottleneck; the cache read dominates it).
        "h1f_granite_decode_mesh32x8_int8": dict(
            arch="granite-3-8b", shape="decode_32k", mesh_shape=(32, 8),
            cfg=cfg("granite-3-8b", cache_dtype="int8")),
        # H5: prefill collectives are FSDP weight all-gathers amortized
        # over only 32 sequences; SERVE_RULES (weights on "model") converts
        # them to activation-sized TP reductions.
        "h5a_mixtral_prefill_serve_rules": dict(
            arch="mixtral-8x22b", shape="prefill_32k", rules=SERVE_RULES),
        "h4b_llava_train_mesh32x8": dict(
            arch="llava-next-34b", shape="train_4k", mesh_shape=(32, 8)),
        "h4c_qwen2_train_mesh64x4": dict(
            arch="qwen2-1.5b", shape="train_4k", mesh_shape=(64, 4)),
        "h4a_qwen15_train_mesh32x8": dict(
            arch="qwen1.5-32b", shape="train_4k", mesh_shape=(32, 8)),
        "h3b_llama4_train_gather_plus_chunked_xent": dict(
            arch="llama4-scout-17b-16e", shape="train_4k",
            cfg=cfg("llama4-scout-17b-16e", moe_dispatch="gather",
                    loss_vocab_chunk=12628)),
        "h3c_llama4_train_chunked_xent_only": dict(
            arch="llama4-scout-17b-16e", shape="train_4k",
            cfg=cfg("llama4-scout-17b-16e", loss_vocab_chunk=12628)),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--run", default=None)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    from repro.launch.dryrun import run_pair  # sets XLA_FLAGS first
    from repro.sharding.rules import DEFAULT_RULES

    exps = _experiments()
    if args.list or not args.run:
        for name, spec in exps.items():
            print(f"{name}: {spec['arch']} x {spec['shape']}")
        return
    spec = exps[args.run]
    mesh = None
    if "mesh_shape" in spec:
        shp = spec["mesh_shape"]
        names = ("pod", "data", "model")[-len(shp):]
        from repro.core.compat import make_mesh
        mesh = make_mesh(shp, names)
    res = run_pair(spec["arch"], spec["shape"],
                   multi_pod=spec.get("multi_pod", False),
                   rules=spec.get("rules", DEFAULT_RULES),
                   cfg_override=spec.get("cfg"), mesh=mesh)
    from repro.checkpoint.store import atomic_write_json
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, args.run + ".json")
    # atomic publish — a killed run must not leave a torn result file
    atomic_write_json(path, res, indent=1)
    print(f"{args.run}: compute={res['compute_s']:.3e}s "
          f"memory={res['memory_s']:.3e}s collective={res['collective_s']:.3e}s "
          f"bottleneck={res['bottleneck']} -> {path}")


if __name__ == "__main__":
    main()
