"""Shared benchmark plumbing: timing, CSV emission, device-count subprocesses.

The scaling benches reproduce the paper's 1→32-machine experiments by
re-launching themselves in a subprocess with
``--xla_force_host_platform_device_count=N`` (the device count must be fixed
before jax initializes, so it cannot change inside one process).  On this
CPU container the 'machines' share cores — the *shape* of the scaling curve
(weak-scaling flatness, strong-scaling slope) is the reproduced claim, not
absolute walltime; see EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Any, Callable, Dict, List


def timeit(fn: Callable[[], Any], warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn() (blocks on jax arrays)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def run_with_devices(module: str, devices: int, payload: Dict[str, Any],
                     timeout: int = 560) -> Dict[str, Any]:
    """Re-exec ``python -m <module> --_worker`` with N host devices; the
    worker reads the JSON payload on stdin and prints a JSON result."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", module, "--_worker"],
        input=json.dumps(payload), capture_output=True, text=True,
        env=env, timeout=timeout, cwd=os.path.dirname(os.path.dirname(__file__)) or ".",
    )
    if out.returncode != 0:
        raise RuntimeError(f"{module} worker failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def emit(name: str, rows: List[Dict[str, Any]]) -> None:
    """Print a small CSV block: name,key=value,... one row per line."""
    for r in rows:
        fields = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{fields}")
