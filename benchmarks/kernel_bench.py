"""Kernel microbenchmarks (beyond-paper; supports the §Perf log).

On this CPU container we cannot time TPU kernels, so two honest views:
  1. walltime of the *jnp oracle* vs the fused XLA path at several sizes
     (CPU wall, sanity only);
  2. analytic HBM-traffic model per kernel: bytes the naive HLO moves vs
     bytes the Pallas schedule moves (the quantity the kernel exists to
     reduce) with the v5e 819 GB/s HBM roofline → projected μs.
"""
from __future__ import annotations

import numpy as np

from benchmarks._util import emit, timeit

HBM_BW = 819e9


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref

    rng = np.random.default_rng(0)
    rows = []

    # ---- logreg grad: naive traffic = X (margin pass) + sigmoid round-trip
    # + X (grad pass); fused = 2·X + small vectors ---------------------------
    for n, d in [(4096, 1024), (8192, 4096), (2048, 16384)]:
        X = jnp.asarray(rng.normal(size=(n, d)), jnp.bfloat16)
        y = jnp.asarray(rng.integers(0, 2, size=n), jnp.float32)
        w = jnp.asarray(rng.normal(size=d) * 0.05, jnp.bfloat16)
        t_ref = timeit(lambda: ref.logreg_grad_ref(X, y, w))
        x_bytes = n * d * 2
        naive = 3 * x_bytes          # unfused fp32 margin materialization
        fused = 2 * x_bytes          # two streamed passes, epilogue fused
        rows.append({
            "kernel": "logreg_grad", "n": n, "d": d,
            "cpu_ref_ms": round(t_ref * 1e3, 2),
            "naive_hbm_mb": round(naive / 2**20, 1),
            "fused_hbm_mb": round(fused / 2**20, 1),
            "projected_tpu_us_naive": round(naive / HBM_BW * 1e6, 1),
            "projected_tpu_us_fused": round(fused / HBM_BW * 1e6, 1),
        })

    # ---- flash attention: naive materializes (S,S) logits+probs in HBM ----
    for B, H, S, hd in [(1, 8, 2048, 128), (1, 8, 8192, 128)]:
        q = jnp.asarray(rng.normal(size=(B, H, S, hd)), jnp.bfloat16)
        t_ref = timeit(lambda: ref.flash_attention_ref(q, q, q, causal=True))
        qkv = 3 * B * H * S * hd * 2
        logits = B * H * S * S * 4
        naive = qkv + 2 * logits + B * H * S * hd * 2
        fused = qkv + B * H * S * hd * 2            # q/k/v in, o out; no (S,S)
        rows.append({
            "kernel": "flash_attention", "B": B, "H": H, "S": S, "hd": hd,
            "cpu_ref_ms": round(t_ref * 1e3, 2),
            "naive_hbm_mb": round(naive / 2**20, 1),
            "fused_hbm_mb": round(fused / 2**20, 1),
            "projected_tpu_us_naive": round(naive / HBM_BW * 1e6, 1),
            "projected_tpu_us_fused": round(fused / HBM_BW * 1e6, 1),
        })

    # ---- SSD scan: unfused scan materializes per-chunk (L,L) score blocks
    # and the (B,H,C,P,N) state trajectory in HBM; the kernel keeps state in
    # VMEM and streams only inputs/outputs ----------------------------------
    for B, H, S, P, N, L in [(8, 80, 4096, 64, 128, 64)]:
        la = jnp.asarray(-np.abs(rng.normal(size=(B, H, 256))) * 0.1, jnp.float32)
        dxs = jnp.asarray(rng.normal(size=(B, H, 256, P)), jnp.float32)
        Bs = jnp.asarray(rng.normal(size=(B, 256, N)), jnp.float32)
        t_ref = timeit(lambda: ref.ssd_chunk_scan_ref(la, dxs, Bs, Bs, chunk=64)[0])
        io = (B * H * S * (1 + 2 * P) + 2 * B * S * N) * 4      # in+out streams
        state_traj = B * H * (S // L) * P * N * 4               # unfused h per chunk
        scores = B * H * (S // L) * L * L * 4
        rows.append({
            "kernel": "ssd_scan", "B": B, "H": H, "S": S, "P": P, "N": N,
            "cpu_ref_ms_256tok": round(t_ref * 1e3, 2),
            "naive_hbm_mb": round((io + state_traj + scores) / 2**20, 1),
            "fused_hbm_mb": round(io / 2**20, 1),
            "projected_tpu_us_naive": round((io + state_traj + scores) / HBM_BW * 1e6, 1),
            "projected_tpu_us_fused": round(io / HBM_BW * 1e6, 1),
        })

    # ---- rmsnorm: 2 reads + 1 write naive vs 1 read + 1 write fused -------
    for rows_n, d in [(8192, 4096), (32768, 1152)]:
        x = jnp.asarray(rng.normal(size=(rows_n, d)), jnp.bfloat16)
        wv = jnp.ones((d,), jnp.bfloat16)
        t_ref = timeit(lambda: ref.rmsnorm_ref(x, wv))
        xb = rows_n * d * 2
        rows.append({
            "kernel": "rmsnorm", "rows": rows_n, "d": d,
            "cpu_ref_ms": round(t_ref * 1e3, 2),
            "naive_hbm_mb": round(3 * xb / 2**20, 1),
            "fused_hbm_mb": round(2 * xb / 2**20, 1),
            "projected_tpu_us_naive": round(3 * xb / HBM_BW * 1e6, 1),
            "projected_tpu_us_fused": round(2 * xb / HBM_BW * 1e6, 1),
        })

    emit("kernel_bench", rows)


if __name__ == "__main__":
    main()
