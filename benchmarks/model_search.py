"""Device-stacked vs sequential model-search throughput (docs/benchmarks.md).

One grid of logistic-regression configs (learning rate × L2), trained to
completion two ways through the same :class:`repro.tune.ModelSearch` on a
real multi-device mesh (subprocess — the device count must be fixed before
jax initializes):

  * **sequential** — one execution unit per config: every trial pays its
    own epoch dispatches, collectives, and scoring pass (the "six
    single-model trainers" baseline this subsystem replaces);
  * **stacked** — all same-shape configs vmapped over a leading trial
    axis: ONE jitted epoch and ONE collective per round advance the whole
    grid, and one shard-aware metrics pass scores it.

Timing accounting: each measured run is a FRESH ``ModelSearch`` (its own
runner, its own jit closures), so ``seconds`` is the full one-shot search
wall time *including* trace/compile — the cost a user actually pays, since
a given search is typically run once.  Both modes pay their own
trace/compile under identical rules; the stacked side's smaller bill
(1 compiled epoch for the whole grid vs. per-unit dispatch overheads ×
K trials) is part of the design being measured, not an artifact.

The reported ``trials_per_sec`` ratio is the claim of the tune subsystem:
searching K models costs far less than K single-model runs.  The
acceptance bar (ISSUE 3) is stacked ≥ 2× sequential; the CPU container
typically shows 4–8×.

The second leg (ISSUE 7) measures *search coverage* at a fixed device
budget: ASHA vs the median rule, both stacked 8 lanes wide, both limited
to the same slot-epoch budget.  The median driver carries every trial to
the full epoch count (frozen lanes still occupy their slot), so a budget
of B slot-epochs evaluates ``B / num_epochs`` trials; ASHA stops most
trials at the first rung and backfills the freed slots from the pending
pool, so the same budget gives far more configs a first-rung look —
the asynchronous-halving claim (Li et al.).  ``--check`` exits nonzero
when ASHA evaluates < 2× the median-rule trial count, or when ASHA's
promotion sequence diverges across the three collective schedules
(promotions are integer-accuracy decisions — they must be exactly
schedule-independent).
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks._util import emit, run_with_devices

DEVICES = 8
ROWS = 512
D = 32
EPOCHS = 6
CHUNKS = 4
GRID = {"learning_rate": [0.05, 0.1, 0.2, 0.3], "l2": [0.0, 0.01]}

# ASHA-vs-median coverage leg: 8 slots x 9 epochs x 2 "units" of budget
ASHA_EPOCHS = 9
ASHA_SLOTS = 8
ASHA_BUDGET = ASHA_SLOTS * ASHA_EPOCHS * 2          # 144 slot-epochs
ASHA_POOL = 64
ASHA_SPACE = {"learning_rate": ("loguniform", 0.01, 1.0),
              "l2": [0.0, 0.01]}


def _worker() -> None:
    import time

    import numpy as np

    from repro.core.compat import make_mesh
    from repro.core.numeric_table import MLNumericTable
    from repro.tune import (AsyncSuccessiveHalving, MedianStoppingRule,
                            ModelSearch, grid, sample)

    import jax

    devices = len(jax.devices())
    mesh = make_mesh((devices,), ("data",))

    rng = np.random.default_rng(0)
    X = rng.normal(size=(ROWS, D)).astype(np.float32)
    w = np.linspace(-1, 1, D).astype(np.float32)
    y = (X @ w > 0).astype(np.float32)
    table = MLNumericTable.from_numpy(np.concatenate([y[:, None], X], 1),
                                      mesh=mesh)
    configs = grid(GRID)

    def run_search(mode: str) -> float:
        search = ModelSearch("logreg", configs, num_epochs=EPOCHS,
                             chunks_per_epoch=CHUNKS, folds=None,
                             execution=mode, seed=0)
        t0 = time.perf_counter()
        search.run(table)
        return time.perf_counter() - t0

    rows_out = []
    times = {}
    for mode in ("sequential", "stacked"):
        # one discarded run settles allocator/XLA autotuning state; each
        # measured run is a fresh search and pays its own trace+compile
        # (see module docstring — that IS the one-shot search cost)
        run_search(mode)
        t = min(run_search(mode) for _ in range(2))
        times[mode] = t
        rows_out.append({"mode": mode, "trials": len(configs),
                         "seconds": round(t, 3),
                         "trials_per_sec": round(len(configs) / t, 2)})
    rows_out.append({"mode": "speedup",
                     "stacked_over_sequential":
                         round(times["sequential"] / times["stacked"], 2)})

    # -- coverage leg: ASHA vs median rule at one slot-epoch budget ---- #
    pool = sample(ASHA_SPACE, ASHA_POOL, seed=0)
    # the median driver runs every admitted trial to the finish line, so
    # the budget admits exactly budget // num_epochs trials
    median_n = ASHA_BUDGET // ASHA_EPOCHS
    med = ModelSearch("logreg", pool[:median_n], num_epochs=ASHA_EPOCHS,
                      chunks_per_epoch=CHUNKS, folds=None,
                      early_stop=MedianStoppingRule(), seed=0).run(table)
    asha = ModelSearch("logreg", pool, num_epochs=ASHA_EPOCHS,
                       chunks_per_epoch=CHUNKS, folds=None,
                       early_stop=AsyncSuccessiveHalving(
                           reduction_factor=3, min_rounds=1,
                           slots=ASHA_SLOTS, epoch_budget=ASHA_BUDGET),
                       seed=0).run(table)
    ratio = len(asha.trials) / max(1, len(med.trials))
    rows_out.append({"mode": "coverage",
                     "budget_slot_epochs": ASHA_BUDGET,
                     "median_trials": len(med.trials),
                     "asha_trials": len(asha.trials),
                     "asha_over_median": round(ratio, 2)})

    # promotion parity: the same ASHA pool under every collective
    # schedule must make the identical promotion sequence (accuracy is a
    # count — schedule-independent by construction)
    promos = {}
    for sched in ("allreduce", "gather_broadcast", "reduce_scatter"):
        res = ModelSearch("logreg", pool[:16], num_epochs=ASHA_EPOCHS,
                          chunks_per_epoch=CHUNKS, folds=None,
                          schedule=sched,
                          early_stop=AsyncSuccessiveHalving(
                              reduction_factor=3, min_rounds=1,
                              slots=ASHA_SLOTS),
                          seed=0).run(table)
        promos[sched] = [(t.index, len(t.rung_scores), t.stopped)
                         for t in res.trials]
    parity = len(set(map(tuple, promos.values()))) == 1
    rows_out.append({"mode": "promotion_parity",
                     "schedules_agree": parity})
    print(json.dumps({"devices": devices, "rows": rows_out}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--_worker", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero when ASHA evaluates < 2x the "
                         "median-rule trial count at the fixed budget, or "
                         "its promotions diverge across schedules")
    args = ap.parse_args()
    if args._worker:
        _worker()
        return

    res = run_with_devices("benchmarks.model_search", DEVICES, {})
    emit("model_search", res["rows"])
    coverage = next(r for r in res["rows"] if r["mode"] == "coverage")
    parity = next(r for r in res["rows"] if r["mode"] == "promotion_parity")
    print("RESULT::" + json.dumps({"asha_over_median":
                                   coverage["asha_over_median"],
                                   "median_trials":
                                   coverage["median_trials"],
                                   "asha_trials": coverage["asha_trials"],
                                   "schedules_agree":
                                   parity["schedules_agree"]}))
    if args.check:
        if coverage["asha_over_median"] < 2.0:
            print(f"CHECK FAILED: asha_over_median="
                  f"{coverage['asha_over_median']} < 2.0", file=sys.stderr)
            sys.exit(1)
        if not parity["schedules_agree"]:
            print("CHECK FAILED: ASHA promotions diverge across collective "
                  "schedules", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
