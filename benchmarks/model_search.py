"""Device-stacked vs sequential model-search throughput (docs/benchmarks.md).

One grid of logistic-regression configs (learning rate × L2), trained to
completion two ways through the same :class:`repro.tune.ModelSearch` on a
real multi-device mesh (subprocess — the device count must be fixed before
jax initializes):

  * **sequential** — one execution unit per config: every trial pays its
    own epoch dispatches, collectives, and scoring pass (the "six
    single-model trainers" baseline this subsystem replaces);
  * **stacked** — all same-shape configs vmapped over a leading trial
    axis: ONE jitted epoch and ONE collective per round advance the whole
    grid, and one shard-aware metrics pass scores it.

Timing accounting: each measured run is a FRESH ``ModelSearch`` (its own
runner, its own jit closures), so ``seconds`` is the full one-shot search
wall time *including* trace/compile — the cost a user actually pays, since
a given search is typically run once.  Both modes pay their own
trace/compile under identical rules; the stacked side's smaller bill
(1 compiled epoch for the whole grid vs. per-unit dispatch overheads ×
K trials) is part of the design being measured, not an artifact.

The reported ``trials_per_sec`` ratio is the claim of the tune subsystem:
searching K models costs far less than K single-model runs.  The
acceptance bar (ISSUE 3) is stacked ≥ 2× sequential; the CPU container
typically shows 4–8×.
"""
from __future__ import annotations

import argparse
import json

from benchmarks._util import emit, run_with_devices

DEVICES = 8
ROWS = 512
D = 32
EPOCHS = 6
CHUNKS = 4
GRID = {"learning_rate": [0.05, 0.1, 0.2, 0.3], "l2": [0.0, 0.01]}


def _worker() -> None:
    import time

    import numpy as np

    from repro.core.compat import make_mesh
    from repro.core.numeric_table import MLNumericTable
    from repro.tune import ModelSearch, grid

    import jax

    devices = len(jax.devices())
    mesh = make_mesh((devices,), ("data",))

    rng = np.random.default_rng(0)
    X = rng.normal(size=(ROWS, D)).astype(np.float32)
    w = np.linspace(-1, 1, D).astype(np.float32)
    y = (X @ w > 0).astype(np.float32)
    table = MLNumericTable.from_numpy(np.concatenate([y[:, None], X], 1),
                                      mesh=mesh)
    configs = grid(GRID)

    def run_search(mode: str) -> float:
        search = ModelSearch("logreg", configs, num_epochs=EPOCHS,
                             chunks_per_epoch=CHUNKS, folds=None,
                             execution=mode, seed=0)
        t0 = time.perf_counter()
        search.run(table)
        return time.perf_counter() - t0

    rows_out = []
    times = {}
    for mode in ("sequential", "stacked"):
        # one discarded run settles allocator/XLA autotuning state; each
        # measured run is a fresh search and pays its own trace+compile
        # (see module docstring — that IS the one-shot search cost)
        run_search(mode)
        t = min(run_search(mode) for _ in range(2))
        times[mode] = t
        rows_out.append({"mode": mode, "trials": len(configs),
                         "seconds": round(t, 3),
                         "trials_per_sec": round(len(configs) / t, 2)})
    rows_out.append({"mode": "speedup",
                     "stacked_over_sequential":
                         round(times["sequential"] / times["stacked"], 2)})
    print(json.dumps({"devices": devices, "rows": rows_out}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--_worker", action="store_true")
    args = ap.parse_args()
    if args._worker:
        _worker()
        return

    res = run_with_devices("benchmarks.model_search", DEVICES, {})
    emit("model_search", res["rows"])


if __name__ == "__main__":
    main()
