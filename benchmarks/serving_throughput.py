"""Continuous batching vs static batching on a mixed-prompt-length workload
(see docs/benchmarks.md, serving section).

The pre-refactor engine could only fuse requests whose prompt lengths
happened to match (``ServeEngine.run_static`` keeps that behavior as the
baseline); on a workload where every prompt length is distinct it
degenerates to slot-at-a-time decode.  The continuous engine right-pads
mixed-length prompts through one ragged prefill and advances every busy
slot through ONE fused per-slot-position decode step, backfilling freed
slots mid-decode — so the device does O(ceil(requests/slots)) fused steps
instead of O(requests) slot-at-a-time loops.

Both paths are greedy and must emit **identical token streams per
request** (asserted here before timing; the same invariant is unit-tested
in ``tests/test_serve_continuous.py``).  Compile time is excluded via
``ServeEngine.warmup`` + a full untimed pass of each path.  The repo's
acceptance bar is continuous ≥ 2× static requests/sec on this workload.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks._util import emit, timeit

ARCH = "qwen2-1.5b"        # dense GQA: ragged prefill + exact greedy parity
SLOTS = 4
MAX_NEW = 8
MAX_SEQ = 96
# every length distinct -> the static engine gets no equal-length fusion
PROMPT_LENS = (5, 7, 9, 11, 13, 15, 17, 19, 21, 23)


def _requests(cfg, seed: int = 7):
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=n)
                    .astype(np.int32), max_new_tokens=MAX_NEW)
            for n in PROMPT_LENS]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="accepted for benchmarks.run compatibility (this "
                         "bench is already smoke-sized)")
    ap.parse_args()

    import jax

    from repro.configs import get_smoke
    from repro.models.transformer import init_model
    from repro.serve import ServeEngine

    cfg = get_smoke(ARCH)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, batch_size=SLOTS, max_seq=MAX_SEQ)

    # compile everything both paths will touch, then one full untimed pass
    # each (the static path jits one prefill/decode pair per distinct
    # prompt length — that is part of its cost model, but not of this
    # measurement)
    engine.warmup(prompt_lens=PROMPT_LENS)
    cont = engine.run(_requests(cfg))
    stat = engine.run_static(_requests(cfg))

    # the acceptance invariant: greedy token streams identical per request
    for c, s in zip(cont, stat):
        assert c.out_tokens == s.out_tokens, (
            f"continuous/static divergence: {c.out_tokens} vs {s.out_tokens}")

    t_cont = timeit(lambda: engine.run(_requests(cfg)), warmup=1, iters=3)
    t_stat = timeit(lambda: engine.run_static(_requests(cfg)), warmup=1,
                    iters=3)

    n = len(PROMPT_LENS)
    tokens = n * MAX_NEW
    speedup = t_stat / t_cont
    rows = [
        {"mode": "static", "requests": n, "slots": SLOTS,
         "seconds": round(t_stat, 4),
         "req_per_sec": round(n / t_stat, 2),
         "tok_per_sec": round(tokens / t_stat, 1)},
        {"mode": "continuous", "requests": n, "slots": SLOTS,
         "seconds": round(t_cont, 4),
         "req_per_sec": round(n / t_cont, 2),
         "tok_per_sec": round(tokens / t_cont, 1),
         "speedup_vs_static": round(speedup, 2)},
    ]
    emit("serving_throughput", rows)
    print(f"# continuous batching {speedup:.2f}x static on "
          f"{n} mixed-length requests (target >= 2x)")
    if speedup < 2.0:
        # plain exception so benchmarks.run's per-job handler records the
        # failure (SystemExit would abort the whole aggregate runner)
        raise RuntimeError(
            f"serving_throughput: continuous/static {speedup:.2f}x < 2x bar")


if __name__ == "__main__":
    main()
