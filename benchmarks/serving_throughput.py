"""Continuous batching vs static batching on a mixed-prompt-length workload
(see docs/benchmarks.md, serving section).

The pre-refactor engine could only fuse requests whose prompt lengths
happened to match (``ServeEngine.run_static`` keeps that behavior as the
baseline); on a workload where every prompt length is distinct it
degenerates to slot-at-a-time decode.  The continuous engine right-pads
mixed-length prompts through one ragged prefill and advances every busy
slot through ONE fused per-slot-position decode step, backfilling freed
slots mid-decode — so the device does O(ceil(requests/slots)) fused steps
instead of O(requests) slot-at-a-time loops.

Both paths are greedy and must emit **identical token streams per
request** (asserted here before timing; the same invariant is unit-tested
in ``tests/test_serve_continuous.py``).  Compile time is excluded via
``ServeEngine.warmup`` + a full untimed pass of each path.  The repo's
acceptance bar is continuous ≥ 2× static requests/sec on this workload.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks._util import emit, timeit

ARCH = "qwen2-1.5b"        # dense GQA: ragged prefill + exact greedy parity
SLOTS = 4
MAX_NEW = 8
MAX_SEQ = 96
# every length distinct -> the static engine gets no equal-length fusion
PROMPT_LENS = (5, 7, 9, 11, 13, 15, 17, 19, 21, 23)


def _requests(cfg, seed: int = 7):
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=n)
                    .astype(np.int32), max_new_tokens=MAX_NEW)
            for n in PROMPT_LENS]


FLEET_REPLICAS = 8
FLEET_REQUESTS = 64


def _fleet_leg(cfg, params) -> None:
    """Fleet vs single-replica on one workload (the ``--check`` leg).

    Bars: fleet ≥ 2× single-replica requests/sec, identical greedy token
    streams, and **non-vacuous latency percentiles** — ``_pct`` maps an
    empty list to 0.0, so a fleet that served nothing would otherwise
    sail under any latency bar.  ``finished > 0`` is checked before the
    percentiles mean anything."""
    import time

    import jax

    from repro.serve import ReplicaRouter, Request, ServeEngine

    rng = np.random.default_rng(11)
    lens = [int(rng.integers(5, 24)) for _ in range(FLEET_REQUESTS)]

    def requests():
        r = np.random.default_rng(13)
        return [Request(prompt=r.integers(0, cfg.vocab_size, size=n)
                        .astype(np.int32), max_new_tokens=MAX_NEW)
                for n in lens]

    single = ServeEngine(cfg, params, batch_size=SLOTS, max_seq=MAX_SEQ)
    single.warmup(prompt_lens=sorted(set(lens)))
    fleet = ReplicaRouter(cfg, params, slots_per_replica=SLOTS,
                          max_replicas=FLEET_REPLICAS, max_seq=MAX_SEQ)
    fleet.warmup(prompt_lens=sorted(set(lens)))

    # parity before timing: the fused-span fleet must emit exactly the
    # single engine's greedy streams
    a, b = requests(), requests()
    fleet.run(a)
    single.run(b)
    for f, s in zip(a, b):
        assert f.out_tokens == s.out_tokens, (
            f"fleet/single divergence: {f.out_tokens} vs {s.out_tokens}")

    # the parity pass above left its (frozen-clock) requests in the
    # schedulers' finished lists — drop them so the report below reflects
    # only the timed run
    for s in fleet.scheds:
        s._finished.clear()

    t_single = timeit(lambda: single.run(requests()), warmup=1, iters=3)
    t0 = time.perf_counter()
    # clock rebased to 0 so latency stamps are seconds-into-run, matching
    # the requests' arrival=0
    fleet.run(requests(), now_fn=lambda: time.perf_counter() - t0)
    t_fleet = time.perf_counter() - t0

    rep = fleet.report()
    if rep["finished"] == 0:
        raise RuntimeError("serving_throughput: fleet leg served nothing — "
                           "latency percentiles are vacuous")
    speedup = t_single / t_fleet
    rows = [
        {"mode": "single_replica", "requests": FLEET_REQUESTS,
         "slots": SLOTS, "seconds": round(t_single, 4),
         "req_per_sec": round(FLEET_REQUESTS / t_single, 2)},
        {"mode": "fleet", "requests": FLEET_REQUESTS,
         "replicas": FLEET_REPLICAS, "slots": SLOTS,
         "seconds": round(t_fleet, 4),
         "req_per_sec": round(FLEET_REQUESTS / t_fleet, 2),
         "finished": rep["finished"],
         "latency_p50": round(rep["latency_p50"], 5),
         "latency_p99": round(rep["latency_p99"], 5),
         "speedup_vs_single": round(speedup, 2)},
    ]
    emit("serving_throughput", rows)
    print(f"# fleet ({FLEET_REPLICAS}x{SLOTS} lanes) {speedup:.2f}x "
          f"single-replica on {FLEET_REQUESTS} requests (target >= 2x)")
    if rep["latency_p99"] <= 0.0:
        raise RuntimeError("serving_throughput: fleet p99 is 0 with "
                           f"finished={rep['finished']} — vacuous percentile")
    if speedup < 2.0:
        raise RuntimeError(
            f"serving_throughput: fleet/single {speedup:.2f}x < 2x bar")


PREFIX_LEN = 240           # long shared prefix: prefill-dominated, as in
PREFIX_REQUESTS = 40       # system-prompt-heavy production traffic
PREFIX_MAX_NEW = 4
PREFIX_SLOTS = 8
PREFIX_MAX_SEQ = 256
PREFIX_RATE = 20000.0      # poisson arrivals, fast enough to keep slots busy


def _prefix_leg(cfg, params) -> None:
    """Repeated-prefix trace: 80% of requests open with one shared
    240-token prefix (a synthetic system prompt), 20% are fully random at
    the same total length (the ``--check`` leg).

    Bars: hit rate must be non-zero, greedy streams must be BIT-IDENTICAL
    cache-on vs cache-off (also under int8 weight-quantized decode), and
    cache-on must clear ≥ 1.3× cache-off requests/sec (the acceptance
    target is 1.5×; the hard floor leaves slack for CI-runner noise).
    Each timed iteration resets the trie, so hits come only from
    within-run repetition — no warm-start flattery."""
    import dataclasses as _dc
    import time

    from repro.launch.serve import arrival_trace
    from repro.serve import RadixPrefixCache, Request, ServeEngine

    shared = np.random.default_rng(21).integers(
        0, cfg.vocab_size, size=PREFIX_LEN).astype(np.int32)

    def requests():
        rng = np.random.default_rng(23)
        arrivals = arrival_trace("poisson", PREFIX_REQUESTS, PREFIX_RATE, 23)
        reqs = []
        for i in range(PREFIX_REQUESTS):
            tail_n = 5 + i % 4
            if i % 5 == 0:             # 20%: no shared prefix, same length
                p = rng.integers(0, cfg.vocab_size,
                                 size=PREFIX_LEN + tail_n).astype(np.int32)
            else:
                tail = rng.integers(0, cfg.vocab_size,
                                    size=tail_n).astype(np.int32)
                p = np.concatenate([shared, tail])
            reqs.append(Request(prompt=p, max_new_tokens=PREFIX_MAX_NEW,
                                arrival=float(arrivals[i])))
        return reqs

    off = ServeEngine(cfg, params, batch_size=PREFIX_SLOTS,
                      max_seq=PREFIX_MAX_SEQ)
    pc = RadixPrefixCache(block_size=16, capacity_blocks=64)
    on = ServeEngine(cfg, params, batch_size=PREFIX_SLOTS,
                     max_seq=PREFIX_MAX_SEQ, prefix_cache=pc)

    # parity before timing — cache-on must not change a single token
    a, b = requests(), requests()
    off.run(a, now_fn=time.perf_counter)
    on.run(b, now_fn=time.perf_counter)
    for x, y in zip(a, b):
        if x.out_tokens != y.out_tokens:
            raise RuntimeError("serving_throughput: prefix-cache token "
                               f"divergence: {x.out_tokens} vs {y.out_tokens}")
    if pc.stats()["cached_tokens"] == 0:
        raise RuntimeError("serving_throughput: prefix leg hit rate is 0 — "
                           "the shared-prefix trace found no cached blocks")

    # ... and under int8 weight-quantized decode (one untimed pass)
    cfg8 = _dc.replace(cfg, quantize="int8")
    off8 = ServeEngine(cfg8, params, batch_size=PREFIX_SLOTS,
                       max_seq=PREFIX_MAX_SEQ)
    on8 = ServeEngine(cfg8, params, batch_size=PREFIX_SLOTS,
                      max_seq=PREFIX_MAX_SEQ,
                      prefix_cache=RadixPrefixCache(block_size=16,
                                                    capacity_blocks=64))
    a8, b8 = requests(), requests()
    off8.run(a8, now_fn=time.perf_counter)
    on8.run(b8, now_fn=time.perf_counter)
    for x, y in zip(a8, b8):
        if x.out_tokens != y.out_tokens:
            raise RuntimeError("serving_throughput: prefix-cache int8 "
                               "divergence: "
                               f"{x.out_tokens} vs {y.out_tokens}")

    t_off = timeit(lambda: off.run(requests(), now_fn=time.perf_counter),
                   warmup=1, iters=3)
    t_on = timeit(lambda: (pc.reset(),
                           on.run(requests(), now_fn=time.perf_counter)),
                  warmup=1, iters=3)
    stats = pc.stats()

    speedup = t_off / t_on
    rows = [
        {"mode": "prefix_cache_off", "requests": PREFIX_REQUESTS,
         "slots": PREFIX_SLOTS, "seconds": round(t_off, 4),
         "req_per_sec": round(PREFIX_REQUESTS / t_off, 2)},
        {"mode": "prefix_cache_on", "requests": PREFIX_REQUESTS,
         "slots": PREFIX_SLOTS, "seconds": round(t_on, 4),
         "req_per_sec": round(PREFIX_REQUESTS / t_on, 2),
         "hit_rate": round(stats["hit_rate"], 3),
         "cached_tokens": stats["cached_tokens"],
         "prompt_tokens": stats["prompt_tokens"],
         "speedup_vs_off": round(speedup, 2)},
    ]
    emit("serving_throughput", rows)
    print(f"# prefix cache {speedup:.2f}x cache-off on {PREFIX_REQUESTS} "
          f"requests, 80% sharing a {PREFIX_LEN}-token prefix "
          f"(hit rate {stats['hit_rate']:.2f}; floor >= 1.3x, "
          "target >= 1.5x)")
    if speedup < 1.3:
        raise RuntimeError(
            f"serving_throughput: prefix cache {speedup:.2f}x < 1.3x floor")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="accepted for benchmarks.run compatibility (this "
                         "bench is already smoke-sized)")
    ap.add_argument("--check", action="store_true",
                    help="also run the fleet-vs-single and repeated-prefix "
                         "legs and enforce their bars (nightly: fleet >= 2x "
                         "single, prefix cache >= 1.3x cache-off with "
                         "non-zero hit rate and bit-identical streams)")
    args = ap.parse_args()

    import jax

    from repro.configs import get_smoke
    from repro.models.transformer import init_model
    from repro.serve import ServeEngine

    cfg = get_smoke(ARCH)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, batch_size=SLOTS, max_seq=MAX_SEQ)

    # compile everything both paths will touch, then one full untimed pass
    # each (the static path jits one prefill/decode pair per distinct
    # prompt length — that is part of its cost model, but not of this
    # measurement)
    engine.warmup(prompt_lens=PROMPT_LENS)
    cont = engine.run(_requests(cfg))
    stat = engine.run_static(_requests(cfg))

    # the acceptance invariant: greedy token streams identical per request
    for c, s in zip(cont, stat):
        assert c.out_tokens == s.out_tokens, (
            f"continuous/static divergence: {c.out_tokens} vs {s.out_tokens}")

    t_cont = timeit(lambda: engine.run(_requests(cfg)), warmup=1, iters=3)
    t_stat = timeit(lambda: engine.run_static(_requests(cfg)), warmup=1,
                    iters=3)

    n = len(PROMPT_LENS)
    tokens = n * MAX_NEW
    speedup = t_stat / t_cont
    rows = [
        {"mode": "static", "requests": n, "slots": SLOTS,
         "seconds": round(t_stat, 4),
         "req_per_sec": round(n / t_stat, 2),
         "tok_per_sec": round(tokens / t_stat, 1)},
        {"mode": "continuous", "requests": n, "slots": SLOTS,
         "seconds": round(t_cont, 4),
         "req_per_sec": round(n / t_cont, 2),
         "tok_per_sec": round(tokens / t_cont, 1),
         "speedup_vs_static": round(speedup, 2)},
    ]
    emit("serving_throughput", rows)
    print(f"# continuous batching {speedup:.2f}x static on "
          f"{n} mixed-length requests (target >= 2x)")
    if speedup < 2.0:
        # plain exception so benchmarks.run's per-job handler records the
        # failure (SystemExit would abort the whole aggregate runner)
        raise RuntimeError(
            f"serving_throughput: continuous/static {speedup:.2f}x < 2x bar")
    if args.check:
        _fleet_leg(cfg, params)
        _prefix_leg(cfg, params)


if __name__ == "__main__":
    main()
