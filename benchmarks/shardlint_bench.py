"""ShardLint cost benchmark (beyond-paper; guards the CI budget).

Times the two static-analysis legs CI runs on every push — the AST lint
over ``src/`` and the jaxpr audit of every registered hot path — and
enforces the <30s audit budget so the tier-1 leg cannot silently grow
into the nightly tier.  ``--check`` exits nonzero on budget overrun OR
on any finding (the same contract as ``python -m repro.analysis``).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks._util import emit

AUDIT_BUDGET_S = 30.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on findings or budget overrun")
    args = ap.parse_args()

    from repro.analysis import lint_paths, run_audit

    t0 = time.perf_counter()
    lint_findings = lint_paths(["src"])
    lint_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    audit_findings, audited, skipped = run_audit()
    audit_s = time.perf_counter() - t0

    emit("shardlint", [{
        "lint_s": round(lint_s, 2),
        "lint_findings": len(lint_findings),
        "audit_s": round(audit_s, 2),
        "hot_paths_audited": len(audited),
        "hot_paths_skipped": len(skipped),
        "audit_findings": len(audit_findings),
        "audit_budget_s": AUDIT_BUDGET_S,
    }])
    for f in lint_findings + audit_findings:
        print(f"  {f}", file=sys.stderr)

    if args.check:
        if lint_findings or audit_findings:
            sys.exit("shardlint: findings present")
        if audit_s > AUDIT_BUDGET_S:
            sys.exit(f"shardlint: audit took {audit_s:.1f}s "
                     f"(budget {AUDIT_BUDGET_S:.0f}s)")


if __name__ == "__main__":
    main()
