"""§Roofline table generator: reads the dry-run JSONs under
experiments/dryrun/ and prints the per-(arch × shape × mesh) three-term
roofline with bottleneck classification and MODEL_FLOPS ratio.

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
    PYTHONPATH=src python -m benchmarks.roofline --markdown   # for EXPERIMENTS.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks._util import emit


def load(dir_: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--mesh", default=None, help="filter: 16x16 or 2x16x16")
    args = ap.parse_args()

    rows = load(args.dir)
    if not rows:
        print(f"no dry-run results under {args.dir}; run "
              f"`python -m repro.launch.dryrun --all --both-meshes --out {args.dir}`")
        return
    if args.mesh:
        rows = [r for r in rows if r["mesh"] == args.mesh]

    out = []
    for r in rows:
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compute_s": f"{r['compute_s']:.3e}",
            "memory_s": f"{r['memory_s']:.3e}",
            "collective_s": f"{r['collective_s']:.3e}",
            "bottleneck": r["bottleneck"].replace("_s", ""),
            "model_flops_ratio": f"{r['model_flops_ratio']:.3f}",
        })

    if args.markdown:
        cols = list(out[0].keys())
        print("| " + " | ".join(cols) + " |")
        print("|" + "---|" * len(cols))
        for r in out:
            print("| " + " | ".join(str(r[c]) for c in cols) + " |")
    else:
        emit("roofline", out)


if __name__ == "__main__":
    main()
