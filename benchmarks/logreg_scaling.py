"""Paper Figs. 2b/2c (weak scaling) and A5/A6 (strong scaling) for logistic
regression via local SGD + parameter averaging, executed through the shared
DistributedRunner (see docs/benchmarks.md).

Weak scaling: data per 'machine' (device) fixed; more devices → ideally flat
walltime.  Strong scaling: total data fixed; more devices → ideally linear
speedup.  Each device count runs in a subprocess (see _util).  The runner's
collective schedule is a sweepable knob: ``--schedules`` takes a
comma-separated list and emits one scaling curve per schedule, which is the
paper's §IV-A gather-vs-allreduce comparison laid over the scaling figures.

    PYTHONPATH=src python -m benchmarks.logreg_scaling --mode weak
    PYTHONPATH=src python -m benchmarks.logreg_scaling \\
        --schedules gather_broadcast,allreduce,reduce_scatter
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks._util import emit, run_with_devices

N_PER_DEV_WEAK = 2048
N_TOTAL_STRONG = 4096
D = 256
ITERS = 5


def _worker() -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.algorithms.logistic_regression import (
        LogisticRegressionAlgorithm, LogisticRegressionParameters)
    from repro.core.numeric_table import MLNumericTable
    from repro.data import synth_classification
    from benchmarks._util import timeit

    cfgj = json.loads(sys.stdin.read())
    n, d = cfgj["n"], cfgj["d"]
    devices = len(jax.devices())
    from repro.core.compat import make_mesh
    mesh = make_mesh((devices,), ("data",))

    X, y, _ = synth_classification(n, d, seed=0)
    data = np.concatenate([y[:, None], X], 1).astype(np.float32)
    table = MLNumericTable.from_numpy(data, mesh=mesh)
    params = LogisticRegressionParameters(
        learning_rate=0.5, max_iter=cfgj["iters"],
        local_batch_size=cfgj.get("local_batch", 32),
        schedule=cfgj.get("schedule", "gather_broadcast"))

    def run():
        return LogisticRegressionAlgorithm(params).fit(table).weights

    t = timeit(run, warmup=1, iters=3)
    model = LogisticRegressionAlgorithm(params).fit(table)
    acc = float((np.asarray(model.predict(jnp.asarray(X))).ravel() == y).mean())
    print(json.dumps({"devices": devices, "seconds": t, "acc": acc}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["weak", "strong", "both"], default="both")
    ap.add_argument("--devices", default="1,2,4,8")
    ap.add_argument("--schedules", default="gather_broadcast",
                    help="comma-separated CollectiveSchedule values to sweep "
                         "through the DistributedRunner")
    ap.add_argument("--_worker", action="store_true")
    args = ap.parse_args()
    if args._worker:
        _worker()
        return

    dev_counts = [int(x) for x in args.devices.split(",")]
    schedules = [s.strip() for s in args.schedules.split(",") if s.strip()]
    modes = ["weak", "strong"] if args.mode == "both" else [args.mode]
    for mode in modes:
        for schedule in schedules:
            rows = []
            base = None
            for nd in dev_counts:
                n = N_PER_DEV_WEAK * nd if mode == "weak" else N_TOTAL_STRONG
                res = run_with_devices("benchmarks.logreg_scaling", nd,
                                       {"n": n, "d": D, "iters": ITERS,
                                        "schedule": schedule})
                if base is None:
                    base = res["seconds"]
                rows.append({"devices": nd, "n": n, "schedule": schedule,
                             "seconds": round(res["seconds"], 3),
                             "relative": round(res["seconds"] / base, 3),
                             "speedup": round(base / res["seconds"], 3),
                             "acc": round(res["acc"], 3)})
            emit(f"logreg_{mode}_scaling", rows)


if __name__ == "__main__":
    main()
