"""Paper Figs. 3b/3c (weak) and A7/A8 (strong) for ALS matrix factorization
on tiled synthetic-Netflix data, paper hyperparameters (rank 10, λ=.01,
10 iterations).

    PYTHONPATH=src python -m benchmarks.als_scaling --mode weak
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks._util import emit, run_with_devices

ITERS = 10
RANK = 10
LAM = 0.01


def _worker() -> None:
    import jax
    import numpy as np

    from repro.core.algorithms.als import (ALSParameters, BroadcastALS,
                                           pack_csr_table)
    from repro.data import synth_netflix_tiled
    from benchmarks._util import timeit

    cfgj = json.loads(sys.stdin.read())
    tiles = cfgj["tiles"]
    devices = len(jax.devices())
    from repro.core.compat import make_mesh
    mesh = make_mesh((devices,), ("data",))

    # tile to a device-divisible user/item count
    users = 64 * devices if cfgj["mode"] == "strong_base" else 64
    M = synth_netflix_tiled(users=64, items=48, rank=4, tiles=tiles, density=0.2)
    # pad rows to divide the mesh
    m, n = M.shape
    pad_m = (-m) % devices
    pad_n = (-n) % devices
    M = np.pad(M, ((0, pad_m), (0, pad_n)))
    m, n = M.shape
    r, c = np.nonzero(M)
    v = M[r, c]
    max_nnz = int(max((M != 0).sum(1).max(), (M != 0).sum(0).max()))
    data = pack_csr_table(r, c, v, m, max_nnz, mesh=mesh)
    data_t = pack_csr_table(c, r, v, n, max_nnz, mesh=mesh)
    p = ALSParameters(rank=RANK, lam=LAM, max_iter=ITERS)

    def run():
        return BroadcastALS(p).fit(data, data_transposed=data_t).U

    t = timeit(run, warmup=1, iters=3)
    model = BroadcastALS(p).fit(data, data_transposed=data_t)
    rmse = float(model.rmse(r, c, v))
    print(json.dumps({"devices": devices, "seconds": t, "rmse": rmse,
                      "nnz": int(len(v))}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["weak", "strong", "both"], default="both")
    ap.add_argument("--devices", default="1,2,4,8")
    ap.add_argument("--_worker", action="store_true")
    args = ap.parse_args()
    if args._worker:
        _worker()
        return

    dev_counts = [int(x) for x in args.devices.split(",")]
    modes = ["weak", "strong"] if args.mode == "both" else [args.mode]
    for mode in modes:
        rows = []
        base = None
        for nd in dev_counts:
            tiles = nd if mode == "weak" else 4     # paper: 9x fixed for strong
            res = run_with_devices("benchmarks.als_scaling", nd,
                                   {"tiles": tiles, "mode": mode})
            if base is None:
                base = res["seconds"]
            rows.append({"devices": nd, "tiles": tiles, "nnz": res["nnz"],
                         "seconds": round(res["seconds"], 3),
                         "relative": round(res["seconds"] / base, 3),
                         "speedup": round(base / res["seconds"], 3),
                         "rmse": round(res["rmse"], 4)})
        emit(f"als_{mode}_scaling", rows)


if __name__ == "__main__":
    main()
