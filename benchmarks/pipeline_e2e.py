"""End-to-end text-pipeline benchmark: fit and predict rows/sec for the
Fig. A2 program (rawText → NGrams → TfIdf → Standardizer → logreg) as ONE
``repro.pipeline.Pipeline`` object, swept across the three §IV-A
collective schedules on an 8-device mesh (subprocess — the device count
must be fixed before jax initializes).

Reported per schedule:

  * ``fit_rows_per_s``    — whole-pipeline fit (featurizer statistics via
    the table's shared-nothing reduces + logreg SGD rounds through the
    DistributedRunner) over the corpus;
  * ``predict_rows_per_s`` — served prediction throughput: raw-text rows
    through the fitted host featurizer + the compiled device chain
    (tf-idf → standardize → predict) via the ModelPredictor microbatcher.

The schedules must agree on the model itself (asserted to fp tolerance) —
the sweep reads the *wire pattern* cost off an invariant computation.
Each schedule's fit is a fresh trace, so the first row pays the shared
jit warm-up; the predict rows are measured on a warmed service.
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks._util import emit, run_with_devices

DEVICES = 8
DOCS = 512
WORDS = 20
TOP = 64
EPOCHS = 5
MAX_BATCH = 64
SERVE_ROWS = 512


def _worker() -> None:
    import time

    import numpy as np

    from benchmarks._util import timeit
    from repro.core.algorithms.logistic_regression import \
        LogisticRegressionAlgorithm
    from repro.core.collectives import CollectiveSchedule
    from repro.core.compat import make_mesh
    from repro.core.mltable import MLTable
    from repro.data import synth_labeled_text
    from repro.features import NGrams, Standardizer, TfIdf
    from repro.pipeline import Pipeline
    from repro.serve import ModelPredictor

    mesh = make_mesh((DEVICES,), ("data",))
    rows = synth_labeled_text(n_docs=DOCS, words_per_doc=WORDS, seed=0)
    raw = MLTable.from_rows(rows, names=["label", "text"], num_partitions=8)
    texts = [t for _, t in rows][:SERVE_ROWS]

    out = []
    weights = {}
    for sched in CollectiveSchedule:
        def make_pipe():
            return Pipeline([
                NGrams(n=1, top=TOP, column="text"),
                TfIdf(),
                Standardizer(),
                LogisticRegressionAlgorithm(learning_rate=0.5,
                                            max_iter=EPOCHS,
                                            local_batch_size=8,
                                            schedule=sched),
            ], mesh=mesh)

        # fit throughput: featurization + training, the whole artifact
        t0 = time.perf_counter()
        fitted = make_pipe().fit(raw)
        fit_s = time.perf_counter() - t0
        weights[sched.value] = np.asarray(fitted.model.weights)

        # serve throughput: raw text through the microbatcher (jit warmed
        # by the first flush; timed flushes reuse the compiled program)
        service = ModelPredictor(fitted, max_batch=MAX_BATCH)
        service.predict_many([texts[:MAX_BATCH]])        # warm the jit

        def serve_pass():
            import jax

            outs = service.predict_many([np.asarray(t, object)
                                         for t in texts])
            return jax.numpy.zeros(())  # timeit blocks on this

        serve_s = timeit(serve_pass, warmup=1, iters=3)
        out.append({
            "schedule": sched.value,
            "fit_rows_per_s": round(DOCS / fit_s, 1),
            "predict_rows_per_s": round(len(texts) / serve_s, 1),
        })

    vals = list(weights.values())
    agree = all(np.allclose(vals[0], v, rtol=1e-5, atol=1e-6)
                for v in vals[1:])
    print(json.dumps({"rows": out, "schedules_agree": bool(agree)}))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--_worker", action="store_true")
    args = ap.parse_args(argv)
    if args._worker:
        _worker()
        return
    res = run_with_devices("benchmarks.pipeline_e2e", DEVICES, {})
    emit("pipeline_e2e", res["rows"])
    if not res["schedules_agree"]:
        print("FAIL: collective schedules disagree on the trained model")
        sys.exit(1)
    print(f"pipeline_e2e: {DOCS} docs, top={TOP}; all schedules agree")


if __name__ == "__main__":
    main()
