"""The paper's §IV-A communication comparison: MLI's gather-to-master +
broadcast vs VW's tree AllReduce (plus our beyond-paper reduce-scatter).

Two views:
  1. *Correctness/time on emulated devices* — run the same local-SGD round
     under each schedule and time it (the schedules are algebraically equal;
     walltime on CPU mostly shows dispatch overhead).
  2. *Wire bytes on the production mesh* — lower one combine per schedule on
     the 16×16 mesh (in a 512-device subprocess) and count collective bytes
     in the HLO: this is the property the paper actually reasons about
     (O(N·d) in for gather vs O(d) for allreduce).
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks._util import emit, run_with_devices

D = 4096


def _worker() -> None:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.collectives import CollectiveSchedule, combine_mean
    from repro.launch.dryrun import collective_bytes  # parser only (no mesh use)

    json.loads(sys.stdin.read())
    mesh = jax.make_mesh((16, 16), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    out = {}
    for sched in CollectiveSchedule:
        def spmd(w):
            return combine_mean(w, ("data",), sched)

        f = jax.jit(jax.shard_map(spmd, mesh=mesh,
                                  in_specs=P("data"), out_specs=P(),
                                  check_vma=False))
        lowered = f.lower(jax.ShapeDtypeStruct((16 * D,), jnp.float32))
        hlo = lowered.compile().as_text()
        out[sched.value] = collective_bytes(hlo)
    print(json.dumps(out))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--_worker", action="store_true")
    args = ap.parse_args()
    if args._worker:
        _worker()
        return

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.algorithms.logistic_regression import (
        LogisticRegressionAlgorithm, LogisticRegressionParameters)
    from repro.core.collectives import CollectiveSchedule
    from repro.core.numeric_table import MLNumericTable
    from repro.data import synth_classification
    from benchmarks._util import timeit

    # view 1: emulated-device walltime + agreement
    X, y, _ = synth_classification(2048, 128, seed=0)
    data = np.concatenate([y[:, None], X], 1).astype(np.float32)
    table = MLNumericTable.from_numpy(data, num_shards=8)
    rows, weights = [], {}
    for sched in CollectiveSchedule:
        p = LogisticRegressionParameters(learning_rate=0.5, max_iter=5,
                                         local_batch_size=32, schedule=sched)
        t = timeit(lambda: LogisticRegressionAlgorithm.train(table, p).weights,
                   warmup=1, iters=3)
        weights[sched] = np.asarray(LogisticRegressionAlgorithm.train(table, p).weights)
        rows.append({"schedule": sched.value, "seconds": round(t, 3)})
    ref = weights[CollectiveSchedule.ALLREDUCE]
    for sched, w in weights.items():
        drift = float(np.abs(w - ref).max())
        assert drift < 1e-4, f"{sched}: schedules disagree by {drift}"
    emit("collective_schedules_walltime", rows)

    # view 2: wire bytes on the production mesh
    res = run_with_devices("benchmarks.collective_schedules", 512, {})
    rows = [{"schedule": k, "collective_bytes": v["total_bytes"],
             **{f"n_{op}": n for op, n in v["count_by_op"].items() if n}}
            for k, v in res.items()]
    emit("collective_schedules_wire_bytes", rows)


if __name__ == "__main__":
    main()
