"""The paper's §IV-A communication comparison: MLI's gather-to-master +
broadcast vs VW's tree AllReduce (plus our beyond-paper reduce-scatter),
swept through the shared :class:`DistributedRunner` (see docs/benchmarks.md).

Two views, both on real multi-device meshes (subprocesses, since the device
count must be fixed before jax initializes):
  1. *Walltime + agreement on an 8-device mesh* — train logistic regression
     and k-means under each schedule via their ``schedule=`` knob (which
     routes through the runner) and time them; the schedules are
     algebraically equal (asserted), so the deltas show collective dispatch
     cost.  On a CPU container the absolute numbers mostly reflect host
     emulation overhead.
  2. *Wire bytes on the production mesh* — lower one runner combine per
     schedule on the 16×16 mesh (512-device subprocess) and count
     collective bytes in the HLO: this is the property the paper actually
     reasons about (O(N·d) in for gather vs O(d) for allreduce).
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks._util import emit, run_with_devices

D = 4096
WALLTIME_DEVICES = 8


def _worker_walltime() -> None:
    import jax
    import numpy as np

    from repro.core.algorithms.kmeans import KMeans, KMeansParameters
    from repro.core.algorithms.logistic_regression import (
        LogisticRegressionAlgorithm, LogisticRegressionParameters)
    from repro.core.collectives import CollectiveSchedule
    from repro.core.compat import make_mesh
    from repro.core.numeric_table import MLNumericTable
    from repro.data import synth_classification
    from benchmarks._util import timeit

    devices = len(jax.devices())
    mesh = make_mesh((devices,), ("data",))
    X, y, _ = synth_classification(2048, 128, seed=0)
    data = np.concatenate([y[:, None], X], 1).astype(np.float32)
    table = MLNumericTable.from_numpy(data, mesh=mesh)
    tX = MLNumericTable.from_numpy(X.astype(np.float32), mesh=mesh)

    def sweep(name, train_fn):
        """Time train_fn(schedule) per schedule and assert the results agree."""
        rows, results = [], {}
        for sched in CollectiveSchedule:
            last = {}

            def run():
                last["out"] = train_fn(sched)
                return last["out"]

            t = timeit(run, warmup=1, iters=3)
            results[sched] = np.asarray(last["out"])
            rows.append({"schedule": sched.value, "seconds": round(t, 3)})
        ref = results[CollectiveSchedule.ALLREDUCE]
        for sched, out in results.items():
            drift = float(np.abs(out - ref).max())
            assert drift < 1e-4, f"{name} {sched}: schedules disagree by {drift}"
        return rows

    logreg_rows = sweep("logreg", lambda sched: LogisticRegressionAlgorithm(
        LogisticRegressionParameters(learning_rate=0.5, max_iter=5,
                                     local_batch_size=32,
                                     schedule=sched)).fit(table).weights)
    kmeans_rows = sweep("kmeans", lambda sched: KMeans(
        KMeansParameters(k=8, max_iter=5, seed=0,
                         schedule=sched)).fit(tX).centroids)
    print(json.dumps({"devices": devices, "logreg": logreg_rows,
                      "kmeans": kmeans_rows}))


def _worker_wire_bytes() -> None:
    import jax
    import jax.numpy as jnp

    from repro.core.collectives import CollectiveSchedule
    from repro.core.compat import make_mesh
    from repro.core.runner import DistributedRunner
    from repro.launch.dryrun import collective_bytes  # parser only (no mesh use)

    mesh = make_mesh((16, 16), ("data", "model"))
    n_data = 16
    out = {}
    for sched in CollectiveSchedule:
        runner = DistributedRunner(mesh=mesh, data_axes=("data",),
                                   schedule=sched)

        def combine(w):
            return runner.partition_apply(w, lambda block: block.mean(axis=0),
                                          combine="mean")

        f = jax.jit(combine)
        lowered = f.lower(jax.ShapeDtypeStruct((n_data, D), jnp.float32))
        hlo = lowered.compile().as_text()
        out[sched.value] = collective_bytes(hlo)
    print(json.dumps(out))


def _worker() -> None:
    payload = json.loads(sys.stdin.read())
    if payload.get("view") == "walltime":
        _worker_walltime()
    else:
        _worker_wire_bytes()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--_worker", action="store_true")
    args = ap.parse_args()
    if args._worker:
        _worker()
        return

    # view 1: walltime + agreement on an 8-device mesh
    res = run_with_devices("benchmarks.collective_schedules", WALLTIME_DEVICES,
                           {"view": "walltime"})
    emit("collective_schedules_logreg_walltime", res["logreg"])
    emit("collective_schedules_kmeans_walltime", res["kmeans"])

    # view 2: wire bytes on the production mesh
    res = run_with_devices("benchmarks.collective_schedules", 512,
                           {"view": "wire_bytes"})
    rows = [{"schedule": k, "collective_bytes": v["total_bytes"],
             **{f"n_{op}": n for op, n in v["count_by_op"].items() if n}}
            for k, v in res.items()]
    emit("collective_schedules_wire_bytes", rows)


if __name__ == "__main__":
    main()
